//! Quickstart: build a tiny MINIMALIST network, walk one column through
//! three time steps (the paper's Fig 2 illustration), then classify a
//! synthetic digit through the full mixed-signal stack.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::dataset::glyphs;
use minimalist::energy::EnergyMeter;
use minimalist::nn::{synthetic_network, GoldenNetwork};
use minimalist::quant::W2;
use minimalist::satsim::adc::OFFSET_NEUTRAL;
use minimalist::satsim::column::{Column, ColumnConfig};
use minimalist::util::rng::Rng;

fn main() -> Result<()> {
    println!("== MINIMALIST quickstart ==\n");

    // ---------------------------------------------------------------
    // 1. One synapse column over three time steps (Fig 2A walkthrough)
    // ---------------------------------------------------------------
    let cfg = CircuitConfig::ideal();
    let mut rng = Rng::new(1);
    let n = 8;
    let col_cfg = ColumnConfig {
        w_h: (0..n).map(|i| W2::new((i % 4) as u8)).collect(),
        w_z: (0..n).map(|i| W2::new(((i + 1) % 4) as u8)).collect(),
        slope_m: n,
        offset_code: OFFSET_NEUTRAL,
        v_theta: cfg.v_0,
    };
    let mut col = Column::new(col_cfg, &cfg, &mut rng);
    let mut meter = EnergyMeter::new();
    println!("one GRU column, {n} synapses, 3 time steps:");
    println!("  t | V_h̃ (mV-V0) | z code | V_h (mV-V0) | spike");
    let inputs = [
        vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0],
        vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0],
        vec![0.0; 8],
    ];
    for (t, x) in inputs.iter().enumerate() {
        let s = col.step(x, &cfg, &mut rng, &mut meter);
        println!(
            "  {t} | {:>11.2} | {:>6} | {:>11.2} | {}",
            (s.v_htilde - cfg.v_0) * 1e3,
            s.z.0,
            (s.v_h - cfg.v_0) * 1e3,
            if s.y { "on" } else { "off" }
        );
    }
    println!(
        "  energy so far: {:.1} fJ over {} cap events\n",
        meter.total_j() * 1e15,
        meter.cap_events
    );

    // ---------------------------------------------------------------
    // 2. Full network: golden model vs mixed-signal cores
    // ---------------------------------------------------------------
    let nw = synthetic_network(&[1, 64, 64, 64, 64, 10], 7);
    let mut golden = GoldenNetwork::new(nw.clone());
    let mut engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry::default(),
    )?;
    println!(
        "paper network 1-64-64-64-64-10 on {} physical cores",
        engine.n_cores()
    );

    let sample = &glyphs::make_split(1, 16, 3)[0];
    let g = golden.classify(&sample.pixels);
    let m = engine.classify(&sample.pixels);
    let e = engine.energy();
    println!("digit with label {}:", sample.label);
    println!("  golden model      → class {g}");
    println!("  mixed-signal sim  → class {m}");
    println!(
        "  simulated energy: {:.1} pJ/step over {} steps",
        e.per_step_j() * 1e12,
        e.steps
    );
    let (events, per_frame) = engine.fabric_stats();
    println!(
        "  event fabric: {events} transitions routed \
         ({per_frame:.1} per layer-frame — the 1-bit sparsity the paper \
         banks on)"
    );
    println!("\nNext: examples/smnist_serve.rs for the end-to-end driver.");
    Ok(())
}
