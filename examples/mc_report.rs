//! Monte-Carlo device-variation sweep: fabricate a population of
//! device instances (one per batch slot, docs/adr/008), run a shared
//! glyph workload through the lockstep batched engine, and reduce to
//! per-mismatch-level accuracy and energy statistics.
//!
//!     cargo run --release --example mc_report
//!
//! Each instance `i` is seeded with `instance_seed(master, i)` — the
//! (i+1)-th splitmix64 output of the master seed — so slot `i` is
//! bit-identical to a whole fresh engine built with that seed. The
//! sweep is a pure function of (weights, sweep config): rerunning with
//! the same master seed, at any `--engine-threads`, reproduces the
//! report bit-for-bit.

use anyhow::Result;
use minimalist::config::CoreGeometry;
use minimalist::montecarlo::DeviceSweep;
use minimalist::nn::synthetic_network;

fn main() -> Result<()> {
    // Small synthetic network on small cores so the example completes
    // in seconds; the CLI (`minimalist mc`) sweeps the paper network.
    let nw = synthetic_network(&[1, 16, 10], 7);

    let sweep = DeviceSweep {
        instances: 64,
        mismatch_levels: vec![0.0, 0.005, 0.01, 0.02, 0.05],
        samples: 8,
        img: 8,
        master_seed: 0x5EED,
        geometry: CoreGeometry { rows: 16, cols: 16 },
        ..DeviceSweep::default()
    };

    println!("== Monte-Carlo device-variation sweep ==\n");
    println!(
        "{} device instances per mismatch level, {} samples of {}×{} \
         pixels,\nmaster seed {:#x} (instance i gets the (i+1)-th \
         splitmix64 output).\n",
        sweep.instances, sweep.samples, sweep.img, sweep.img, sweep.master_seed
    );

    let report = sweep.run(&nw)?;
    print!("{}", report.summary());

    println!(
        "\nAccuracy degrades as capacitor mismatch σ grows while the \
         label-flip rate\nagainst the ideal device rises; energy per \
         step is activity-dependent and\nnear-constant across levels. \
         Rerun with any thread count — the report is\nbit-identical \
         for a fixed master seed."
    );
    Ok(())
}
