//! Fig 3C: ADC transfer characteristics as a function of the two tuning
//! knobs — slope (number of IMC capacitors left connected during
//! conversion) and offset (the 6-bit DAC pre-set code).
//!
//!     cargo run --release --example adc_characterization
//!
//! Prints the same families of curves the paper's Fig 3C shows from
//! Cadence mixed-signal simulation: code-vs-V_in for a sweep of slopes
//! at neutral offset, and for a sweep of offsets at fixed slope.

use minimalist::config::CircuitConfig;
use minimalist::energy::EnergyMeter;
use minimalist::satsim::adc::{SarAdc, OFFSET_NEUTRAL};
use minimalist::util::rng::Rng;

fn main() {
    let cfg = CircuitConfig::default();
    let mut rng = Rng::new(0xADC);
    let adc = SarAdc::new(&cfg, &mut rng);
    let mut meter = EnergyMeter::new();

    let sweep: Vec<f64> = (0..=40)
        .map(|i| cfg.v_0 - 0.1 + 0.2 * i as f64 / 40.0)
        .collect();

    // ---- slope family (connected segments m ∈ {0, 4, 16, 64}) ---------
    println!("# Fig 3C (left): slope control via C_IMC segments");
    println!("# columns: V_in-V_0 [mV], then code for m = 0, 4, 16, 64");
    let ms = [0usize, 4, 16, 64];
    for &v in &sweep {
        print!("{:8.1}", (v - cfg.v_0) * 1e3);
        for &m in &ms {
            let c_ext = m as f64 * cfg.c_unit + cfg.c_line;
            let code = adc.convert(v, c_ext, OFFSET_NEUTRAL, &cfg, &mut rng, &mut meter);
            print!(" {code:4}");
        }
        println!();
    }
    for &m in &ms {
        let c_ext = m as f64 * cfg.c_unit + cfg.c_line;
        println!(
            "# m={m:2}: analytic slope {:7.1} codes/V, range {:.1} mV",
            SarAdc::slope_codes_per_volt(c_ext, &cfg),
            64.0 / SarAdc::slope_codes_per_volt(c_ext, &cfg) * 1e3
        );
    }

    // ---- offset family (DAC pre-set ∈ {8, 20, 32, 44, 56}) ------------
    println!("\n# Fig 3C (right): offset control via DAC pre-set");
    println!("# columns: V_in-V_0 [mV], then code for off = 8, 20, 32, 44, 56");
    let offs = [8u8, 20, 32, 44, 56];
    let m_fixed = 16usize;
    let c_ext = m_fixed as f64 * cfg.c_unit + cfg.c_line;
    for &v in &sweep {
        print!("{:8.1}", (v - cfg.v_0) * 1e3);
        for &off in &offs {
            let code = adc.convert(v, c_ext, off, &cfg, &mut rng, &mut meter);
            print!(" {code:4}");
        }
        println!();
    }
    println!(
        "\n# {} conversions, {} comparator strobes, {:.2} pJ total",
        meter.adc_conversions,
        meter.comparator_decisions,
        meter.total_j() * 1e12
    );
}
