//! End-to-end driver: batched serving of
//! sequential-digit classification through the full stack — request
//! queue → dynamic batcher → backend (PJRT-compiled JAX model, golden
//! rust model, or the switched-capacitor simulator) — reporting
//! accuracy, latency percentiles and throughput.
//!
//!     cargo run --release --example smnist_serve -- \
//!         [--backend pjrt|golden|satsim] [--requests 64] \
//!         [--weights runs/hw_s0/weights.mtf] [--max-batch 8] \
//!         [--workers N]
//!
//! golden/satsim shard across `--workers` backend instances (default:
//! one per CPU). The PJRT backend requires `make artifacts` (and its
//! sequence length is fixed at compile time — 16×16 inputs by default);
//! it runs single-worker, constructed on its serving thread because the
//! XLA handles are not `Send`.

use std::time::Duration;

use anyhow::{bail, Context, Result};
use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    BatchPolicy, GoldenBackend, MixedSignalBackend, PjrtBackend, Server,
};
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, NetworkWeights};
use minimalist::runtime::Runtime;
use minimalist::util::cli::Args;
use minimalist::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    let backend_kind = args.get_or("backend", "golden").to_string();
    let n_req = args.get_usize("requests", 64)?;
    let img = args.get_usize("img-size", 16)?;
    let workers = args
        .get_usize("workers", minimalist::config::default_workers())?
        .max(1);
    let policy = BatchPolicy::new(
        args.get_usize("max-batch", 8)?,
        Duration::from_millis(args.get_u64("max-wait-ms", 4)?),
    );

    let weights = match args.opt("weights") {
        Some(p) => NetworkWeights::load(p)?,
        None => ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf", "../runs/hw_s0/weights.mtf", "../runs/quant_s0/weights.mtf"]
            .iter()
            .find(|p| std::path::Path::new(p).exists())
            .map(|p| NetworkWeights::load(p))
            .transpose()?
            .unwrap_or_else(|| {
                eprintln!("note: no trained checkpoint; synthetic weights");
                synthetic_network(&[1, 64, 64, 64, 64, 10], 7)
            }),
    };

    println!(
        "== smnist_serve: backend={backend_kind}, {n_req} requests, \
         {workers} worker(s), batch≤{}, wait≤{:?} ==",
        policy.max_batch, policy.max_wait
    );

    let server = match backend_kind.as_str() {
        "golden" => Server::spawn_sharded(
            GoldenBackend::factory(weights.clone()),
            policy,
            workers,
        ),
        "satsim" => {
            let (plan, factory) = MixedSignalBackend::factory(
                weights.clone(),
                CircuitConfig::default(),
                CoreGeometry::default(),
            )?;
            println!(
                "mapping: {} core(s) of {}x{}",
                plan.n_cores, plan.geometry.rows, plan.geometry.cols
            );
            // uniform-length batches arrive as one lockstep group for
            // the engine's batched path
            Server::spawn_sharded(factory, policy.bucketed(), workers)
        }
        "pjrt" => {
            let meta_text = std::fs::read_to_string("artifacts/meta.json")
                .context("reading artifacts/meta.json — run `make artifacts`")?;
            let meta = Json::parse(&meta_text)
                .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
            let t_len = meta.req_f64("t_len")? as usize;
            let batch = meta.req_f64("batch")? as usize;
            let dims: Vec<usize> = meta
                .req("dims")?
                .as_arr()
                .unwrap()
                .iter()
                .map(|d| d.as_f64().unwrap() as usize)
                .collect();
            if t_len != img * img {
                bail!(
                    "artifact sequence length {t_len} != requested {}; \
                     re-run aot.py with --img-size {img}",
                    img * img
                );
            }
            let (d_in, n_classes) = (dims[0], *dims.last().unwrap());
            Server::spawn_with(
                move || {
                    let rt = Runtime::cpu().expect("PJRT client");
                    let exe = rt
                        .load_hlo_text("artifacts/sequence.hlo.txt")
                        .expect("loading sequence artifact");
                    Box::new(PjrtBackend::new(exe, t_len, batch, d_in, n_classes)) as _
                },
                // the AOT artifact is compiled for one [T, B, d] shape —
                // length bucketing guarantees it never sees a ragged batch
                policy.bucketed(),
            )
        }
        other => bail!("unknown backend '{other}' (golden|satsim|pjrt)"),
    };

    // reference labels for accuracy: the golden model is ground truth
    // for serving consistency; the dataset label measures task accuracy.
    let samples = glyphs::make_split(n_req, img, args.get_u64("seed", 1)?);
    let client = server.client();
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| (s.label, client.submit(i as u64, s.pixels.clone())))
        .collect();
    let mut correct = 0usize;
    let mut failed = 0usize;
    for (label, rx) in rxs {
        // failed requests are reported, not fatal — the summary (with
        // its error counter) must still print
        match rx.recv() {
            Ok(resp) => match resp.result {
                Ok(l) => correct += (l == label) as usize,
                Err(e) => {
                    failed += 1;
                    eprintln!("request {} failed: {e}", resp.id);
                }
            },
            Err(_) => failed += 1,
        }
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();

    println!("latency  : {}", metrics.summary());
    println!(
        "wall     : {:?} for {n_req} sequences of T={} → {:.1} seq/s",
        wall,
        img * img,
        n_req as f64 / wall.as_secs_f64()
    );
    println!(
        "accuracy : {correct}/{n_req} = {:.3} ({failed} failed)",
        correct as f64 / n_req as f64
    );
    Ok(())
}
