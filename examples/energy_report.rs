//! §4.2 energy: the analytic worst-case bound (paper: 169 pJ per time
//! step for 4 cores of 64×64, all switches toggling, z ≡ 1) plus the
//! activity-dependent simulated energy the paper leaves to future work.
//!
//!     cargo run --release --example energy_report

use anyhow::Result;
use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::dataset::glyphs;
use minimalist::energy::{paper_network_bound, worst_case_step_bound};
use minimalist::nn::{synthetic_network, NetworkWeights};
use minimalist::util::bench::Table;

fn main() -> Result<()> {
    let cfg = CircuitConfig::default();

    println!("== §4.2 energy model ==\n");
    println!("electrical parameters:");
    println!("  V_DD {} V, C_unit {:.1} fF, C_gate {:.2} fF",
             cfg.v_dd, cfg.c_unit * 1e15, cfg.c_gate * 1e15);

    let per_core = worst_case_step_bound(&cfg, 64, 64);
    println!("\nanalytic worst case (all caps full swing, all switches toggle):");
    println!("  per 64×64 core : {:.1} pJ/step", per_core * 1e12);
    println!(
        "  4-core network : {:.1} pJ/step   (paper's bound: 169 pJ)",
        paper_network_bound(&cfg) * 1e12
    );

    // ---- simulated, activity-dependent -------------------------------
    let nw: NetworkWeights = {
        let candidates = ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf", "../runs/hw_s0/weights.mtf", "../runs/quant_s0/weights.mtf"];
        candidates
            .iter()
            .find(|p| std::path::Path::new(p).exists())
            .map(|p| NetworkWeights::load(p).unwrap())
            .unwrap_or_else(|| synthetic_network(&[1, 64, 64, 64, 64, 10], 7))
    };
    let mut engine =
        MixedSignalEngine::new(nw, cfg.clone(), CoreGeometry::default())?;

    let samples = glyphs::make_split(4, 16, 21);
    for s in &samples {
        engine.classify(&s.pixels);
    }
    // live meter state: the cores' meters are lifetime-cumulative, so
    // the per-inference figure is the total amortized over the
    // inferences actually run
    let m = engine.energy();
    let n_inf = samples.len() as f64;

    println!("\nsimulated on real digit sequences ({} cores, {} steps):",
             engine.n_cores(), m.steps);
    let mut t = Table::new(&["quantity", "value"]);
    t.row(&["cap events".into(), format!("{}", m.cap_events)]);
    t.row(&["switch toggles".into(), format!("{}", m.switch_toggles)]);
    t.row(&["ADC conversions".into(), format!("{}", m.adc_conversions)]);
    t.row(&["comparator strobes".into(), format!("{}", m.comparator_decisions)]);
    t.row(&["cap energy".into(), format!("{:.2} pJ", m.cap_energy_j * 1e12)]);
    t.row(&["gate energy".into(), format!("{:.2} pJ", m.gate_energy_j * 1e12)]);
    t.row(&["energy / step".into(), format!("{:.2} pJ", m.per_step_j() * 1e12)]);
    t.row(&[
        "energy / inference".into(),
        format!("{:.2} pJ", m.total_j() / n_inf * 1e12),
    ]);
    t.row(&[
        "bound utilization".into(),
        format!(
            "{:.1} %",
            100.0 * m.per_step_j()
                / (engine.n_cores() as f64 * per_core)
        ),
    ]);
    t.print();
    println!(
        "\nThe simulated figure sits below the bound because real \
         activity is sparse:\nmost rows clamp to V_0 (small ΔV) and z \
         rarely saturates at 1 (few swaps)."
    );
    Ok(())
}
