//! Streaming end-to-end driver: sequential-digit classification with
//! frames arriving incrementally, the way a memory-constrained edge
//! sensor would deliver them — instead of handing the server whole
//! sequences, each client opens a **session**, pushes pixels a chunk at
//! a time, polls the running logits mid-sequence (watch the prediction
//! firm up as evidence accumulates), and closes for the final label.
//!
//!     cargo run --release --example smnist_stream -- \
//!         [--backend golden|satsim] [--requests 32] [--img-size 16] \
//!         [--workers 2] [--sessions 8] [--frames-per-push 32] \
//!         [--weights runs/hw_s0/weights.mtf]
//!
//! Every live session's analog state stays resident in one engine slot
//! of its worker (capacitor voltages, swap configuration, RNG stream
//! position), and each tick advances all sessions with pending frames
//! through a single lockstep plan traversal. The streamed labels are
//! bit-identical to one-shot classification of the same pixels —
//! verified here against the golden model's direct answer.

use anyhow::{bail, Result};
use minimalist::config::{CircuitConfig, CoreGeometry, MappingConfig};
use minimalist::coordinator::{GoldenBackend, MixedSignalBackend, StreamServer};
use minimalist::dataset::glyphs;
use minimalist::mapping::Plan;
use minimalist::nn::{argmax, synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let backend_kind = args.get_or("backend", "golden").to_string();
    let n_req = args.get_usize("requests", 32)?;
    let img = args.get_usize("img-size", 16)?;
    let workers = args.get_usize("workers", 2)?.max(1);
    let sessions = args.get_usize("sessions", 8)?.max(1);
    let chunk = args.get_usize("frames-per-push", 32)?.max(1);

    let weights = match args.opt("weights") {
        Some(p) => NetworkWeights::load(p)?,
        None => {
            eprintln!("note: no trained checkpoint; synthetic weights");
            synthetic_network(&[1, 64, 64, 64, 64, 10], 7)
        }
    };
    let mut golden = GoldenNetwork::new(weights.clone());

    println!(
        "== smnist_stream: backend={backend_kind}, {n_req} sessions over \
         {workers} worker(s) × {sessions} slot(s), {chunk} frame(s)/push =="
    );

    let server = match backend_kind.as_str() {
        "golden" => StreamServer::spawn(
            GoldenBackend::streaming_factory(weights.clone(), sessions),
            workers,
            sessions,
        ),
        "satsim" => {
            let planned = Plan::build(
                &weights.dims,
                &MappingConfig::with_geometry(CoreGeometry::default()),
            )?;
            let (plan, factory) = MixedSignalBackend::streaming_factory_from_plan(
                weights.clone(),
                CircuitConfig::default(),
                planned,
                sessions,
            )?;
            println!(
                "mapping: {} core(s) of {}x{}, {} resident slot(s)/worker",
                plan.n_cores, plan.geometry.rows, plan.geometry.cols, sessions
            );
            StreamServer::spawn(factory, workers, sessions)
        }
        other => bail!("unknown backend '{other}' (golden|satsim)"),
    };

    let client = server.client();
    let samples = glyphs::make_split(n_req, img, args.get_u64("seed", 1)?);
    let capacity = workers * sessions;
    let (mut correct, mut agree, mut failed) = (0usize, 0usize, 0usize);
    let mut watched = false;
    let t0 = std::time::Instant::now();
    for wave in samples.chunks(capacity) {
        // open one session per sample of this wave (≤ capacity, so no
        // Busy rejections in this driver — serve --streaming has the
        // oversubscription knob)
        let mut live = Vec::new();
        for s in wave {
            match client.open() {
                Ok(sess) => live.push((s, sess, 0usize)),
                Err(e) => {
                    failed += 1;
                    eprintln!("open failed: {e}");
                }
            }
        }
        // frame-paced rounds: a chunk per session per round, pushed
        // without waiting so the worker advances them in lockstep
        let total = img * img;
        while live.iter().any(|(_, _, cur)| *cur < total) {
            let mut acks = Vec::with_capacity(live.len());
            for (s, sess, cur) in live.iter_mut() {
                if *cur >= s.pixels.len() {
                    continue;
                }
                let end = (*cur + chunk).min(s.pixels.len());
                acks.push(sess.push_frames_nowait(s.pixels[*cur..end].to_vec()));
                *cur = end;
            }
            for rx in acks {
                let _ = rx.recv();
            }
            // once per run, watch a prediction firm up mid-sequence
            if !watched {
                if let Some((s, sess, cur)) = live.first() {
                    if *cur * 2 >= total && *cur < total {
                        if let Ok(l) = sess.logits() {
                            println!(
                                "  session {} at {}/{} frames: running \
                                 argmax={} (true label {})",
                                sess.id,
                                cur,
                                total,
                                argmax(&l),
                                s.label
                            );
                            watched = true;
                        }
                    }
                }
            }
        }
        for (s, sess, _) in live {
            match sess.close() {
                Ok(label) => {
                    correct += (label == s.label) as usize;
                    // the streamed label equals one-shot classification
                    if backend_kind == "golden" {
                        agree += (label == golden.classify(&s.pixels)) as usize;
                    }
                }
                Err(e) => {
                    failed += 1;
                    eprintln!("close failed: {e}");
                }
            }
        }
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();

    println!("latency  : {}", metrics.summary());
    println!(
        "wall     : {:?} for {n_req} streamed sequences of T={} → {:.1} seq/s",
        wall,
        img * img,
        n_req as f64 / wall.as_secs_f64()
    );
    if backend_kind == "golden" {
        println!(
            "parity   : {agree}/{} streamed labels equal one-shot golden \
             classification",
            n_req - failed
        );
    }
    println!(
        "accuracy : {correct}/{n_req} = {:.3} ({failed} failed)",
        correct as f64 / n_req as f64
    );
    Ok(())
}
