//! Fig 4: compare the activations (z, h̃, h) of a unit between the
//! software model and the mixed-signal simulation, set up with
//! equivalent weights and biases — on a trained network when available.
//!
//!     cargo run --release --example trace_compare -- \
//!         [--weights runs/hw_s0/weights.mtf] [--unit 7] [--layer 1]
//!
//! Prints three aligned trace tables (software | ideal circuit | noisy
//! circuit) plus summary deviation statistics.

use anyhow::Result;
use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let layer = args.get_usize("layer", 1)?;
    let unit = args.get_usize("unit", 7)?;
    let nw = match args.opt("weights") {
        Some(p) => NetworkWeights::load(p)?,
        None => {
            for c in ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf", "../runs/hw_s0/weights.mtf", "../runs/quant_s0/weights.mtf"] {
                if std::path::Path::new(c).exists() {
                    eprintln!("using trained checkpoint {c}");
                    return run(NetworkWeights::load(c)?, layer, unit);
                }
            }
            eprintln!("no checkpoint found; using a synthetic network");
            synthetic_network(&[1, 64, 64, 64, 64, 10], 42)
        }
    };
    run(nw, layer, unit)
}

fn run(nw: NetworkWeights, layer: usize, unit: usize) -> Result<()> {
    let sample = &glyphs::make_split(1, 16, 11)[0];
    let seq = &sample.pixels;
    let t_show = 48usize.min(seq.len());

    // software model traces
    let mut golden = GoldenNetwork::new(nw.clone());
    golden.reset();
    let mut g_z = Vec::new();
    let mut g_h = Vec::new();
    let mut g_ht = Vec::new();
    for &x in seq.iter().take(t_show) {
        let mut tr = Vec::new();
        golden.step(&[x], Some(&mut tr));
        g_z.push(tr[layer].z[unit]);
        g_h.push(tr[layer].h[unit]);
        g_ht.push(tr[layer].htilde[unit]);
    }

    // circuit traces (ideal + default non-idealities)
    let trace_engine = |cfg: CircuitConfig| -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut e =
            MixedSignalEngine::new(nw.clone(), cfg, CoreGeometry::default())?;
        e.reset();
        let (mut z, mut h, mut ht) = (Vec::new(), Vec::new(), Vec::new());
        for (t, &x) in seq.iter().take(t_show).enumerate() {
            let mut tr = Vec::new();
            e.step(t as u32, &[x], Some(&mut tr));
            z.push(tr[layer].z.last().unwrap()[unit]);
            h.push(tr[layer].h.last().unwrap()[unit]);
            ht.push(tr[layer].htilde.last().unwrap()[unit]);
        }
        Ok((z, h, ht))
    };
    let (iz, ih, iht) = trace_engine(CircuitConfig::ideal())?;
    let (nz, nh, nht) = trace_engine(CircuitConfig::default())?;

    println!("# Fig 4 traces — layer {layer}, unit {unit} (logical units)");
    println!("#  t |   z sw  z ideal  z noisy |  h̃ sw  h̃ ideal  h̃ noisy |   h sw  h ideal  h noisy");
    for t in 0..t_show {
        println!(
            "{t:4} | {:6.3} {:7.3} {:7.3} | {:6.3} {:7.3} {:7.3} | {:6.3} {:7.3} {:7.3}",
            g_z[t], iz[t], nz[t], g_ht[t], iht[t], nht[t], g_h[t], ih[t], nh[t]
        );
    }

    let rms = |a: &[f32], b: &[f32]| -> f32 {
        (a.iter()
            .zip(b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            / a.len() as f32)
            .sqrt()
    };
    println!("\n# deviation vs software model (RMS over {t_show} steps):");
    println!(
        "#   ideal circuit: z {:.4}  h̃ {:.4}  h {:.4}",
        rms(&g_z, &iz),
        rms(&g_ht, &iht),
        rms(&g_h, &ih)
    );
    println!(
        "#   noisy circuit: z {:.4}  h̃ {:.4}  h {:.4}",
        rms(&g_z, &nz),
        rms(&g_ht, &nht),
        rms(&g_h, &nh)
    );
    Ok(())
}
