//! Ablation benches for the design choices DESIGN.md §4 calls out:
//! non-ideality sensitivity (mismatch, noise, injection), ADC resolution
//! (via slope granularity), and swap granularity (cap bank size).
//!
//!     cargo bench --bench ablations

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::util::bench::Table;

fn network() -> NetworkWeights {
    // Prefer the *quant* checkpoint: it is the best-trained quantized
    // network, so its logits are differentiated enough that agreement
    // numbers mean something (a near-chance checkpoint flips argmax on
    // any epsilon). Deployment snapping (α to the ADC slope grid, β into
    // the ±3 DAC range) keeps golden and circuit on the same parameters.
    let raw = (|| {
        for c in ["runs/quant_s0/weights.mtf", "runs/hw_s0/weights.mtf",
                  "../runs/quant_s0/weights.mtf", "../runs/hw_s0/weights.mtf"] {
            if std::path::Path::new(c).exists() {
                if let Ok(nw) = NetworkWeights::load(c) {
                    eprintln!("# using checkpoint {c}");
                    return nw;
                }
            }
        }
        synthetic_network(&[1, 64, 64, 64, 64, 10], 42)
    })();
    minimalist::quant::codesign::snap_network(
        &raw,
        &CircuitConfig::ideal(),
        64,
    )
    .unwrap()
}

fn agreement(nw: &NetworkWeights, cfg: CircuitConfig, n: usize) -> (f64, f64) {
    let samples = glyphs::make_split(n, 16, 77);
    let mut golden = GoldenNetwork::new(nw.clone());
    let mut engine = MixedSignalEngine::new(
        nw.clone(),
        cfg,
        CoreGeometry::default(),
    )
    .unwrap();
    let mut agree = 0usize;
    let mut task = 0usize;
    for s in &samples {
        let g = golden.classify(&s.pixels);
        let m = engine.classify(&s.pixels);
        agree += (g == m) as usize;
        task += (m == s.label) as usize;
    }
    (agree as f64 / n as f64, task as f64 / n as f64)
}

fn main() {
    let nw = network();
    let n = 16; // sequences per cell (satsim is the budget)

    println!("== ablation: non-ideality sensitivity ==");
    println!("# class agreement = mixed-signal vs golden on the same input\n");
    let mut t = Table::new(&["configuration", "agree w/ golden", "task acc"]);
    let base = CircuitConfig::default();
    let cases: Vec<(&str, CircuitConfig)> = vec![
        ("ideal", CircuitConfig::ideal()),
        ("default", base.clone()),
        ("mismatch ×4", { let mut c = base.clone(); c.sigma_c *= 4.0; c }),
        ("comparator noise ×8", {
            let mut c = base.clone();
            c.sigma_comp_noise *= 8.0;
            c.sigma_comp_offset *= 8.0;
            c
        }),
        ("charge injection ×10", { let mut c = base.clone(); c.c_inj *= 10.0; c }),
        ("hot (400 K)", { let mut c = base.clone(); c.temp_k = 400.0; c }),
        ("small caps (C/4)", {
            let mut c = base.clone();
            c.c_unit /= 4.0;
            c.c_adc_unit /= 4.0;
            c
        }),
    ];
    for (name, cfg) in cases {
        let (agree, task) = agreement(&nw, cfg, n);
        t.row(&[name.to_string(), format!("{agree:.2}"), format!("{task:.2}")]);
    }
    t.print();

    println!("\n== ablation: swap granularity (state-bank size) ==");
    println!("# fewer caps per bank → coarser z mixing (6-bit z needs ≥64).");
    println!("# small synthetic net (1-16-10) so core rows can shrink;");
    println!("# worst per-unit |Δh| vs golden over a random sequence.\n");
    let small = synthetic_network(&[1, 16, 10], 7);
    let mut t2 = Table::new(&["core rows", "layer-0 bank caps", "worst |Δh|"]);
    for rows in [16usize, 32, 64] {
        let mut engine = MixedSignalEngine::new(
            small.clone(),
            CircuitConfig::ideal(),
            CoreGeometry { rows, cols: 16 },
        )
        .unwrap();
        let mut golden = GoldenNetwork::new(small.clone());
        engine.reset();
        golden.reset();
        let mut worst = 0.0f32;
        for t in 0..64u32 {
            let x = ((t * 37) % 11) as f32 / 10.0;
            let mut et = Vec::new();
            let mut gt = Vec::new();
            engine.step(t, &[x], Some(&mut et));
            golden.step(&[x], Some(&mut gt));
            for (a, b) in et[0].h.last().unwrap().iter().zip(&gt[0].h) {
                worst = worst.max((a - b).abs());
            }
        }
        t2.row(&[
            format!("{rows}"),
            format!("{}", rows), // layer 0 replicates 1 input to all rows
            format!("{worst:.4}"),
        ]);
    }
    t2.print();
}
