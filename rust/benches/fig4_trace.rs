//! Bench + regeneration target for Fig 4: software vs mixed-signal trace
//! agreement on a trained network, with the step timing of both paths.
//!
//!     cargo bench --bench fig4_trace

use std::time::Duration;

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::util::bench::{bench, black_box, fmt_ns, Table};

fn network() -> NetworkWeights {
    let raw = (|| {
        for c in ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf", "../runs/hw_s0/weights.mtf", "../runs/quant_s0/weights.mtf"] {
            if std::path::Path::new(c).exists() {
                if let Ok(nw) = NetworkWeights::load(c) {
                    eprintln!("# using trained checkpoint {c}");
                    return nw;
                }
            }
        }
        eprintln!("# no checkpoint; synthetic paper-size network");
        synthetic_network(&[1, 64, 64, 64, 64, 10], 42)
    })();
    // compare on the deployed (circuit-realizable) parameters
    minimalist::quant::codesign::snap_network(
        &raw,
        &minimalist::config::CircuitConfig::ideal(),
        64,
    )
    .unwrap()
}

fn main() {
    let nw = network();
    let sample = &glyphs::make_split(1, 16, 11)[0];
    let seq = &sample.pixels;

    println!("== Fig 4 regeneration: trace agreement ==\n");
    let mut table = Table::new(&[
        "configuration", "RMS Δz", "RMS Δh̃", "RMS Δh", "class agree",
    ]);

    let mut golden = GoldenNetwork::new(nw.clone());
    let gold_class = golden.classify(seq);

    for (name, cfg) in [
        ("ideal circuit", CircuitConfig::ideal()),
        ("default non-idealities", CircuitConfig::default()),
        ("3× mismatch & noise", {
            let mut c = CircuitConfig::default();
            c.sigma_c *= 3.0;
            c.sigma_comp_noise *= 3.0;
            c.sigma_comp_offset *= 3.0;
            c
        }),
    ] {
        let mut engine = MixedSignalEngine::new(
            nw.clone(),
            cfg,
            CoreGeometry::default(),
        )
        .unwrap();
        engine.reset();
        golden.reset();
        let (mut sz, mut sht, mut sh, mut n) = (0.0f64, 0.0f64, 0.0f64, 0u64);
        for (t, &x) in seq.iter().enumerate() {
            let mut et = Vec::new();
            let mut gt = Vec::new();
            engine.step(t as u32, &[x], Some(&mut et));
            golden.step(&[x], Some(&mut gt));
            for l in 0..gt.len() {
                for (a, b) in et[l].z.last().unwrap().iter().zip(&gt[l].z) {
                    sz += ((a - b) as f64).powi(2);
                }
                for (a, b) in
                    et[l].htilde.last().unwrap().iter().zip(&gt[l].htilde)
                {
                    sht += ((a - b) as f64).powi(2);
                }
                for (a, b) in et[l].h.last().unwrap().iter().zip(&gt[l].h) {
                    sh += ((a - b) as f64).powi(2);
                }
                n += gt[l].z.len() as u64;
            }
        }
        let rms = |s: f64| (s / n as f64).sqrt();
        let sim_class = {
            let mut e2 = MixedSignalEngine::new(
                nw.clone(),
                engine.circuit.clone(),
                CoreGeometry::default(),
            )
            .unwrap();
            e2.classify(seq)
        };
        table.row(&[
            name.to_string(),
            format!("{:.4}", rms(sz)),
            format!("{:.4}", rms(sht)),
            format!("{:.4}", rms(sh)),
            format!("{}", sim_class == gold_class),
        ]);
    }
    table.print();

    println!("\n== step timing (full 1-64-64-64-64-10 network) ==");
    let mut engine = MixedSignalEngine::new(
        nw.clone(),
        CircuitConfig::default(),
        CoreGeometry::default(),
    )
    .unwrap();
    let mut t = 0u32;
    let r = bench("satsim network step", Duration::from_secs(3), || {
        let x = seq[(t as usize) % seq.len()];
        engine.step(t, &[x], None);
        t = t.wrapping_add(1);
    });
    println!("  mixed-signal: {} per network step", fmt_ns(r.median_ns));
    let mut g = GoldenNetwork::new(nw);
    let mut i = 0usize;
    let rg = bench("golden network step", Duration::from_secs(2), || {
        let x = seq[i % seq.len()];
        g.step(&[x], None);
        black_box(&g);
        i += 1;
    });
    println!("  golden      : {} per network step", fmt_ns(rg.median_ns));
    println!(
        "  physics overhead: {:.1}×",
        r.median_ns / rg.median_ns
    );
}
