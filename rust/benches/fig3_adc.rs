//! Bench + regeneration target for Fig 3C: ADC transfer characteristics
//! under slope/offset control, plus conversion timing.
//!
//!     cargo bench --bench fig3_adc

use std::time::Duration;

use minimalist::config::CircuitConfig;
use minimalist::energy::EnergyMeter;
use minimalist::satsim::adc::{SarAdc, OFFSET_NEUTRAL};
use minimalist::util::bench::{bench, black_box, fmt_ns, Table};
use minimalist::util::rng::Rng;

fn main() {
    let cfg = CircuitConfig::default();
    let mut rng = Rng::new(0x316);
    let adc = SarAdc::new(&cfg, &mut rng);

    println!("== Fig 3C regeneration: transfer characteristics ==\n");

    // slope family: measured slope + range per segment setting
    let mut t = Table::new(&[
        "segments m", "C_IMC [fF]", "slope [codes/V]", "range [mV]",
        "code(V0-20mV)", "code(V0)", "code(V0+20mV)",
    ]);
    for &m in &[0usize, 2, 4, 8, 16, 32, 64] {
        let c_ext = m as f64 * cfg.c_unit + cfg.c_line;
        let slope = SarAdc::slope_codes_per_volt(c_ext, &cfg);
        let at = |dv: f64| adc.ideal_code(cfg.v_0 + dv, c_ext, OFFSET_NEUTRAL, &cfg);
        t.row(&[
            format!("{m}"),
            format!("{:.1}", c_ext * 1e15),
            format!("{slope:.0}"),
            format!("{:.1}", 64.0 / slope * 1e3),
            format!("{}", at(-0.02)),
            format!("{}", at(0.0)),
            format!("{}", at(0.02)),
        ]);
    }
    t.print();

    // offset family
    println!();
    let mut t2 = Table::new(&["offset code", "code(V0)", "code shift vs neutral"]);
    let c_ext = 16.0 * cfg.c_unit + cfg.c_line;
    let neutral = adc.ideal_code(cfg.v_0, c_ext, OFFSET_NEUTRAL, &cfg) as i32;
    for &off in &[0u8, 8, 16, 32, 48, 56, 63] {
        let c = adc.ideal_code(cfg.v_0, c_ext, off, &cfg) as i32;
        t2.row(&[
            format!("{off}"),
            format!("{c}"),
            format!("{:+}", c - neutral),
        ]);
    }
    t2.print();

    // timing: one noisy SAR conversion (6 strobes + DAC settling)
    println!("\n== conversion timing ==");
    let mut meter = EnergyMeter::new();
    let mut v = cfg.v_0 - 0.05;
    let r = bench("sar_convert (6-bit, noisy)", Duration::from_secs(2), || {
        v = if v > cfg.v_0 + 0.05 { cfg.v_0 - 0.05 } else { v + 1e-4 };
        black_box(adc.convert(v, c_ext, OFFSET_NEUTRAL, &cfg, &mut rng, &mut meter));
    });
    println!(
        "  {}: median {} (→ {:.1} Mconv/s on this host)",
        r.name,
        fmt_ns(r.median_ns),
        1e3 / r.median_ns
    );
}
