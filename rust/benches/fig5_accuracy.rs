//! Fig 5 regeneration harness (rust side): replay the trained variants'
//! checkpoints on the exported test split and print the accuracy table.
//! The training itself runs in python (`make fig5`); this bench verifies
//! the *deployment* accuracy — golden model and mixed-signal engine —
//! matches the python-side evaluation, closing the codesign loop.
//!
//!     cargo bench --bench fig5_accuracy

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::dataset::load_test_split;
use minimalist::nn::{GoldenNetwork, NetworkWeights};
use minimalist::util::bench::Table;

fn main() {
    let split_path = ["artifacts/synthmnist_test.mtf", "../artifacts/synthmnist_test.mtf"]
        .iter()
        .find(|p| std::path::Path::new(p).exists())
        .copied();
    let Some(split_path) = split_path else {
        println!("no test split found — run `make artifacts` (data export) first");
        return;
    };
    let split = load_test_split(split_path).expect("loading test split");
    let n_eval = split.x.len().min(200); // satsim budget on one CPU core

    println!("== Fig 5 regeneration: deployment accuracy ==");
    println!("# paper (sMNIST, 10 seeds): fp32 98.1 %, quant 97.7 %, hw 96.9 %");
    println!("# this testbed: synthMNIST T={}, scaled training (see EXPERIMENTS.md)\n", split.seq_len);

    let mut table = Table::new(&[
        "checkpoint", "golden acc", "satsim acc (ideal)", "satsim acc (noisy)", "n",
    ]);
    for variant in ["quant", "hw"] {
        for seed in 0..4 {
            let path = format!("runs/{variant}_s{seed}/weights.mtf");
            if !std::path::Path::new(&path).exists() {
                continue;
            }
            let nw = NetworkWeights::load(&path).expect("checkpoint");
            let mut golden = GoldenNetwork::new(nw.clone());
            let mut correct_g = 0usize;
            for (x, &y) in split.x.iter().zip(&split.y).take(n_eval) {
                correct_g += (golden.classify(x) == y) as usize;
            }
            // mixed-signal on a subset (physics is ~10× slower)
            let n_ms = n_eval.min(60);
            let mut acc_ms = [0.0f64; 2];
            for (k, cfg) in [CircuitConfig::ideal(), CircuitConfig::default()]
                .into_iter()
                .enumerate()
            {
                let mut engine = MixedSignalEngine::new(
                    nw.clone(),
                    cfg,
                    CoreGeometry::default(),
                )
                .expect("engine");
                let mut c = 0usize;
                for (x, &y) in split.x.iter().zip(&split.y).take(n_ms) {
                    c += (engine.classify(x) == y) as usize;
                }
                acc_ms[k] = c as f64 / n_ms as f64;
            }
            table.row(&[
                format!("{variant}_s{seed}"),
                format!("{:.3}", correct_g as f64 / n_eval as f64),
                format!("{:.3}", acc_ms[0]),
                format!("{:.3}", acc_ms[1]),
                format!("{n_eval}/{}", n_ms),
            ]);
        }
    }
    table.print();
    println!("\n# fp32 rows have no circuit mapping (no code planes) — their");
    println!("# accuracy lives in runs/fig5_summary.json from python training.");
    println!("# NB all rows are evaluated under *hardware semantics* (hard-σ,");
    println!("# 6-bit z, comparator bias): hw rows match their python eval;");
    println!("# quant rows show the deployment drop of a non-hw-trained");
    println!("# checkpoint (gate β outside the ADC range — see EXPERIMENTS.md).");
}
