//! End-to-end serving throughput/latency across worker counts, backends
//! and batching policies — the headline-systems bench of the serving
//! extension (DESIGN.md §4, last row).
//!
//!     cargo bench --bench throughput
//!
//! The first table sweeps the coordinator's worker count on a fixed
//! synthetic workload: the speedup column is the direct measurement of
//! the sharded engine (workers = 1 reproduces the old single-leader
//! configuration).
//!
//! After the human-readable tables, the machine-readable suite
//! ([`minimalist::bench_suite`]) runs — engine steps/s, the lockstep
//! batch-size sweep, serving sweeps — and writes `BENCH_baseline.json`, the
//! same file `minimalist bench` produces, so CI and local runs record
//! comparable baselines. Pass `-- --quick` for smoke scale.

use std::time::{Duration, Instant};

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    BatchPolicy, GoldenBackend, MixedSignalBackend, Server,
};
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, NetworkWeights};
use minimalist::util::bench::Table;

fn network() -> NetworkWeights {
    for c in [
        "runs/hw_s0/weights.mtf",
        "runs/quant_s0/weights.mtf",
        "../runs/hw_s0/weights.mtf",
        "../runs/quant_s0/weights.mtf",
    ] {
        if std::path::Path::new(c).exists() {
            if let Ok(nw) = NetworkWeights::load(c) {
                return nw;
            }
        }
    }
    synthetic_network(&[1, 64, 64, 64, 64, 10], 42)
}

/// Serve `n_req` sequences through an already-spawned server; returns
/// (wall time, p50, p99).
fn drive(
    server: Server,
    samples: &[glyphs::Sample],
) -> (Duration, Duration, Duration) {
    let client = server.client();
    let t0 = Instant::now();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let wall = t0.elapsed();
    let m = server.shutdown();
    (wall, m.percentile(50.0), m.percentile(99.0))
}

fn main() {
    let nw = network();
    let img = 16usize;
    println!("== serving throughput (T={} pixel sequences) ==\n", img * img);

    // ---- worker sweep: the sharded-coordinator measurement ------------
    let n_req = 128usize;
    let samples = glyphs::make_split(n_req, img, 3);
    let policy = BatchPolicy::new(8, Duration::from_millis(1));
    let max_workers = minimalist::config::default_workers();
    println!(
        "worker sweep: golden backend, {n_req} requests, batch≤{}, host \
         parallelism {max_workers}",
        policy.max_batch
    );
    let mut sweep = Table::new(&[
        "workers", "wall", "seq/s", "p50", "p99", "speedup vs 1",
    ]);
    let mut base_rate = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        if workers > max_workers.max(2) {
            println!("# skipping workers={workers} (> host parallelism)");
            continue;
        }
        let server = Server::spawn_sharded(
            GoldenBackend::factory(nw.clone()),
            policy,
            workers,
        );
        let (wall, p50, p99) = drive(server, &samples);
        let rate = n_req as f64 / wall.as_secs_f64();
        if workers == 1 {
            base_rate = rate;
        }
        sweep.row(&[
            format!("{workers}"),
            format!("{wall:.2?}"),
            format!("{rate:.1}"),
            format!("{p50:.2?}"),
            format!("{p99:.2?}"),
            if base_rate > 0.0 {
                format!("{:.2}×", rate / base_rate)
            } else {
                "-".to_string()
            },
        ]);
    }
    sweep.print();

    // ---- backend × batch comparison -----------------------------------
    println!("\nbackend × batching policy:");
    let mut table = Table::new(&["backend", "workers", "batch", "n", "p50", "p99", "seq/s"]);
    for (name, workers, max_batch, n_req) in [
        ("golden", 1usize, 1usize, 64usize),
        ("golden", 1, 8, 64),
        ("golden", 4, 8, 64),
        ("satsim", 1, 4, 12),
        ("satsim", 2, 4, 12),
    ] {
        let policy = BatchPolicy::new(max_batch, Duration::from_millis(2));
        let server = match name {
            "golden" => Server::spawn_sharded(
                GoldenBackend::factory(nw.clone()),
                policy,
                workers,
            ),
            _ => {
                let (_plan, factory) = MixedSignalBackend::factory(
                    nw.clone(),
                    CircuitConfig::default(),
                    CoreGeometry::default(),
                )
                .unwrap();
                Server::spawn_sharded(factory, policy, workers)
            }
        };
        let samples = glyphs::make_split(n_req, img, 3);
        let (wall, p50, p99) = drive(server, &samples);
        table.row(&[
            name.to_string(),
            format!("{workers}"),
            format!("{max_batch}"),
            format!("{n_req}"),
            format!("{p50:.2?}"),
            format!("{p99:.2?}"),
            format!("{:.1}", n_req as f64 / wall.as_secs_f64()),
        ]);
    }
    table.print();
    println!(
        "\n# satsim rows simulate full circuit physics per step — their \
         throughput is the simulator's, not the chip's. The chip-level \
         estimate lives in the energy model (fJ/step → ns-scale steps)."
    );

    // ---- geometry sweep: the tiled mapping planner on the physics
    // backend — smaller cores force column and then row splits of the
    // same network; the cost of the extra tiles (and of the partial-sum
    // combination of row-split layers) shows up directly -------------
    println!("\ngeometry sweep: satsim backend, 1-48-10 network, 8 requests:");
    let sweep_nw = synthetic_network(&[1, 48, 10], 7);
    let n_req = 8usize;
    let samples = glyphs::make_split(n_req, 8, 3);
    let mut geo = Table::new(&[
        "geometry", "cores", "row-split layers", "wall", "seq/s",
    ]);
    for (rows, cols) in [(64usize, 64usize), (32, 32), (16, 16)] {
        let policy = BatchPolicy::new(4, Duration::from_millis(1));
        let (plan, factory) = MixedSignalBackend::factory(
            sweep_nw.clone(),
            CircuitConfig::default(),
            CoreGeometry { rows, cols },
        )
        .unwrap();
        let n_split = plan.layers.iter().filter(|l| l.is_row_split()).count();
        let server = Server::spawn_sharded(factory, policy, 1);
        let (wall, _p50, _p99) = drive(server, &samples);
        geo.row(&[
            format!("{rows}x{cols}"),
            format!("{}", plan.n_cores),
            format!("{n_split}"),
            format!("{wall:.2?}"),
            format!("{:.1}", n_req as f64 / wall.as_secs_f64()),
        ]);
    }
    geo.print();
    println!(
        "# 48 hidden units: 32x32 and 16x16 cores split the 48-input \
         hidden->readout layer across row tiles (weighted partial-sum \
         combination on the owner tile)."
    );

    // ---- machine-readable baseline (BENCH_baseline.json) --------------
    let quick = std::env::args().any(|a| a == "--quick");
    println!(
        "\nrecording machine-readable baseline ({}) ...",
        if quick { "quick" } else { "full" }
    );
    let doc = minimalist::bench_suite::run(
        &minimalist::bench_suite::BenchOpts { quick },
    );
    minimalist::bench_suite::print_engine_summary(&doc);
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor on the manifest to refresh the committed root-level file
    let out_path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_baseline.json");
    minimalist::bench_suite::write(out_path, &doc)
        .expect("writing BENCH_baseline.json");
    println!("wrote {out_path}");
}
