//! End-to-end serving throughput/latency across backends and batching
//! policies — the headline-systems bench of the serving extension
//! (DESIGN.md §4, last row).
//!
//!     cargo bench --bench throughput

use std::time::{Duration, Instant};

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    BatchPolicy, GoldenBackend, MixedSignalBackend, MixedSignalEngine, Server,
};
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::util::bench::Table;

fn network() -> NetworkWeights {
    for c in ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf", "../runs/hw_s0/weights.mtf", "../runs/quant_s0/weights.mtf"] {
        if std::path::Path::new(c).exists() {
            if let Ok(nw) = NetworkWeights::load(c) {
                return nw;
            }
        }
    }
    synthetic_network(&[1, 64, 64, 64, 64, 10], 42)
}

fn main() {
    let nw = network();
    let img = 16usize;
    println!("== serving throughput (T={} pixel sequences) ==\n", img * img);

    let mut table = Table::new(&[
        "backend", "batch", "n", "p50", "p99", "seq/s",
    ]);

    for (name, max_batch, n_req) in [
        ("golden", 1usize, 64usize),
        ("golden", 8, 64),
        ("golden", 32, 64),
        ("satsim", 4, 12),
    ] {
        let policy = BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(2),
        };
        let server = match name {
            "golden" => Server::spawn(
                Box::new(GoldenBackend::new(GoldenNetwork::new(nw.clone()))),
                policy,
            ),
            _ => {
                let engine = MixedSignalEngine::new(
                    nw.clone(),
                    CircuitConfig::default(),
                    CoreGeometry::default(),
                )
                .unwrap();
                Server::spawn_with(
                    move || Box::new(MixedSignalBackend::new(engine)) as _,
                    policy,
                )
            }
        };
        let client = server.client();
        let samples = glyphs::make_split(n_req, img, 3);
        let t0 = Instant::now();
        let rxs: Vec<_> = samples
            .iter()
            .enumerate()
            .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed();
        let m = server.shutdown();
        table.row(&[
            name.to_string(),
            format!("{max_batch}"),
            format!("{n_req}"),
            format!("{:?}", m.percentile(50.0)),
            format!("{:?}", m.percentile(99.0)),
            format!("{:.1}", n_req as f64 / wall.as_secs_f64()),
        ]);
    }
    table.print();
    println!(
        "\n# satsim rows simulate full circuit physics per step — their \
         throughput is the simulator's, not the chip's. The chip-level \
         estimate lives in the energy model (fJ/step → ns-scale steps)."
    );
}
