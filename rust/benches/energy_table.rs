//! §4.2 regeneration: the energy table — analytic worst-case bound vs
//! simulated activity-dependent energy, swept over core counts and
//! activity levels.
//!
//!     cargo bench --bench energy_table

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::energy::{paper_network_bound, worst_case_step_bound};
use minimalist::nn::synthetic_network;
use minimalist::util::bench::Table;
use minimalist::util::rng::Rng;

fn main() {
    let cfg = CircuitConfig::default();

    println!("== §4.2 regeneration: energy per time step ==\n");
    println!(
        "paper bound: 169 pJ/step for 4×(64×64) cores, z ≡ 1, all \
         switches toggling"
    );
    println!(
        "this model : {:.1} pJ/step (C_unit {:.1} fF, V_DD {} V)\n",
        paper_network_bound(&cfg) * 1e12,
        cfg.c_unit * 1e15,
        cfg.v_dd
    );

    let mut t = Table::new(&[
        "cores", "geometry", "bound [pJ/step]", "simulated [pJ/step]",
        "utilization",
    ]);

    let mut rng = Rng::new(33);
    for (dims, geo) in [
        (vec![1usize, 64, 10], CoreGeometry { rows: 64, cols: 64 }),
        (vec![1, 64, 64, 10], CoreGeometry { rows: 64, cols: 64 }),
        (vec![1, 64, 64, 64, 64, 10], CoreGeometry { rows: 64, cols: 64 }),
        (vec![1, 32, 32, 10], CoreGeometry { rows: 32, cols: 32 }),
    ] {
        let nw = synthetic_network(&dims, 5);
        let mut engine =
            MixedSignalEngine::new(nw, cfg.clone(), geo).unwrap();
        let seq: Vec<f32> = (0..128).map(|_| rng.uniform() as f32).collect();
        engine.classify(&seq);
        let m = engine.energy();
        let bound =
            engine.n_cores() as f64 * worst_case_step_bound(&cfg, geo.rows, geo.cols);
        t.row(&[
            format!("{}", engine.n_cores()),
            format!("{}×{}", geo.rows, geo.cols),
            format!("{:.1}", bound * 1e12),
            format!("{:.1}", m.per_step_j() * 1e12),
            format!("{:.0} %", 100.0 * m.per_step_j() / bound),
        ]);
    }
    t.print();

    // activity sweep: the worst case is approached as inputs saturate
    println!("\nactivity sweep (paper network, input duty cycle):");
    let mut t2 = Table::new(&["input activity", "simulated [pJ/step]", "z̄ effect"]);
    for duty in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
        let nw = synthetic_network(&[1, 64, 64, 64, 64, 10], 5);
        let mut engine = MixedSignalEngine::new(
            nw,
            cfg.clone(),
            CoreGeometry::default(),
        )
        .unwrap();
        let seq: Vec<f32> = (0..96).map(|_| duty).collect();
        engine.classify(&seq);
        let m = engine.energy();
        t2.row(&[
            format!("{duty:.2}"),
            format!("{:.1}", m.per_step_j() * 1e12),
            format!("{} swaps", m.switch_toggles / m.steps.max(1)),
        ]);
    }
    t2.print();
}
