//! Streamed-vs-one-shot parity (PR 5 tentpole).
//!
//! A sequence fed frame by frame through a streaming session — however
//! it is chunked, and however many other live sessions' ticks interleave
//! with it — must produce **bit-identical** logits to a one-shot
//! classification of the same frames, for the golden *and* the
//! mixed-signal backends, under full circuit noise. The mixed-signal
//! guarantee is the slot-RNG seeding convention once more (every leased
//! slot replays the construction noise stream from its own local clock;
//! docs/adr/001 and 003): state that makes streaming *possible* is
//! exactly the state that makes it *exact*.
//!
//! Also pinned here: slot exhaustion (`ServeError::Busy`, leader-side
//! admission), and close-mid-sequence cleanup — a slot abandoned partway
//! through a sequence returns to the free pool, and the next session
//! leasing it matches a fresh sequential run bit for bit.

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    Backend, GoldenBackend, MixedSignalBackend, MixedSignalEngine, ServeError,
    StreamServer,
};
use minimalist::nn::{argmax, synthetic_network, GoldenNetwork};

/// Deterministic per-session test sequence.
fn seq(len: usize, salt: usize) -> Vec<f32> {
    (0..len)
        .map(|t| (((t + 2) * (salt + 3)) % 7) as f32 / 6.0)
        .collect()
}

#[test]
fn golden_streamed_interleaved_matches_one_shot() {
    let nw = synthetic_network(&[1, 12, 10], 9);
    let mut reference = GoldenNetwork::new(nw.clone());
    let mut backend = GoldenBackend::with_sessions(GoldenNetwork::new(nw), 3);
    let sb = backend.streaming().expect("sessions provisioned");
    // three sessions of different lengths, advanced through shared
    // lockstep ticks until each runs out of frames
    let seqs = [seq(24, 0), seq(16, 1), seq(20, 2)];
    let slots: Vec<usize> = (0..3).map(|_| sb.open_session().expect("capacity 3")).collect();
    for t in 0..24 {
        let (mut tick_slots, mut tick_frames) = (Vec::new(), Vec::new());
        for (i, s) in seqs.iter().enumerate() {
            if t < s.len() {
                tick_slots.push(slots[i]);
                tick_frames.push(s[t]);
            }
        }
        sb.step_sessions(&tick_slots, &tick_frames);
    }
    for (i, s) in seqs.iter().enumerate() {
        reference.classify(s);
        assert_eq!(
            sb.session_logits(slots[i]),
            reference.logits(),
            "golden session {i} diverged from one-shot logits"
        );
        assert_eq!(sb.close_session(slots[i]), argmax(&reference.logits()));
    }
}

#[test]
fn mixed_signal_streamed_interleaved_matches_one_shot_noisy() {
    // full circuit noise: this pins the per-slot RNG convention on the
    // streaming path, not just the arithmetic
    let nw = synthetic_network(&[1, 16, 10], 21);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let engine = seq_engine.replicate().unwrap();
    let mut backend = MixedSignalBackend::with_sessions(engine, 3);
    let sb = backend.streaming().expect("sessions provisioned");
    let seqs = [seq(20, 4), seq(12, 5), seq(16, 6)];
    let slots: Vec<usize> = (0..3).map(|_| sb.open_session().expect("capacity 3")).collect();
    for t in 0..20 {
        let (mut tick_slots, mut tick_frames) = (Vec::new(), Vec::new());
        for (i, s) in seqs.iter().enumerate() {
            if t < s.len() {
                tick_slots.push(slots[i]);
                tick_frames.push(s[t]);
            }
        }
        sb.step_sessions(&tick_slots, &tick_frames);
    }
    for (i, s) in seqs.iter().enumerate() {
        let want = seq_engine.classify(s);
        assert_eq!(
            sb.session_logits(slots[i]),
            seq_engine.logits(),
            "mixed-signal session {i} is not bit-identical to one-shot"
        );
        assert_eq!(sb.close_session(slots[i]), want);
    }
}

#[test]
fn mixed_signal_row_split_streams_bit_identical() {
    // 40 inputs on 32-row cores → 2 row tiles: the streamed subset path
    // through the partial-sum combine, interleaved with a second session
    let nw = synthetic_network(&[40, 8], 5);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 32, cols: 32 },
    )
    .unwrap();
    assert!(seq_engine.plan.layers[0].is_row_split());
    let engine = seq_engine.replicate().unwrap();
    let mut backend = MixedSignalBackend::with_sessions(engine, 2);
    let sb = backend.streaming().expect("sessions provisioned");
    let (a, b) = (seq(40 * 8, 7), seq(40 * 5, 8));
    let (sa, sb_slot) = (sb.open_session().unwrap(), sb.open_session().unwrap());
    for t in 0..8 {
        let mut slots = vec![sa];
        let mut frames = a[t * 40..(t + 1) * 40].to_vec();
        if t < 5 {
            slots.push(sb_slot);
            frames.extend_from_slice(&b[t * 40..(t + 1) * 40]);
        }
        sb.step_sessions(&slots, &frames);
    }
    let want_a = seq_engine.classify(&a);
    assert_eq!(sb.session_logits(sa), seq_engine.logits());
    let want_b = seq_engine.classify(&b);
    assert_eq!(sb.session_logits(sb_slot), seq_engine.logits());
    assert_eq!(sb.close_session(sa), want_a);
    assert_eq!(sb.close_session(sb_slot), want_b);
}

#[test]
fn close_mid_sequence_recycles_slot_bit_clean() {
    // a session abandoned partway through returns its slot to the pool,
    // and the next session leasing that slot matches a fresh sequential
    // run exactly — no residue from the abandoned analog state
    let nw = synthetic_network(&[1, 16, 10], 33);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let engine = seq_engine.replicate().unwrap();
    let mut backend = MixedSignalBackend::with_sessions(engine, 1);
    let sb = backend.streaming().expect("sessions provisioned");
    let abandoned = sb.open_session().expect("capacity 1");
    assert!(sb.open_session().is_none(), "slot pool must exhaust");
    // advance the abandoned session partway, then close mid-sequence
    for t in 0..7 {
        sb.step_sessions(&[abandoned], &[seq(20, 9)[t]]);
    }
    sb.close_session(abandoned);
    // the freed slot serves a fresh session
    let fresh = sb.open_session().expect("slot must return to the pool");
    assert_eq!(fresh, abandoned);
    let s = seq(20, 10);
    for &f in &s {
        sb.step_sessions(&[fresh], &[f]);
    }
    let want = seq_engine.classify(&s);
    assert_eq!(
        sb.session_logits(fresh),
        seq_engine.logits(),
        "recycled slot must match a fresh sequential run bit for bit"
    );
    assert_eq!(sb.close_session(fresh), want);
}

#[test]
fn stream_server_e2e_matches_one_shot_golden_and_satsim() {
    // the full protocol path — leader routing, worker affinity, frame
    // assembly — on both backends, sessions interleaved and chunked
    // unevenly; every streamed label must equal one-shot classification
    let nw = synthetic_network(&[1, 12, 10], 13);
    let mut golden_ref = GoldenNetwork::new(nw.clone());
    let satsim_template = MixedSignalEngine::new(
        nw.clone(),
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut satsim_ref = satsim_template.replicate().unwrap();

    let golden_server =
        StreamServer::spawn(GoldenBackend::streaming_factory(nw.clone(), 4), 1, 4);
    let (_, satsim_factory) = MixedSignalBackend::streaming_factory_from_plan(
        nw,
        CircuitConfig::default(),
        satsim_template.plan.clone(),
        4,
        1,
    )
    .unwrap();
    let satsim_server = StreamServer::spawn(satsim_factory, 1, 4);

    for (name, server) in [("golden", golden_server), ("satsim", satsim_server)] {
        let client = server.client();
        let seqs = [seq(24, 0), seq(18, 1), seq(21, 2), seq(24, 3)];
        let sessions: Vec<_> = (0..4).map(|_| client.open().expect("capacity 4")).collect();
        // uneven chunking: session i pushes i+1 frames per round
        let mut cursors = [0usize; 4];
        loop {
            let mut acks = Vec::new();
            for (i, sess) in sessions.iter().enumerate() {
                let end = (cursors[i] + i + 1).min(seqs[i].len());
                if cursors[i] < end {
                    acks.push(sess.push_frames_nowait(seqs[i][cursors[i]..end].to_vec()));
                    cursors[i] = end;
                }
            }
            if acks.is_empty() {
                break;
            }
            for rx in acks {
                rx.recv().expect("push must be acked");
            }
        }
        // mid-run logits poll on a live session is exactly the one-shot
        // logits of its pushed prefix
        let polled = sessions[1].logits().expect("poll must serve");
        let want_logits = match name {
            "golden" => {
                golden_ref.classify(&seqs[1]);
                golden_ref.logits()
            }
            _ => {
                satsim_ref.classify(&seqs[1]);
                satsim_ref.logits()
            }
        };
        assert_eq!(polled, want_logits, "{name}: polled logits diverged");
        for (i, sess) in sessions.into_iter().enumerate() {
            let label = sess.close().expect("close must serve");
            let want = match name {
                "golden" => golden_ref.classify(&seqs[i]),
                _ => satsim_ref.classify(&seqs[i]),
            };
            assert_eq!(label, want, "{name}: session {i} label diverged");
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.errors, 0, "{name}: no serving errors expected");
        assert!(metrics.items > 0, "{name}: push latencies recorded");
    }
}

#[test]
fn stream_server_rejects_busy_and_readmits_after_close() {
    let nw = synthetic_network(&[1, 8, 10], 3);
    let server = StreamServer::spawn(GoldenBackend::streaming_factory(nw, 2), 1, 2);
    let client = server.client();
    let a = client.open().unwrap();
    let b = client.open().unwrap();
    // capacity 1×2 exhausted: leader rejects without touching a worker
    match client.open() {
        Err(ServeError::Busy) => {}
        other => panic!("expected Busy, got {:?}", other.err()),
    }
    a.push_frames(seq(8, 0)).unwrap();
    a.close().unwrap();
    // the freed slot admits the next session
    let c = client.open().expect("slot freed by close");
    c.push_frames(seq(8, 1)).unwrap();
    c.close().unwrap();
    b.close().unwrap();
    let metrics = server.shutdown();
    assert_eq!(metrics.errors_busy, 1, "the rejection must be counted");
    assert_eq!(metrics.errors, 1);
}
