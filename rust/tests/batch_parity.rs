//! Batched-vs-sequential parity (PR 4 tentpole).
//!
//! The lockstep batch path must be **bit-identical** to sequential
//! serving: `classify_batch` over B sequences equals B sequential
//! `classify` calls, for the golden and the mixed-signal backends, under
//! full circuit noise. The per-slot RNG convention (every slot's noise
//! stream clones the core's construction stream — exactly what a fresh
//! sequential run replays) is what makes this exact rather than
//! statistical.
//!
//! Also here: the ragged-traffic end-to-end — a server with
//! `BatchPolicy::bucketed()` must only ever hand uniform-length batches
//! to the batched engine (asserted by a wrapper backend), and every
//! served label must equal the direct sequential reference.

use std::time::Duration;

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    Backend, BatchPolicy, GoldenBackend, MixedSignalBackend,
    MixedSignalEngine, Server,
};
use minimalist::montecarlo::instance_seed;
use minimalist::nn::{synthetic_network, GoldenNetwork};

/// Deterministic test load: `b` sequences of `t_len` frames of `d_in`.
fn make_seqs(b: usize, t_len: usize, d_in: usize, salt: usize) -> Vec<Vec<f32>> {
    (0..b)
        .map(|s| {
            (0..t_len * d_in)
                .map(|t| (((t + 1) * (s + 2) * (salt + 3)) % 7) as f32 / 6.0)
                .collect()
        })
        .collect()
}

/// Classify `seqs` sequentially and batched on two same-seed engines,
/// asserting label parity AND **bit-exact** logits parity per slot —
/// argmax alone could mask a small numeric divergence between the
/// sequential and lockstep traversals; exact f32 equality cannot.
fn assert_bitwise_parity(
    seq_engine: &mut MixedSignalEngine,
    bat_engine: &mut MixedSignalEngine,
    seqs: &[Vec<f32>],
    ctx: &str,
) {
    let mut want_labels = Vec::new();
    let mut want_logits = Vec::new();
    for s in seqs {
        want_labels.push(seq_engine.classify(s));
        want_logits.push(seq_engine.logits());
    }
    let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
    assert_eq!(
        bat_engine.classify_batch(&refs),
        want_labels,
        "{ctx}: lockstep labels diverged from sequential"
    );
    for (slot, want) in want_logits.iter().enumerate() {
        assert_eq!(
            &bat_engine.logits_slot(slot),
            want,
            "{ctx}: slot {slot} logits are not bit-identical to sequential"
        );
    }
}

#[test]
fn engine_batch_parity_unsplit_noisy() {
    // replicated narrow input layer (1 -> 24) under full noise,
    // B ∈ {1, 3, 8}
    for &b in &[1usize, 3, 8] {
        let nw = synthetic_network(&[1, 24, 10], 17);
        let mut seq_engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::default(),
            CoreGeometry { rows: 32, cols: 32 },
        )
        .unwrap();
        let mut bat_engine = seq_engine.replicate().unwrap();
        let seqs = make_seqs(b, 20, 1, b);
        assert_bitwise_parity(
            &mut seq_engine,
            &mut bat_engine,
            &seqs,
            &format!("unsplit B={b}"),
        );
    }
}

#[test]
fn engine_batch_parity_row_split_noisy() {
    // 40 inputs on 32-row cores -> 2 row tiles: the batched partial-sum
    // combine path, interleaving every slot's phases across tiles
    for &b in &[1usize, 3, 8] {
        let nw = synthetic_network(&[40, 8], 5);
        let mut seq_engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::default(),
            CoreGeometry { rows: 32, cols: 32 },
        )
        .unwrap();
        assert!(seq_engine.plan.layers[0].is_row_split());
        let mut bat_engine = seq_engine.replicate().unwrap();
        let seqs = make_seqs(b, 6, 40, b);
        assert_bitwise_parity(
            &mut seq_engine,
            &mut bat_engine,
            &seqs,
            &format!("row-split B={b}"),
        );
    }
}

#[test]
fn engine_batch_reuse_stays_consistent() {
    // growing, shrinking, and reusing the slot provisioning must not
    // leak state between batches
    let nw = synthetic_network(&[1, 16, 10], 23);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut bat_engine = seq_engine.replicate().unwrap();
    for &b in &[3usize, 8, 2, 8, 1] {
        let seqs = make_seqs(b, 12, 1, b);
        let want: Vec<usize> =
            seqs.iter().map(|s| seq_engine.classify(s)).collect();
        let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
        assert_eq!(bat_engine.classify_batch(&refs), want, "reuse at B={b}");
    }
}

#[test]
fn engine_streamed_slots_match_batch_and_sequential() {
    // the PR 5 extension of the invariant: the same sequences through
    // (a) sequential classify, (b) lockstep classify_batch, and (c) the
    // streaming slot-lease path advanced frame by frame — all three
    // bit-identical under full noise
    let nw = synthetic_network(&[1, 20, 10], 29);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 32, cols: 32 },
    )
    .unwrap();
    let mut bat_engine = seq_engine.replicate().unwrap();
    let mut stream_engine = seq_engine.replicate().unwrap();
    let seqs = make_seqs(3, 18, 1, 2);
    // (a) vs (b)
    assert_bitwise_parity(&mut seq_engine, &mut bat_engine, &seqs, "pr5");
    // (c): lease a slot per sequence and advance all three per tick
    stream_engine.provision_sessions(3);
    let slots: Vec<usize> = (0..3).map(|_| stream_engine.lease_slot().unwrap()).collect();
    for t in 0..18 {
        let frames: Vec<f32> = seqs.iter().map(|s| s[t]).collect();
        stream_engine.step_slots(&slots, &frames);
    }
    for (i, s) in seqs.iter().enumerate() {
        seq_engine.classify(s);
        assert_eq!(
            stream_engine.logits_slot(slots[i]),
            seq_engine.logits(),
            "streamed slot {i} diverged from sequential"
        );
    }
}

#[test]
fn delta_tiny_threshold_is_bit_identical_when_every_component_fires() {
    // PR 7: with a threshold far below the input quantization, every
    // component drifts past delta on every frame of an alternating
    // workload, so the masked share runs all-fired — which must be
    // bit-identical to the legacy path, for unsplit and row-split
    // placements alike. Single-layer nets keep the frames (which we
    // control) as the only layer input.
    for (dims, geometry, ctx) in [
        (
            vec![8usize, 10],
            CoreGeometry { rows: 8, cols: 16 },
            "unsplit",
        ),
        (
            vec![40usize, 8],
            CoreGeometry { rows: 32, cols: 32 },
            "row-split",
        ),
    ] {
        let nw = synthetic_network(&dims, 13);
        let mut exact =
            MixedSignalEngine::new(nw.clone(), CircuitConfig::default(), geometry)
                .unwrap();
        let mut delta = MixedSignalEngine::new(
            nw,
            CircuitConfig { delta: 1e-9, ..CircuitConfig::default() },
            geometry,
        )
        .unwrap();
        if ctx == "row-split" {
            assert!(exact.plan.layers[0].is_row_split());
        }
        let d_in = dims[0];
        // frame t flips every component: |Δx| = 1 ≫ 1e-9 each step
        let seqs: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                (0..20 * d_in)
                    .map(|k| (((k / d_in) + (k % d_in) + s) % 2) as f32)
                    .collect()
            })
            .collect();
        for s in &seqs {
            let want = exact.classify(s);
            assert_eq!(delta.classify(s), want, "{ctx}: labels diverged");
            assert_eq!(
                delta.logits(),
                exact.logits(),
                "{ctx}: delta=1e-9 logits are not bit-identical to the \
                 default path"
            );
        }
        let stats = delta.delta_stats();
        assert_eq!(
            stats.components_skipped, 0,
            "{ctx}: an always-moving workload must never skip"
        );
        assert!(stats.components_fired > 0);
    }
}

#[test]
fn delta_zero_config_is_the_default_path_bitwise() {
    // the gate itself: an explicit delta = 0.0 circuit must serve the
    // exact legacy computation — here proven across paths, comparing
    // default-config sequential logits against zero-delta lockstep
    // batch logits per slot, for unsplit and row-split placements
    for (dims, geometry, ctx) in [
        (
            vec![1usize, 16, 10],
            CoreGeometry { rows: 16, cols: 16 },
            "delta=0 unsplit",
        ),
        (
            vec![40usize, 8],
            CoreGeometry { rows: 32, cols: 32 },
            "delta=0 row-split",
        ),
    ] {
        let nw = synthetic_network(&dims, 19);
        let mut default_seq =
            MixedSignalEngine::new(nw.clone(), CircuitConfig::default(), geometry)
                .unwrap();
        let mut zero_bat = MixedSignalEngine::new(
            nw,
            CircuitConfig { delta: 0.0, ..CircuitConfig::default() },
            geometry,
        )
        .unwrap();
        let seqs = make_seqs(3, 10, dims[0], 5);
        assert_bitwise_parity(&mut default_seq, &mut zero_bat, &seqs, ctx);
        let stats = zero_bat.delta_stats();
        assert_eq!(
            stats.components_fired + stats.components_skipped,
            0,
            "{ctx}: delta = 0 must not engage the tracking machinery"
        );
    }
}

#[test]
fn delta_path_parity_holds_across_serving_paths() {
    // nonzero threshold: the skipping computation itself must be
    // deterministic and identical through sequential classify, the
    // lockstep batch path, and the streamed slot-lease path — each
    // slot tracks its own x_last, so the three traversals replay the
    // same skip decisions and the same draws
    let nw = synthetic_network(&[1, 20, 10], 29);
    let circuit = CircuitConfig { delta: 0.05, ..CircuitConfig::default() };
    let mut seq_engine =
        MixedSignalEngine::new(nw, circuit, CoreGeometry { rows: 32, cols: 32 })
            .unwrap();
    let mut bat_engine = seq_engine.replicate().unwrap();
    let mut stream_engine = seq_engine.replicate().unwrap();
    let seqs = make_seqs(3, 18, 1, 4);
    assert_bitwise_parity(&mut seq_engine, &mut bat_engine, &seqs, "delta=0.05");
    stream_engine.provision_sessions(3);
    let slots: Vec<usize> =
        (0..3).map(|_| stream_engine.lease_slot().unwrap()).collect();
    for t in 0..18 {
        let frames: Vec<f32> = seqs.iter().map(|s| s[t]).collect();
        stream_engine.step_slots(&slots, &frames);
    }
    for (i, s) in seqs.iter().enumerate() {
        seq_engine.classify(s);
        assert_eq!(
            stream_engine.logits_slot(slots[i]),
            seq_engine.logits(),
            "streamed slot {i} diverged from sequential on the delta path"
        );
    }
    // the parity only means something if the threshold engaged
    assert!(
        seq_engine.delta_stats().components_skipped > 0,
        "delta = 0.05 never skipped on this workload"
    );
}

#[test]
fn mixed_device_batch_slots_are_independent_devices() {
    // ADR-008 opt-in: with provisioned per-slot devices, every lane of
    // the lockstep batch is a *different fabricated chip*. Three checks:
    // (a) slot s is bit-identical to a whole fresh engine built with
    //     `instance_seed(master, s)` as its circuit seed;
    // (b) the instances are actually distinct hardware (their logits on
    //     a shared input do not all coincide);
    // (c) changing every *other* lane's input leaves slot s's logits
    //     bit-unchanged — no cross-slot coupling through the shared
    //     arrays, even though the slots now hold different capacitor
    //     mismatch and ADC calibration.
    let nw = synthetic_network(&[1, 16, 10], 37);
    let geometry = CoreGeometry { rows: 16, cols: 16 };
    let master = 0xDEC0DE;
    let mut mc =
        MixedSignalEngine::new(nw.clone(), CircuitConfig::default(), geometry)
            .unwrap();
    mc.provision_devices(master, 4);
    let shared = make_seqs(1, 12, 1, 3).remove(0);
    let refs: Vec<&[f32]> = (0..4).map(|_| shared.as_slice()).collect();
    mc.classify_batch(&refs);
    let logits: Vec<Vec<f32>> = (0..4).map(|s| mc.logits_slot(s)).collect();
    // (a)
    for (s, want) in logits.iter().enumerate() {
        let cfg = CircuitConfig {
            seed: instance_seed(master, s),
            ..CircuitConfig::default()
        };
        let mut fresh = MixedSignalEngine::new(nw.clone(), cfg, geometry).unwrap();
        fresh.classify(&shared);
        assert_eq!(
            &fresh.logits(),
            want,
            "slot {s} is not bit-identical to the instance-seed device"
        );
    }
    // (b)
    assert!(
        logits.windows(2).any(|w| w[0] != w[1]),
        "4 device instances produced identical logits on a shared input"
    );
    // (c)
    let varied = make_seqs(4, 12, 1, 9);
    for (s, want) in logits.iter().enumerate() {
        let mut batch: Vec<&[f32]> =
            varied.iter().map(|v| v.as_slice()).collect();
        batch[s] = shared.as_slice();
        mc.classify_batch(&batch);
        assert_eq!(
            &mc.logits_slot(s),
            want,
            "slot {s}'s device coupled to its neighbors' inputs"
        );
    }
}

#[test]
fn golden_backend_batch_matches_sequential() {
    let nw = synthetic_network(&[1, 12, 10], 9);
    let mut a = GoldenBackend::new(GoldenNetwork::new(nw.clone()));
    let mut b = GoldenBackend::new(GoldenNetwork::new(nw));
    for &n in &[1usize, 3, 8] {
        let seqs = make_seqs(n, 16, 1, n);
        let want: Vec<usize> = seqs
            .iter()
            .map(|s| a.classify_batch(std::slice::from_ref(s))[0])
            .collect();
        assert_eq!(b.classify_batch(&seqs), want, "B={n}");
    }
}

#[test]
fn mixed_signal_backend_batch_matches_sequential_even_ragged() {
    let nw = synthetic_network(&[1, 16, 10], 31);
    let engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut reference = MixedSignalBackend::new(engine.replicate().unwrap());
    let mut backend = MixedSignalBackend::new(engine);
    // ragged: three different lengths, interleaved
    let seqs: Vec<Vec<f32>> = [16usize, 24, 16, 8, 24, 8]
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            (0..n).map(|t| (((t + 2) * (i + 3)) % 5) as f32 / 4.0).collect()
        })
        .collect();
    let want: Vec<usize> = seqs
        .iter()
        .map(|s| reference.classify_batch(std::slice::from_ref(s))[0])
        .collect();
    assert_eq!(backend.classify_batch(&seqs), want);
}

/// Wrapper proving what the server hands the batched engine: panics on
/// any ragged batch (surfacing as `BackendPanicked` error responses),
/// delegates to the real mixed-signal backend otherwise.
struct AssertUniform(MixedSignalBackend);

impl Backend for AssertUniform {
    fn name(&self) -> &str {
        "assert-uniform-satsim"
    }

    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
        let len0 = seqs.first().map(|s| s.len()).unwrap_or(0);
        assert!(
            seqs.iter().all(|s| s.len() == len0),
            "bucketed policy leaked a ragged batch to the batched engine"
        );
        self.0.classify_batch(seqs)
    }
}

#[test]
fn bucketed_server_feeds_uniform_batches_and_matches_sequential() {
    let nw = synthetic_network(&[1, 12, 10], 41);
    let template = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut reference = template.replicate().unwrap();
    let engine = template.replicate().unwrap();
    // ragged traffic: two sequence lengths interleaved within one batch
    // window, so an unbucketed drain would be ragged
    let seqs: Vec<Vec<f32>> = (0..12)
        .map(|i| {
            let n = if i % 2 == 0 { 16 } else { 24 };
            (0..n).map(|t| (((t + 1) * (i + 2)) % 7) as f32 / 6.0).collect()
        })
        .collect();
    let want: Vec<usize> = seqs.iter().map(|s| reference.classify(s)).collect();
    let server = Server::spawn_with(
        move || {
            Box::new(AssertUniform(MixedSignalBackend::new(engine))) as _
        },
        BatchPolicy::new(4, Duration::from_millis(2)).bucketed(),
    );
    let client = server.client();
    let rxs: Vec<_> = seqs
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.clone()))
        .collect();
    for (rx, want) in rxs.into_iter().zip(want) {
        let resp = rx.recv().unwrap();
        assert_eq!(
            resp.result,
            Ok(want),
            "ragged traffic through the bucketed batched path must serve \
             the sequential labels"
        );
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.items, 12);
    assert_eq!(metrics.errors, 0);
}
