//! Batched-vs-sequential parity (PR 4 tentpole).
//!
//! The lockstep batch path must be **bit-identical** to sequential
//! serving: `classify_batch` over B sequences equals B sequential
//! `classify` calls, for the golden and the mixed-signal backends, under
//! full circuit noise. The per-slot RNG convention (every slot's noise
//! stream clones the core's construction stream — exactly what a fresh
//! sequential run replays) is what makes this exact rather than
//! statistical.
//!
//! Also here: the ragged-traffic end-to-end — a server with
//! `BatchPolicy::bucketed()` must only ever hand uniform-length batches
//! to the batched engine (asserted by a wrapper backend), and every
//! served label must equal the direct sequential reference.

use std::time::Duration;

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    Backend, BatchPolicy, GoldenBackend, MixedSignalBackend,
    MixedSignalEngine, Server,
};
use minimalist::nn::{synthetic_network, GoldenNetwork};

/// Deterministic test load: `b` sequences of `t_len` frames of `d_in`.
fn make_seqs(b: usize, t_len: usize, d_in: usize, salt: usize) -> Vec<Vec<f32>> {
    (0..b)
        .map(|s| {
            (0..t_len * d_in)
                .map(|t| (((t + 1) * (s + 2) * (salt + 3)) % 7) as f32 / 6.0)
                .collect()
        })
        .collect()
}

/// Classify `seqs` sequentially and batched on two same-seed engines,
/// asserting label parity AND **bit-exact** logits parity per slot —
/// argmax alone could mask a small numeric divergence between the
/// sequential and lockstep traversals; exact f32 equality cannot.
fn assert_bitwise_parity(
    seq_engine: &mut MixedSignalEngine,
    bat_engine: &mut MixedSignalEngine,
    seqs: &[Vec<f32>],
    ctx: &str,
) {
    let mut want_labels = Vec::new();
    let mut want_logits = Vec::new();
    for s in seqs {
        want_labels.push(seq_engine.classify(s));
        want_logits.push(seq_engine.logits());
    }
    let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
    assert_eq!(
        bat_engine.classify_batch(&refs),
        want_labels,
        "{ctx}: lockstep labels diverged from sequential"
    );
    for (slot, want) in want_logits.iter().enumerate() {
        assert_eq!(
            &bat_engine.logits_slot(slot),
            want,
            "{ctx}: slot {slot} logits are not bit-identical to sequential"
        );
    }
}

#[test]
fn engine_batch_parity_unsplit_noisy() {
    // replicated narrow input layer (1 -> 24) under full noise,
    // B ∈ {1, 3, 8}
    for &b in &[1usize, 3, 8] {
        let nw = synthetic_network(&[1, 24, 10], 17);
        let mut seq_engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::default(),
            CoreGeometry { rows: 32, cols: 32 },
        )
        .unwrap();
        let mut bat_engine = seq_engine.replicate().unwrap();
        let seqs = make_seqs(b, 20, 1, b);
        assert_bitwise_parity(
            &mut seq_engine,
            &mut bat_engine,
            &seqs,
            &format!("unsplit B={b}"),
        );
    }
}

#[test]
fn engine_batch_parity_row_split_noisy() {
    // 40 inputs on 32-row cores -> 2 row tiles: the batched partial-sum
    // combine path, interleaving every slot's phases across tiles
    for &b in &[1usize, 3, 8] {
        let nw = synthetic_network(&[40, 8], 5);
        let mut seq_engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::default(),
            CoreGeometry { rows: 32, cols: 32 },
        )
        .unwrap();
        assert!(seq_engine.plan.layers[0].is_row_split());
        let mut bat_engine = seq_engine.replicate().unwrap();
        let seqs = make_seqs(b, 6, 40, b);
        assert_bitwise_parity(
            &mut seq_engine,
            &mut bat_engine,
            &seqs,
            &format!("row-split B={b}"),
        );
    }
}

#[test]
fn engine_batch_reuse_stays_consistent() {
    // growing, shrinking, and reusing the slot provisioning must not
    // leak state between batches
    let nw = synthetic_network(&[1, 16, 10], 23);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut bat_engine = seq_engine.replicate().unwrap();
    for &b in &[3usize, 8, 2, 8, 1] {
        let seqs = make_seqs(b, 12, 1, b);
        let want: Vec<usize> =
            seqs.iter().map(|s| seq_engine.classify(s)).collect();
        let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
        assert_eq!(bat_engine.classify_batch(&refs), want, "reuse at B={b}");
    }
}

#[test]
fn engine_streamed_slots_match_batch_and_sequential() {
    // the PR 5 extension of the invariant: the same sequences through
    // (a) sequential classify, (b) lockstep classify_batch, and (c) the
    // streaming slot-lease path advanced frame by frame — all three
    // bit-identical under full noise
    let nw = synthetic_network(&[1, 20, 10], 29);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 32, cols: 32 },
    )
    .unwrap();
    let mut bat_engine = seq_engine.replicate().unwrap();
    let mut stream_engine = seq_engine.replicate().unwrap();
    let seqs = make_seqs(3, 18, 1, 2);
    // (a) vs (b)
    assert_bitwise_parity(&mut seq_engine, &mut bat_engine, &seqs, "pr5");
    // (c): lease a slot per sequence and advance all three per tick
    stream_engine.provision_sessions(3);
    let slots: Vec<usize> = (0..3).map(|_| stream_engine.lease_slot().unwrap()).collect();
    for t in 0..18 {
        let frames: Vec<f32> = seqs.iter().map(|s| s[t]).collect();
        stream_engine.step_slots(&slots, &frames);
    }
    for (i, s) in seqs.iter().enumerate() {
        seq_engine.classify(s);
        assert_eq!(
            stream_engine.logits_slot(slots[i]),
            seq_engine.logits(),
            "streamed slot {i} diverged from sequential"
        );
    }
}

#[test]
fn golden_backend_batch_matches_sequential() {
    let nw = synthetic_network(&[1, 12, 10], 9);
    let mut a = GoldenBackend::new(GoldenNetwork::new(nw.clone()));
    let mut b = GoldenBackend::new(GoldenNetwork::new(nw));
    for &n in &[1usize, 3, 8] {
        let seqs = make_seqs(n, 16, 1, n);
        let want: Vec<usize> = seqs
            .iter()
            .map(|s| a.classify_batch(std::slice::from_ref(s))[0])
            .collect();
        assert_eq!(b.classify_batch(&seqs), want, "B={n}");
    }
}

#[test]
fn mixed_signal_backend_batch_matches_sequential_even_ragged() {
    let nw = synthetic_network(&[1, 16, 10], 31);
    let engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut reference = MixedSignalBackend::new(engine.replicate().unwrap());
    let mut backend = MixedSignalBackend::new(engine);
    // ragged: three different lengths, interleaved
    let seqs: Vec<Vec<f32>> = [16usize, 24, 16, 8, 24, 8]
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            (0..n).map(|t| (((t + 2) * (i + 3)) % 5) as f32 / 4.0).collect()
        })
        .collect();
    let want: Vec<usize> = seqs
        .iter()
        .map(|s| reference.classify_batch(std::slice::from_ref(s))[0])
        .collect();
    assert_eq!(backend.classify_batch(&seqs), want);
}

/// Wrapper proving what the server hands the batched engine: panics on
/// any ragged batch (surfacing as `BackendPanicked` error responses),
/// delegates to the real mixed-signal backend otherwise.
struct AssertUniform(MixedSignalBackend);

impl Backend for AssertUniform {
    fn name(&self) -> &str {
        "assert-uniform-satsim"
    }

    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
        let len0 = seqs.first().map(|s| s.len()).unwrap_or(0);
        assert!(
            seqs.iter().all(|s| s.len() == len0),
            "bucketed policy leaked a ragged batch to the batched engine"
        );
        self.0.classify_batch(seqs)
    }
}

#[test]
fn bucketed_server_feeds_uniform_batches_and_matches_sequential() {
    let nw = synthetic_network(&[1, 12, 10], 41);
    let template = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut reference = template.replicate().unwrap();
    let engine = template.replicate().unwrap();
    // ragged traffic: two sequence lengths interleaved within one batch
    // window, so an unbucketed drain would be ragged
    let seqs: Vec<Vec<f32>> = (0..12)
        .map(|i| {
            let n = if i % 2 == 0 { 16 } else { 24 };
            (0..n).map(|t| (((t + 1) * (i + 2)) % 7) as f32 / 6.0).collect()
        })
        .collect();
    let want: Vec<usize> = seqs.iter().map(|s| reference.classify(s)).collect();
    let server = Server::spawn_with(
        move || {
            Box::new(AssertUniform(MixedSignalBackend::new(engine))) as _
        },
        BatchPolicy::new(4, Duration::from_millis(2)).bucketed(),
    );
    let client = server.client();
    let rxs: Vec<_> = seqs
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.clone()))
        .collect();
    for (rx, want) in rxs.into_iter().zip(want) {
        let resp = rx.recv().unwrap();
        assert_eq!(
            resp.result,
            Ok(want),
            "ragged traffic through the bucketed batched path must serve \
             the sequential labels"
        );
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.items, 12);
    assert_eq!(metrics.errors, 0);
}
