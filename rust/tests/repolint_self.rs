//! Self-test of the repolint passes (ADR-006).
//!
//! Two obligations, both load-bearing: every rule must be **clean over
//! the real repository tree** (this is the same scan the blocking CI
//! `lint` job runs via the `repolint` binary), and every rule must
//! **fire on an embedded bad fixture** — exactly once, with a
//! `file:line: [rule-id]` prefixed message — so a refactor that
//! silently neuters a pass fails here instead of letting violations
//! through unreported.

use std::fs;
use std::path::{Path, PathBuf};

use minimalist::lint::LintTree;

/// The repo root: the parent of the `rust/` crate directory.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("the rust/ crate dir sits inside the repo root")
        .to_path_buf()
}

/// Run all passes over an in-memory fixture tree, returning rendered
/// violation strings.
fn run(entries: &[(&str, &str)]) -> Vec<String> {
    LintTree::from_memory(entries)
        .run_all()
        .iter()
        .map(|v| v.to_string())
        .collect()
}

/// Assert the fixture produces exactly one violation, anchored at
/// `file:line:` and tagged with `[rule]`.
fn fire_once(entries: &[(&str, &str)], rule: &str, at: &str) -> String {
    let v = run(entries);
    assert_eq!(
        v.len(),
        1,
        "expected exactly one [{rule}] violation, got {}: {v:#?}",
        v.len()
    );
    assert!(
        v[0].starts_with(at),
        "violation should be anchored at `{at}`: {}",
        v[0]
    );
    assert!(
        v[0].contains(&format!("[{rule}]")),
        "violation should carry rule id [{rule}]: {}",
        v[0]
    );
    v[0].clone()
}

// ---------------------------------------------------------------- real tree

#[test]
fn real_tree_is_clean() {
    let tree = LintTree::load(&repo_root()).expect("scanning the repo tree");
    assert!(
        tree.len() > 40,
        "suspiciously few files scanned ({}) — did the walker lose a dir?",
        tree.len()
    );
    let v = tree.run_all();
    assert!(
        v.is_empty(),
        "repolint violations in the real tree:\n{}",
        v.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
    );
}

/// The acceptance-critical direction of `rng-discipline`: stripping a
/// real `rng-draws` annotation from the real `satsim/column.rs` must
/// make the pass fire. This guards the ADR-005 draw-burn pairing —
/// `skip_share` must keep declaring the draws `phase_share` consumes.
#[test]
fn removing_a_real_rng_annotation_fires() {
    let path = repo_root().join("rust/src/satsim/column.rs");
    let src = fs::read_to_string(&path).expect("reading satsim/column.rs");
    let marker = "// lint: rng-draws(2, column-share)";
    assert!(
        src.matches(marker).count() >= 3,
        "expected the three column-share annotations in satsim/column.rs"
    );
    // Strip only the LAST annotation (the one above `skip_share`) so
    // the group still has a reference count to diff against.
    let last = src.rfind(marker).unwrap();
    let stripped = format!("{}{}", &src[..last], &src[last + marker.len()..]);
    let tree =
        LintTree::from_memory(&[("rust/src/satsim/column.rs", stripped.as_str())]);
    let v = tree.run_all();
    let rendered: Vec<String> = v.iter().map(|v| v.to_string()).collect();
    assert_eq!(v.len(), 1, "{rendered:#?}");
    assert_eq!(v[0].rule, "rng-discipline");
    assert!(
        v[0].msg.contains("skip_share"),
        "should name the de-annotated fn: {}",
        v[0]
    );
}

// ------------------------------------------------------------ bad fixtures

#[test]
fn alloc_discipline_fires_on_unannotated_push() {
    let msg = fire_once(
        &[(
            "rust/src/router/event.rs",
            "pub fn delta_encode(out: &mut Vec<u8>) {\n    out.push(1);\n}\n",
        )],
        "alloc-discipline",
        "rust/src/router/event.rs:2:",
    );
    assert!(msg.contains(".push("), "should name the token: {msg}");
}

#[test]
fn alloc_discipline_honors_a_reasoned_allow() {
    let clean = run(&[(
        "rust/src/router/event.rs",
        "pub fn delta_encode(out: &mut Vec<u8>) {\n    \
         out.push(1); // lint: allow(alloc, caller-owned buffer)\n}\n",
    )]);
    assert!(clean.is_empty(), "reasoned allow should exempt: {clean:#?}");
    // An allow without a reason does not parse and does not exempt.
    let v = run(&[(
        "rust/src/router/event.rs",
        "pub fn delta_encode(out: &mut Vec<u8>) {\n    \
         out.push(1); // lint: allow(alloc)\n}\n",
    )]);
    assert_eq!(v.len(), 1, "reasonless allow must not exempt: {v:#?}");
}

#[test]
fn rng_discipline_fires_on_count_mismatch() {
    let msg = fire_once(
        &[(
            "rust/src/satsim/column.rs",
            "// lint: rng-draws(2, column-share)\n\
             pub fn phase_share(&mut self) {}\n\
             // lint: rng-draws(1, column-share)\n\
             pub fn skip_share(&mut self) {}\n",
        )],
        "rng-discipline",
        "rust/src/satsim/column.rs:3:",
    );
    assert!(msg.contains("skip_share") && msg.contains("phase_share"), "{msg}");
}

#[test]
fn rng_discipline_fires_when_either_annotation_is_removed() {
    // skip path de-annotated
    fire_once(
        &[(
            "rust/src/satsim/column.rs",
            "// lint: rng-draws(2, column-share)\n\
             pub fn phase_share(&mut self) {}\n\
             pub fn skip_share(&mut self) {}\n",
        )],
        "rng-discipline",
        "rust/src/satsim/column.rs:3:",
    );
    // full path de-annotated
    fire_once(
        &[(
            "rust/src/satsim/column.rs",
            "pub fn phase_share(&mut self) {}\n\
             // lint: rng-draws(2, column-share)\n\
             pub fn skip_share(&mut self) {}\n",
        )],
        "rng-discipline",
        "rust/src/satsim/column.rs:1:",
    );
}

#[test]
fn exhaustive_status_fires_on_missing_arm() {
    let server = "\
pub enum ServeError {
    Busy,
    Lost,
    Gone,
}
";
    let http = "\
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::Busy => 429,
        ServeError::Lost => 503,
    }
}
";
    // Docs mention every variant, so only the missing arm fires.
    let docs = "Busy (429), Lost (503), Gone (410).\n";
    let msg = fire_once(
        &[
            ("rust/src/coordinator/server.rs", server),
            ("rust/src/coordinator/http.rs", http),
            ("docs/http-api.md", docs),
        ],
        "exhaustive-status",
        "rust/src/coordinator/server.rs:4:",
    );
    assert!(msg.contains("ServeError::Gone") && msg.contains("status_for"), "{msg}");
}

#[test]
fn exhaustive_metrics_fires_on_undocumented_family() {
    let msg = fire_once(
        &[
            (
                "rust/src/coordinator/http.rs",
                "fn render() -> String {\n    \
                 String::from(\"minimalist_bogus_total 1\\n\")\n}\n",
            ),
            ("docs/http-api.md", "no metrics documented here\n"),
        ],
        "exhaustive-metrics",
        "rust/src/coordinator/http.rs:2:",
    );
    assert!(msg.contains("minimalist_bogus_total"), "{msg}");
}

#[test]
fn exhaustive_schema_fires_on_unmentioned_bump() {
    let msg = fire_once(
        &[
            (
                "rust/src/bench_suite.rs",
                "fn report() { let _ = (\"schema\", 9usize); }\n",
            ),
            ("README.md", "mentions schema 8 only\n"),
        ],
        "exhaustive-schema",
        "rust/src/bench_suite.rs:1:",
    );
    assert!(msg.contains("schema 9"), "{msg}");
}

#[test]
fn exhaustive_adr_fires_on_missing_index_row() {
    let msg = fire_once(
        &[
            ("docs/adr/007-new-thing.md", "# ADR 7\n"),
            ("docs/adr/README.md", "| [006](006-old.md) | old | Accepted |\n"),
        ],
        "exhaustive-adr",
        "docs/adr/007-new-thing.md:1:",
    );
    assert!(msg.contains("007-new-thing.md"), "{msg}");
}

#[test]
fn panic_hygiene_fires_on_unannotated_unwrap() {
    let msg = fire_once(
        &[(
            "rust/src/coordinator/loadgen.rs",
            "pub fn drive(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
        )],
        "panic-hygiene",
        "rust/src/coordinator/loadgen.rs:2:",
    );
    assert!(msg.contains(".unwrap()"), "{msg}");
}

#[test]
fn unsafe_safety_fires_on_uncommented_unsafe() {
    let msg = fire_once(
        &[(
            "rust/src/util/raw.rs",
            "pub unsafe fn poke(p: *mut u8) {\n    *p = 0;\n}\n",
        )],
        "unsafe-safety",
        "rust/src/util/raw.rs:1:",
    );
    assert!(msg.contains("SAFETY"), "{msg}");
}
