//! The zero-allocation invariant of the satsim hot path (PR 3 tentpole,
//! extended to the lockstep batch path in PR 4): after a warmup sequence
//! has grown every scratch buffer to its steady state,
//! `MixedSignalEngine::step` **and** `MixedSignalEngine::step_batch`
//! must perform **zero** heap allocations — for unsplit (including
//! row-replicated) plans and for row-split plans alike. Batch
//! boundaries (`reset_batch` with a new size) may allocate; steps may
//! not.
//!
//! Mechanism: a counting `#[global_allocator]` wrapping the system
//! allocator. Everything runs inside a single `#[test]` so no
//! concurrently running test can pollute the counter (each integration
//! test file is its own binary, and this one contains exactly one test).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::nn::synthetic_network;

struct CountingAllocator;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

// SAFETY: pure pass-through to the System allocator — every method
// forwards its exact arguments and returns the System result, adding
// only a relaxed counter bump, so System's safety contract carries
// over unchanged.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: caller upholds GlobalAlloc's contract; delegates to System.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: caller upholds GlobalAlloc's contract; delegates to System.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller upholds GlobalAlloc's contract; delegates to System.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller upholds GlobalAlloc's contract; delegates to System.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn allocations() -> usize {
    ALLOCATIONS.load(Ordering::SeqCst)
}

/// Warm an engine up (buffers grow to steady state), then count heap
/// allocations over a window of steady-state steps — must be zero.
fn assert_zero_alloc_steps(engine: &mut MixedSignalEngine, d_in: usize, label: &str) {
    let x: Vec<f32> = (0..d_in).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();
    engine.reset();
    for t in 0..16u32 {
        engine.step(t, &x, None);
    }
    let before = allocations();
    for t in 16..48u32 {
        engine.step(t, &x, None);
    }
    let n = allocations() - before;
    assert_eq!(
        n, 0,
        "{label}: {n} heap allocation(s) over 32 steady-state steps \
         (the hot path must be allocation-free)"
    );
}

/// Same invariant for the lockstep batch path: provision `b` slots
/// (allocation allowed here — a batch boundary), warm up, then assert
/// zero allocations over a window of steady-state batched steps.
fn assert_zero_alloc_batch_steps(
    engine: &mut MixedSignalEngine,
    d_in: usize,
    b: usize,
    label: &str,
) {
    let xs: Vec<f32> =
        (0..b * d_in).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();
    engine.reset_batch(b);
    for t in 0..16u32 {
        engine.step_batch(t, &xs);
    }
    let before = allocations();
    for t in 16..48u32 {
        engine.step_batch(t, &xs);
    }
    let n = allocations() - before;
    assert_eq!(
        n, 0,
        "{label}: {n} heap allocation(s) over 32 steady-state batched \
         steps at B={b} (the lockstep path must be allocation-free)"
    );
}

#[test]
fn engine_step_is_allocation_free_after_warmup() {
    // the counter counts — construction alone must register
    let base = allocations();

    // unsplit plan with row replication: 1→32→10 on 64×64 cores (the
    // 1-wide input layer replicates 64×, exercising the x_rep scratch)
    let nw = synthetic_network(&[1, 32, 10], 11);
    let mut unsplit = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 64, cols: 64 },
    )
    .unwrap();
    assert!(allocations() > base, "allocation counter is not counting");
    assert_zero_alloc_steps(&mut unsplit, 1, "unsplit/replicated");
    // the same engine's lockstep batch path, after a B=8 batch boundary
    assert_zero_alloc_batch_steps(&mut unsplit, 1, 8, "unsplit/replicated");
    // and the sequential path again on the multi-slot engine (slot 0)
    assert_zero_alloc_steps(&mut unsplit, 1, "unsplit/multi-slot seq");

    // row-split plan: 100 inputs on 64-row cores → 2 row tiles, the
    // weighted partial-sum combine path
    let nw = synthetic_network(&[100, 8], 3);
    let mut split = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 64, cols: 64 },
    )
    .unwrap();
    assert!(split.plan.layers[0].is_row_split());
    assert_zero_alloc_steps(&mut split, 100, "row-split");
    assert_zero_alloc_batch_steps(&mut split, 100, 4, "row-split");

    // delta-sparsity engines (ADR-005): the per-slot x_last tracker and
    // the fired/x_eff scratch must respect the invariant too. The
    // constant steady-state input sends every component quiescent after
    // its first step, so the counted window runs the whole-column skip
    // path — the fast path itself must also be allocation-free.
    let nw = synthetic_network(&[1, 32, 10], 11);
    let mut delta_unsplit = MixedSignalEngine::new(
        nw,
        CircuitConfig { delta: 0.25, ..CircuitConfig::default() },
        CoreGeometry { rows: 64, cols: 64 },
    )
    .unwrap();
    assert_zero_alloc_steps(&mut delta_unsplit, 1, "delta/unsplit");
    assert_zero_alloc_batch_steps(&mut delta_unsplit, 1, 8, "delta/unsplit");
    assert!(
        delta_unsplit.delta_stats().components_skipped > 0,
        "the constant workload must have exercised the skip path"
    );

    let nw = synthetic_network(&[100, 8], 3);
    let mut delta_split = MixedSignalEngine::new(
        nw,
        CircuitConfig { delta: 0.25, ..CircuitConfig::default() },
        CoreGeometry { rows: 64, cols: 64 },
    )
    .unwrap();
    assert!(delta_split.plan.layers[0].is_row_split());
    assert_zero_alloc_steps(&mut delta_split, 100, "delta/row-split");
    assert_zero_alloc_batch_steps(&mut delta_split, 100, 4, "delta/row-split");

    // threaded plan traversal (ADR-007): with the scoped pool active the
    // steady-state invariant must hold unchanged — the pool allocates at
    // construction (set_engine_threads, a batch-boundary event) and its
    // per-step dispatch is a mutex handshake plus an atomic cursor, so
    // the counted window still sees zero. Covers both traversal shapes:
    // the per-tile fan-out (unsplit) and the partial/combine split
    // (row-split), staging buffers included.
    unsplit.set_engine_threads(2);
    assert_eq!(unsplit.engine_threads(), 2);
    assert_zero_alloc_batch_steps(&mut unsplit, 1, 8, "threaded/unsplit");
    split.set_engine_threads(2);
    assert_zero_alloc_batch_steps(&mut split, 100, 4, "threaded/row-split");
}
