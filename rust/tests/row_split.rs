//! Row-split (tiled) mapping + short-sequence readout, end to end:
//! networks whose input dims exceed the core rows must plan, build, and
//! track the golden model on the physics path, and both models must
//! normalize the readout by the steps actually seen.

use minimalist::config::{CircuitConfig, CoreGeometry, MappingConfig};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::mapping::Plan;
use minimalist::nn::{synthetic_network, GoldenNetwork};
use minimalist::quant::codesign::snap_network;

#[test]
fn multi_layer_row_split_network_plans_and_serves() {
    // 100-80-10 on 48x48 cores: both weight layers row-split, the first
    // also column-splits. The engine must construct and classify.
    let geometry = CoreGeometry { rows: 48, cols: 48 };
    let nw = synthetic_network(&[100, 80, 10], 21);
    let plan = Plan::build(&nw.dims, &MappingConfig::with_geometry(geometry)).unwrap();
    assert!(plan.layers[0].is_row_split());
    assert_eq!(plan.layers[0].col_tiles, 2);
    assert!(plan.layers[1].is_row_split());
    let mut e = MixedSignalEngine::new(nw, CircuitConfig::ideal(), geometry).unwrap();
    assert_eq!(e.n_cores(), plan.n_cores);
    let seq: Vec<f32> =
        (0..100 * 10).map(|i| ((i * 3) % 7) as f32 / 6.0).collect();
    let a = e.classify(&seq);
    assert_eq!(a, e.classify(&seq));
    // real, finite head activity — not a silent all-zero path
    let logits = e.logits();
    assert!(logits.iter().all(|l| l.is_finite()));
    let bh = &e.weights.layers.last().unwrap().bh;
    assert!(
        logits.iter().zip(bh.iter()).any(|(l, b)| (l - b).abs() > 1e-4),
        "head states never moved off the bias"
    );
}

#[test]
fn row_split_engine_matches_golden_on_deployed_parameters() {
    // Fig-4-style parity with a forced row split: snap the network to
    // the realizable gate slope, then the ideal circuit must track the
    // golden model's readout within swap granularity on every sequence
    // (argmax agreement is tie-sensitive, so compare logits directly —
    // same form as tests/trace_parity.rs).
    let raw = synthetic_network(&[100, 8], 1);
    let nw = snap_network(&raw, &CircuitConfig::ideal(), 64).unwrap();
    let geometry = CoreGeometry { rows: 64, cols: 64 };
    let mut engine =
        MixedSignalEngine::new(nw.clone(), CircuitConfig::ideal(), geometry).unwrap();
    assert!(engine.plan.layers[0].is_row_split());
    let mut golden = GoldenNetwork::new(nw);

    let mut worst = 0.0f32;
    for trial in 0..4usize {
        let seq: Vec<f32> = (0..100 * 16)
            .map(|i| ((i * (3 + trial)) % 11) as f32 / 10.0)
            .collect();
        let sim = engine.classify(&seq);
        let gold = golden.classify(&seq);
        for (a, b) in engine.logits().iter().zip(golden.logits().iter()) {
            worst = worst.max((a - b).abs());
        }
        eprintln!("trial {trial}: class sim={sim} gold={gold}");
    }
    assert!(worst < 0.25, "row-split readout drifted: worst |Δlogit| = {worst}");
}

#[test]
fn short_sequence_readout_averages_only_seen_steps_in_both_models() {
    // The shared readout-normalization test: for a sequence shorter
    // than READOUT_STEPS, both GoldenNetwork::logits and
    // MixedSignalEngine::logits must equal mean(head states seen) +
    // bias — dividing by min(steps_seen, READOUT_STEPS), not by the
    // full ring length (the old zero-padding bias scaled both by 3/8).
    let nw = synthetic_network(&[1, 16, 10], 5);
    let seq = [0.9f32, 0.1, 0.7]; // 3 steps < READOUT_STEPS = 8
    let bias: Vec<f32> = nw.layers.last().unwrap().bh.clone();

    // golden: logits == mean of the 3 head states + bias
    let mut golden = GoldenNetwork::new(nw.clone());
    golden.reset();
    let mut g_sum = vec![0.0f32; 10];
    for &x in &seq {
        golden.step(&[x], None);
        let head = &golden.states[golden.weights.n_layers() - 1].h;
        for (s, &h) in g_sum.iter_mut().zip(head.iter()) {
            *s += h;
        }
    }
    for (j, &l) in golden.logits().iter().enumerate() {
        let expect = g_sum[j] / 3.0 + bias[j];
        assert!((l - expect).abs() < 1e-6, "golden logit {j}: {l} vs {expect}");
    }

    // engine: same property, head states taken from the traces
    let mut engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::ideal(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    engine.reset();
    let mut traces = Vec::new();
    for (t, &x) in seq.iter().enumerate() {
        engine.step(t as u32, &[x], Some(&mut traces));
    }
    let head_traces = &traces[traces.len() - 1].h;
    assert_eq!(head_traces.len(), 3);
    for (j, &l) in engine.logits().iter().enumerate() {
        let expect =
            head_traces.iter().map(|h| h[j]).sum::<f32>() / 3.0 + bias[j];
        assert!((l - expect).abs() < 1e-5, "engine logit {j}: {l} vs {expect}");
    }
}
