//! Determinism of the Monte-Carlo device-variation sweep (ADR-008).
//!
//! The sweep is a pure function of (weights, sweep config): no wall
//! clock, no ambient randomness — every mismatch draw derives from the
//! master seed through `instance_seed`, and the threaded plan traversal
//! is bit-identical at every lane count (ADR-007). These tests pin
//! that down:
//! * same master seed ⇒ bit-identical reports across engine thread
//!   counts, across repeated runs, and with the delta-sparsity fast
//!   path on;
//! * batch-shape invariance: instance `i`'s device (and therefore its
//!   logits on a shared input) does not depend on how many other
//!   instances were provisioned alongside it;
//! * distinct instance seeds ⇒ distinct per-slot mismatch draws, and
//!   distinct master seeds ⇒ distinct populations.

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::montecarlo::{instance_seed, DeviceSweep};
use minimalist::nn::synthetic_network;

fn base_sweep(master: u64) -> DeviceSweep {
    DeviceSweep {
        instances: 6,
        mismatch_levels: vec![0.0, 0.02, 0.05],
        samples: 3,
        img: 8,
        master_seed: master,
        geometry: CoreGeometry { rows: 16, cols: 16 },
        ..DeviceSweep::default()
    }
}

#[test]
fn sweep_is_bit_identical_across_engine_thread_counts() {
    // delta ∈ {0, 0.05}: the quiescent-skip fast path must not perturb
    // the sweep either — skip decisions are per-slot deterministic
    let nw = synthetic_network(&[1, 12, 10], 31);
    for delta in [0.0f64, 0.05] {
        let mut reports = Vec::new();
        for threads in [1usize, 2, 4] {
            let sweep = DeviceSweep {
                engine_threads: threads,
                delta,
                ..base_sweep(0x5EED)
            };
            reports.push(sweep.run(&nw).unwrap());
        }
        for (i, r) in reports.iter().enumerate().skip(1) {
            assert_eq!(
                r.levels, reports[0].levels,
                "delta={delta}: thread count #{i} changed the sweep levels"
            );
            assert_eq!(
                r.ideal_accuracy, reports[0].ideal_accuracy,
                "delta={delta}: thread count #{i} changed the ideal reference"
            );
        }
    }
}

#[test]
fn sweep_is_reproducible_run_to_run() {
    let nw = synthetic_network(&[1, 12, 10], 31);
    let a = base_sweep(0xABCD).run(&nw).unwrap();
    let b = base_sweep(0xABCD).run(&nw).unwrap();
    assert_eq!(a, b, "same master seed must reproduce the report exactly");
}

#[test]
fn distinct_master_seeds_fabricate_distinct_populations() {
    let nw = synthetic_network(&[1, 12, 10], 31);
    let a = base_sweep(0x1111).run(&nw).unwrap();
    let b = base_sweep(0x2222).run(&nw).unwrap();
    // the noisy levels dissipate different joules under different
    // mismatch draws — an f64-exact collision would be astronomical
    let last = a.levels.len() - 1;
    assert!(
        a.levels[last].energy_total_j != b.levels[last].energy_total_j
            || a.levels[last].per_instance_acc
                != b.levels[last].per_instance_acc,
        "two master seeds produced an identical σ={} level",
        a.levels[last].sigma_c
    );
}

#[test]
fn instance_devices_do_not_depend_on_population_size() {
    // batch-shape invariance: slot i holds the instance_seed(master, i)
    // device whether 4 or 8 instances were provisioned around it, and
    // its logits on a shared input are bit-identical in both shapes
    let nw = synthetic_network(&[1, 16, 10], 43);
    let geometry = CoreGeometry { rows: 16, cols: 16 };
    let master = 0xBA7C4;
    let shared: Vec<f32> = (0..12).map(|t| (t % 3) as f32 / 2.0).collect();
    let run = |instances: usize| -> Vec<Vec<f32>> {
        let mut engine = MixedSignalEngine::new(
            nw.clone(),
            CircuitConfig::default(),
            geometry,
        )
        .unwrap();
        engine.provision_devices(master, instances);
        let refs: Vec<&[f32]> =
            (0..instances).map(|_| shared.as_slice()).collect();
        engine.classify_batch(&refs);
        (0..instances).map(|s| engine.logits_slot(s)).collect()
    };
    let small = run(4);
    let large = run(8);
    for s in 0..4 {
        assert_eq!(
            small[s], large[s],
            "slot {s}'s device changed with the population size"
        );
    }
    // distinct instance seeds ⇒ distinct per-slot mismatch draws: the
    // same input through 8 sibling devices cannot agree everywhere
    assert!(
        large.windows(2).any(|w| w[0] != w[1]),
        "8 sibling instances produced identical logits"
    );
}

#[test]
fn instance_seed_stream_is_distinct_and_master_sensitive() {
    let mut seen = std::collections::HashSet::new();
    for i in 0..512 {
        assert!(
            seen.insert(instance_seed(0xFACE, i)),
            "instance seed collision at i={i}"
        );
    }
    // a different master shifts the whole stream
    assert_ne!(instance_seed(0xFACE, 0), instance_seed(0xFACF, 0));
    // and the construction device (cfg.seed = master) is NOT instance 0
    assert_ne!(instance_seed(0xFACE, 0), 0xFACE);
}
