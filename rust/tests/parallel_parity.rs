//! Thread-count invariance of the batched engine (PR 9 tentpole).
//!
//! The threaded plan traversal (ADR-007) must be a **pure scheduling
//! change**: for any engine thread count, any plan shape, and any
//! delta-sparsity threshold, the lockstep batch path produces logits
//! bit-identical to the 1-thread serial traversal — which
//! `tests/batch_parity.rs` in turn pins to the sequential scalar
//! `step` path. The chain anchors here on the sequential engine
//! directly, so one assertion covers both links: threading × the lane
//! inner loops vs the scalar path.
//!
//! Why this can be exact and not merely close: the worker tasks never
//! share a float accumulation. Each task steps its own cores (whose
//! RNG streams depend only on their own call sequence, docs/adr/001),
//! writes its outputs into per-core staging, and the main thread
//! replays the serial splice/combine order — row-tile-ascending
//! weighted partial sums, core-ascending output order. Scheduling
//! decides *when* a tile computes, never *what* it computes or the
//! order anything is reduced in.
//!
//! Also pinned: the observability counters (delta skip counters,
//! energy meters, fabric stats) are identical under threading — they
//! are per-core state merged in core-index order at read time, so two
//! runs at different thread counts must agree to the bit.

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::nn::{argmax, synthetic_network};

/// Engine thread counts under test; 1 is the serial traversal the
/// others must match bit for bit.
const THREADS: [usize; 3] = [1, 2, 4];

/// Deterministic uniform-length batch: `b` sequences of `t_len` frames
/// of width `d_in`, every value distinct enough to exercise the delta
/// tracker's fire/skip boundary.
fn make_seqs(b: usize, t_len: usize, d_in: usize) -> Vec<Vec<f32>> {
    (0..b)
        .map(|s| {
            (0..t_len * d_in)
                .map(|i| (((i + 3) * (s * 7 + 5)) % 11) as f32 / 10.0)
                .collect()
        })
        .collect()
}

/// Core assertion: every thread count reproduces the sequential scalar
/// path's logits and labels, bit for bit, on the given plan and delta.
fn assert_thread_invariance(
    dims: &[usize],
    geometry: CoreGeometry,
    delta: f64,
    want_row_split: bool,
    ctx: &str,
) {
    let template = MixedSignalEngine::new(
        synthetic_network(dims, 17),
        CircuitConfig { delta, ..CircuitConfig::default() },
        geometry,
    )
    .expect("parity network must map");
    assert_eq!(
        template.plan.layers.iter().any(|l| l.is_row_split()),
        want_row_split,
        "{ctx}: plan shape is not what this case intends to cover"
    );
    let (b, t_len) = (4usize, 12usize);
    let data = make_seqs(b, t_len, dims[0]);
    let views: Vec<&[f32]> = data.iter().map(|s| s.as_slice()).collect();

    // the outside anchor: the sequential scalar step path, one
    // sequence at a time
    let mut seq_engine = template.replicate().expect("replicate");
    let seq_logits: Vec<Vec<f32>> = data
        .iter()
        .map(|s| {
            seq_engine.classify(s);
            seq_engine.logits()
        })
        .collect();

    for &threads in &THREADS {
        let mut engine = template.replicate().expect("replicate");
        engine.set_engine_threads(threads);
        assert_eq!(engine.engine_threads(), threads);
        let labels = engine.classify_batch(&views);
        for slot in 0..b {
            let logits = engine.logits_slot(slot);
            assert_eq!(
                logits, seq_logits[slot],
                "{ctx}: slot {slot} at {threads} engine threads is not \
                 bit-identical to the sequential scalar path"
            );
            assert_eq!(labels[slot], argmax(&seq_logits[slot]));
        }
    }
}

#[test]
fn unsplit_plan_is_thread_invariant_exact_and_delta() {
    // single-tile layers: the pool degenerates to per-layer fan-out of
    // one task — the scheduling edge case, not the scaling case
    let geometry = CoreGeometry { rows: 16, cols: 16 };
    for delta in [0.0, 0.05] {
        assert_thread_invariance(
            &[1, 16, 10],
            geometry,
            delta,
            false,
            &format!("unsplit delta={delta}"),
        );
    }
}

#[test]
fn row_split_plan_is_thread_invariant_exact_and_delta() {
    // 40 inputs on 32-row cores → 2 row tiles: the partial-sum combine
    // is where the serial accumulation-order replay actually matters
    let geometry = CoreGeometry { rows: 32, cols: 32 };
    for delta in [0.0, 0.05] {
        assert_thread_invariance(
            &[40, 8],
            geometry,
            delta,
            true,
            &format!("row-split delta={delta}"),
        );
    }
}

#[test]
fn multi_layer_paper_shape_is_thread_invariant() {
    // a deeper stack on small cores: column splits + a row split in
    // the same traversal, many independent tiles per layer — the
    // fan-out the pool exists for
    let geometry = CoreGeometry { rows: 24, cols: 24 };
    assert_thread_invariance(
        &[40, 32, 32, 10],
        geometry,
        0.0,
        true,
        "multi-layer",
    );
}

#[test]
fn counters_are_deterministic_under_threading() {
    // delta skip counters, energy meters, and fabric stats are
    // per-core state merged in core-index order at read time: a
    // threaded run must report exactly what the serial run reports,
    // and two threaded runs must report exactly each other
    let dims = [40usize, 8];
    let geometry = CoreGeometry { rows: 32, cols: 32 };
    let template = MixedSignalEngine::new(
        synthetic_network(&dims, 17),
        CircuitConfig { delta: 0.05, ..CircuitConfig::default() },
        geometry,
    )
    .expect("parity network must map");
    let data = make_seqs(4, 12, dims[0]);
    let views: Vec<&[f32]> = data.iter().map(|s| s.as_slice()).collect();

    let run = |threads: usize| {
        let mut engine = template.replicate().expect("replicate");
        engine.set_engine_threads(threads);
        engine.classify_batch(&views);
        (engine.delta_stats(), engine.energy(), engine.fabric_stats())
    };
    let serial = run(1);
    assert!(
        serial.0.components_fired + serial.0.components_skipped > 0,
        "the delta tracker must actually engage on this workload"
    );
    for threads in [2usize, 4] {
        assert_eq!(run(threads), serial, "{threads} threads");
        assert_eq!(run(threads), serial, "{threads} threads, second run");
    }
}
