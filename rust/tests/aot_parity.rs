//! Cross-layer integration: the AOT HLO artifact produced by
//! `python -m compile.aot` must load through the PJRT CPU client and
//! reproduce the jax-evaluated logits.
//!
//! Tolerance note: the reference logits come from jax's bundled XLA
//! (≥0.8) while the rust side compiles the same HLO with xla_extension
//! 0.5.1 — different fusion/reassociation choices accumulate f32 drift
//! across the T=256 recurrent steps (observed worst |Δ| ≈ 0.02 on
//! logits of O(1–10)). The classification (argmax) must agree exactly.
//!
//! Skipped (cleanly) when `artifacts/` has not been built yet.

use minimalist::io::tensorfile::TensorFile;
use minimalist::runtime::Runtime;
use minimalist::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("sequence.hlo.txt").exists() && dir.join("aot_smoke.mtf").exists() {
        Some(dir)
    } else {
        None
    }
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and the PJRT feature — \
            run `cargo test --features pjrt -- --ignored`"]
fn sequence_artifact_matches_jax_eval() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let meta = Json::parse(
        &std::fs::read_to_string(dir.join("meta.json")).unwrap(),
    )
    .unwrap();
    let t_len = meta.req_f64("t_len").unwrap() as usize;
    let batch = meta.req_f64("batch").unwrap() as usize;
    let dims: Vec<usize> = meta
        .req("dims")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|d| d.as_f64().unwrap() as usize)
        .collect();
    let d_in = dims[0];
    let n_out = *dims.last().unwrap();

    let smoke = TensorFile::load(dir.join("aot_smoke.mtf")).unwrap();
    let x = smoke.req("x").unwrap().as_f32();
    let expect = smoke.req("logits").unwrap().as_f32();
    assert_eq!(x.len(), t_len * batch * d_in);
    assert_eq!(expect.len(), batch * n_out);

    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(dir.join("sequence.hlo.txt")).unwrap();
    let out = exe
        .run_f32(&[(&x, &[t_len, batch, d_in])])
        .expect("executing sequence artifact");
    let logits = &out[0];
    assert_eq!(logits.len(), expect.len());
    let mut worst = 0.0f32;
    for (a, b) in logits.iter().zip(expect.iter()) {
        worst = worst.max((a - b).abs());
    }
    assert!(
        worst < 5e-2,
        "rust-PJRT vs jax logits diverged: worst |Δ| = {worst}"
    );
    // and the classification must agree wherever the decision margin
    // exceeds the cross-build numeric drift (the smoke inputs are random
    // noise, so some logit vectors are near-degenerate by construction)
    let am = |v: &[f32]| {
        v.iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0
    };
    let mut checked = 0;
    for b in 0..batch {
        let e = &expect[b * n_out..(b + 1) * n_out];
        let mut sorted: Vec<f32> = e.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let margin = sorted[0] - sorted[1];
        if margin > 4.0 * worst {
            assert_eq!(
                am(&logits[b * n_out..(b + 1) * n_out]),
                am(e),
                "argmax mismatch in batch element {b} (margin {margin})"
            );
            checked += 1;
        }
    }
    eprintln!("argmax checked on {checked}/{batch} confident elements");
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and the PJRT feature — \
            run `cargo test --features pjrt -- --ignored`"]
fn step_artifact_loads_and_runs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let meta = Json::parse(
        &std::fs::read_to_string(dir.join("meta.json")).unwrap(),
    )
    .unwrap();
    let batch = meta.req_f64("batch").unwrap() as usize;
    let dims: Vec<usize> = meta
        .req("dims")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|d| d.as_f64().unwrap() as usize)
        .collect();

    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(dir.join("step.hlo.txt")).unwrap();

    // zero states + a mid-scale input: one streaming step
    let x = vec![0.5f32; batch * dims[0]];
    let mut inputs: Vec<(Vec<f32>, Vec<usize>)> =
        vec![(x, vec![batch, dims[0]])];
    for &h in &dims[1..] {
        inputs.push((vec![0.0f32; batch * h], vec![batch, h]));
    }
    let refs: Vec<(&[f32], &[usize])> = inputs
        .iter()
        .map(|(d, s)| (d.as_slice(), s.as_slice()))
        .collect();
    let out = exe.run_f32(&refs).expect("executing step artifact");
    // outputs: readout + one new state per layer
    assert_eq!(out.len(), 1 + dims.len() - 1);
    assert_eq!(out[0].len(), batch * *dims.last().unwrap());
    // states must stay inside the convex rail range
    for (l, h) in out.iter().skip(1).enumerate() {
        for &v in h {
            assert!(v.is_finite() && v.abs() < 10.0,
                    "layer {l} state out of range: {v}");
        }
    }
}
