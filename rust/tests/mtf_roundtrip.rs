//! Cross-language container test: MTF files written by python
//! (`compile/export.py`) load in rust, and vice versa. The python side of
//! the reverse direction is covered by `python/tests/test_export.py`,
//! which reads a rust-written file checked in to a temp dir via this
//! test's twin. Here we verify (a) rust↔rust byte-identity and (b) a
//! python-produced artifact (when present) loads with the expected
//! schema.

use minimalist::io::tensorfile::{Tensor, TensorFile};

#[test]
fn rust_writer_rust_reader() {
    let mut tf = TensorFile::new();
    tf.insert("weights", Tensor::f32(vec![4, 2], (0..8).map(|i| i as f32 * 0.5).collect()));
    tf.insert("codes", Tensor::i32(vec![3], vec![0, 2, 3]));
    let path = std::env::temp_dir().join("roundtrip_rust.mtf");
    tf.save(&path).unwrap();
    let back = TensorFile::load(&path).unwrap();
    assert_eq!(back.get("weights"), tf.get("weights"));
    assert_eq!(back.get("codes"), tf.get("codes"));
    // byte-identity of a re-serialize
    assert_eq!(back.to_bytes(), tf.to_bytes());
}

#[test]
#[ignore = "needs a python-trained checkpoint (runs/*/weights.mtf) — run \
            training first, then `cargo test -- --ignored`"]
fn python_checkpoint_loads_when_present() {
    // Any trained run directory works; skip cleanly even under
    // `--ignored` when not trained yet.
    let candidates = [
        "runs/quant_s0/weights.mtf",
        "runs/hw_s0/weights.mtf",
        "../runs/quant_s0/weights.mtf",
    ];
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let Some(path) = candidates
        .iter()
        .map(|c| root.join(c))
        .find(|p| p.exists())
    else {
        eprintln!("skipping: no trained checkpoint found (run training first)");
        return;
    };
    let nw = minimalist::nn::NetworkWeights::load(path.to_str().unwrap())
        .expect("loading python-trained checkpoint");
    assert!(nw.n_layers() >= 2);
    assert_eq!(nw.dims.len(), nw.n_layers() + 1);
    // code planes must be valid 2-bit codes and biases finite
    for l in &nw.layers {
        assert!(l.wh_codes.iter().all(|&c| (0..4).contains(&c)));
        assert!(l.bh.iter().chain(l.bz.iter()).all(|b| b.is_finite()));
        assert!(l.alpha > 0.0);
    }
}
