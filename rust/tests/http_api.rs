//! Wire-level conformance and parity for the HTTP front end (PR 6
//! tentpole), against the contract in docs/http-api.md.
//!
//! Two families of guarantees:
//!
//! * **Parity** — a request served over the wire must equal the same
//!   call made in-process, bit for bit: one-shot classification labels,
//!   and streaming running logits (f32 survives the JSON roundtrip
//!   exactly because numbers are printed shortest-roundtrip f64).
//! * **Robustness** — a malformed peer can never take the listener
//!   down. Every refusal status the parser defines (400, 411, 413,
//!   431, 501, 505) is provoked over a raw socket and followed by a
//!   fresh well-formed request that must still succeed.
//!
//! Every status code documented in docs/http-api.md has a conformance
//! test here: 200, 201, 400, 404, 405, 411, 413, 429, 431, 501, 503,
//! 505 (500 is the defensive panic-containment path, exercised only in
//! prose — no handler panics on purpose).

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use minimalist::coordinator::loadgen::{self, LoadGenOpts};
use minimalist::coordinator::{
    status_for, BatchPolicy, GoldenBackend, HttpConfig, HttpServer, ServeError,
    Server, StreamServer,
};
use minimalist::nn::{argmax, synthetic_network, GoldenNetwork};
use minimalist::util::http::{read_response, HttpClient, HttpResponse};
use minimalist::util::json::Json;

const DIMS: [usize; 3] = [1, 16, 10];

/// Short keep-alive so idle/drain paths resolve quickly under test.
fn test_config() -> HttpConfig {
    HttpConfig {
        keepalive: Duration::from_millis(200),
        ..HttpConfig::default()
    }
}

/// The full serving stack on an ephemeral port: golden one-shot engine,
/// golden streaming engine, HTTP front end over both.
struct Stack {
    http: HttpServer,
    server: Server,
    stream: StreamServer,
}

fn spawn_stack(workers: usize, sessions: usize) -> Stack {
    let nw = synthetic_network(&DIMS, 9);
    let server = Server::spawn_sharded(
        GoldenBackend::factory(nw.clone()),
        BatchPolicy::new(8, Duration::from_millis(1)),
        workers,
    );
    let stream = StreamServer::spawn(
        GoldenBackend::streaming_factory(nw, sessions),
        workers,
        sessions,
    );
    let http = HttpServer::bind(
        "127.0.0.1:0",
        Some(server.client()),
        Some(stream.client()),
        test_config(),
    )
    .expect("ephemeral-port bind");
    Stack { http, server, stream }
}

impl Stack {
    fn addr(&self) -> String {
        self.http.addr().to_string()
    }

    /// Front end first, then the engines — the documented drain order.
    fn teardown(self) {
        self.http.shutdown();
        self.server.shutdown();
        self.stream.shutdown();
    }
}

/// Deterministic test sequence (d_in = 1: one value per frame).
fn seq(len: usize, salt: usize) -> Vec<f32> {
    (0..len)
        .map(|t| (((t + 2) * (salt + 3)) % 7) as f32 / 6.0)
        .collect()
}

fn f32s_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn json_f32s(j: &Json, key: &str) -> Vec<f32> {
    j.req(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

/// Fire raw bytes at the server and read the single response — the
/// malformed-input path, below the well-formed [`HttpClient`].
fn raw(addr: &str, bytes: &[u8]) -> HttpResponse {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(bytes).unwrap();
    let mut r = BufReader::new(s);
    read_response(&mut r).unwrap()
}

#[test]
fn wire_classify_matches_in_process_and_reference() {
    let stack = spawn_stack(2, 2);
    let mut c = HttpClient::connect(&stack.addr()).unwrap();
    let mut reference = GoldenNetwork::new(synthetic_network(&DIMS, 9));
    for salt in 0..4usize {
        let s = seq(24, salt);
        let body = Json::obj(vec![
            ("id", ((salt + 100) as f64).into()),
            ("sequence", f32s_json(&s)),
        ]);
        let resp = c.request("POST", "/v1/classify", Some(&body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.text());
        let j = resp.json().unwrap();
        assert_eq!(j.req_f64("id").unwrap() as usize, salt + 100);
        assert!(j.req_f64("latency_us").unwrap() >= 0.0);
        let wire_label = j.req_f64("label").unwrap() as usize;
        // the same engine called in-process must agree exactly...
        let inproc = stack.server.client().classify(9000 + salt as u64, s.clone());
        assert_eq!(wire_label, inproc.result.unwrap());
        // ...and so must the golden reference network
        assert_eq!(wire_label, reference.classify(&s));
    }
    stack.teardown();
}

#[test]
fn wire_streaming_matches_one_shot_bitwise() {
    let stack = spawn_stack(1, 2);
    let mut c = HttpClient::connect(&stack.addr()).unwrap();
    let s = seq(23, 3);
    let r = c.request("POST", "/v1/session", None).unwrap();
    assert_eq!(r.status, 201, "{}", r.text());
    let sid = r.json().unwrap().req_f64("session").unwrap() as u64;
    let mut reference = GoldenNetwork::new(synthetic_network(&DIMS, 9));
    let mut pushed = 0usize;
    for chunk in [3usize, 5, 8, 7] {
        let body =
            Json::obj(vec![("values", f32s_json(&s[pushed..pushed + chunk]))]);
        let pr = c
            .request("POST", &format!("/v1/session/{sid}/frames"), Some(&body))
            .unwrap();
        assert_eq!(pr.status, 200, "{}", pr.text());
        assert_eq!(pr.json().unwrap().req_f64("frames").unwrap() as usize, chunk);
        pushed += chunk;
        // running logits over the prefix must be bit-identical to a
        // one-shot classification of the same frames — the JSON number
        // roundtrip (f32 → shortest f64 text → f32) is exact
        let lr = c
            .request("GET", &format!("/v1/session/{sid}/logits"), None)
            .unwrap();
        assert_eq!(lr.status, 200, "{}", lr.text());
        let lj = lr.json().unwrap();
        reference.classify(&s[..pushed]);
        assert_eq!(
            json_f32s(&lj, "logits"),
            reference.logits(),
            "prefix of {pushed} frames diverged over the wire"
        );
        assert_eq!(
            lj.req_f64("argmax").unwrap() as usize,
            argmax(&reference.logits())
        );
    }
    assert_eq!(pushed, s.len());
    let dr = c.request("DELETE", &format!("/v1/session/{sid}"), None).unwrap();
    assert_eq!(dr.status, 200, "{}", dr.text());
    assert_eq!(
        dr.json().unwrap().req_f64("label").unwrap() as usize,
        reference.classify(&s)
    );
    // the id is retired: every further op on it is a 404
    let gone = c
        .request("GET", &format!("/v1/session/{sid}/logits"), None)
        .unwrap();
    assert_eq!(gone.status, 404, "{}", gone.text());
    stack.teardown();
}

#[test]
fn healthz_and_metrics_report_live_state() {
    let stack = spawn_stack(1, 2);
    let mut c = HttpClient::connect(&stack.addr()).unwrap();
    let h = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 200);
    let hj = h.json().unwrap();
    assert_eq!(hj.req_str("status").unwrap(), "ok");
    assert_eq!(hj.req_f64("live_sessions").unwrap(), 0.0);

    let sid = c
        .request("POST", "/v1/session", None)
        .unwrap()
        .json()
        .unwrap()
        .req_f64("session")
        .unwrap() as u64;
    let hj = c.request("GET", "/healthz", None).unwrap().json().unwrap();
    assert_eq!(hj.req_f64("live_sessions").unwrap(), 1.0);

    let m = c.request("GET", "/metrics", None).unwrap();
    assert_eq!(m.status, 200);
    assert!(m.header("content-type").unwrap().starts_with("text/plain"));
    let text = m.text();
    for family in [
        "minimalist_http_connections_total",
        "minimalist_http_requests_total",
        "minimalist_http_protocol_errors_total 0",
        "minimalist_http_sessions_live 1",
        "minimalist_http_responses_total{status=\"200\"}",
        "minimalist_http_responses_total{status=\"201\"} 1",
        "minimalist_http_request_latency_us{quantile=\"0.5\"}",
        "minimalist_http_request_latency_us_count",
        "minimalist_serve_errors_total{kind=\"busy\"} 0",
        "minimalist_serve_errors_total{kind=\"lost\"} 0",
        "minimalist_serve_errors_total{kind=\"panicked\"} 0",
    ] {
        assert!(text.contains(family), "missing '{family}' in:\n{text}");
    }
    let dr = c.request("DELETE", &format!("/v1/session/{sid}"), None).unwrap();
    assert_eq!(dr.status, 200);
    stack.teardown();
}

#[test]
fn unknown_routes_and_wrong_methods() {
    let stack = spawn_stack(1, 1);
    let mut c = HttpClient::connect(&stack.addr()).unwrap();
    let r = c.request("GET", "/no/such/route", None).unwrap();
    assert_eq!(r.status, 404);
    assert_eq!(r.json().unwrap().req_str("error").unwrap(), "not_found");
    // wrong method on every known path: 405, not 404
    for (method, path) in [
        ("GET", "/v1/classify"),
        ("PUT", "/v1/classify"),
        ("POST", "/healthz"),
        ("POST", "/metrics"),
        ("DELETE", "/v1/session"),
        ("GET", "/v1/session/1"),
        ("PUT", "/v1/session/1/frames"),
        ("POST", "/v1/session/1/logits"),
    ] {
        let r = c.request(method, path, None).unwrap();
        assert_eq!(r.status, 405, "{method} {path}: {}", r.text());
        assert_eq!(
            r.json().unwrap().req_str("error").unwrap(),
            "method_not_allowed"
        );
    }
    // non-integer session ids are 400, unknown numeric ids 404
    for (method, path) in [
        ("GET", "/v1/session/abc/logits"),
        ("DELETE", "/v1/session/abc"),
    ] {
        assert_eq!(c.request(method, path, None).unwrap().status, 400);
    }
    let body = Json::obj(vec![("values", vec![0.5f64].into())]);
    for (method, path, b) in [
        ("POST", "/v1/session/424242/frames", Some(&body)),
        ("GET", "/v1/session/424242/logits", None),
        ("DELETE", "/v1/session/424242", None),
    ] {
        let r = c.request(method, path, b).unwrap();
        assert_eq!(r.status, 404, "{method} {path}: {}", r.text());
        assert_eq!(
            r.json().unwrap().req_str("error").unwrap(),
            "unknown_session"
        );
    }
    stack.teardown();
}

#[test]
fn malformed_requests_are_refused_and_the_listener_survives() {
    let stack = spawn_stack(1, 1);
    let addr = stack.addr();
    let mut cases: Vec<(Vec<u8>, u16)> = vec![
        // garbage request line
        (b"GARBAGE\r\n\r\n".to_vec(), 400),
        // unsupported HTTP version
        (b"GET /healthz HTTP/2.0\r\nhost: h\r\n\r\n".to_vec(), 505),
        // POST without Content-Length
        (b"POST /v1/classify HTTP/1.1\r\nhost: h\r\n\r\n".to_vec(), 411),
        // unparseable Content-Length
        (
            b"POST /v1/classify HTTP/1.1\r\ncontent-length: abc\r\n\r\n"
                .to_vec(),
            400,
        ),
        // declared body over the limit
        (
            b"POST /v1/classify HTTP/1.1\r\ncontent-length: 999999999\r\n\r\n"
                .to_vec(),
            413,
        ),
        // chunked encoding is outside the subset
        (
            b"POST /v1/classify HTTP/1.1\r\ntransfer-encoding: chunked\r\n\
              content-length: 4\r\n\r\nabcd"
                .to_vec(),
            501,
        ),
    ];
    // a single oversized header line
    let mut big = b"GET /healthz HTTP/1.1\r\nx-big: ".to_vec();
    big.extend(vec![b'a'; 20_000]);
    big.extend_from_slice(b"\r\n\r\n");
    cases.push((big, 431));
    // too many headers
    let mut many = b"GET /healthz HTTP/1.1\r\n".to_vec();
    for i in 0..80 {
        many.extend_from_slice(format!("x-h{i}: v\r\n").as_bytes());
    }
    many.extend_from_slice(b"\r\n");
    cases.push((many, 431));

    for (bytes, want) in cases {
        let resp = raw(&addr, &bytes);
        assert_eq!(resp.status, want, "{}", resp.text());
        // protocol violations always close the connection...
        assert_eq!(resp.header("connection"), Some("close"));
        assert_eq!(resp.json().unwrap().req_str("error").unwrap(), "protocol");
        // ...and never take the listener down: a fresh well-formed
        // request right after must succeed
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.request("GET", "/healthz", None).unwrap().status, 200);
    }
    let metrics = stack.http.shutdown();
    assert_eq!(metrics.protocol_errors, 8);
    stack.server.shutdown();
    stack.stream.shutdown();
}

#[test]
fn early_disconnect_mid_body_leaves_the_listener_alive() {
    let stack = spawn_stack(1, 1);
    let addr = stack.addr();
    {
        let mut s = TcpStream::connect(addr.as_str()).unwrap();
        s.write_all(
            b"POST /v1/classify HTTP/1.1\r\ncontent-length: 100\r\n\r\n{\"seq",
        )
        .unwrap();
        // dropped here: the peer vanishes with 94 bytes still owed
    }
    let mut c = HttpClient::connect(&addr).unwrap();
    assert_eq!(c.request("GET", "/healthz", None).unwrap().status, 200);
    stack.teardown();
}

#[test]
fn invalid_bodies_are_400_without_killing_the_connection() {
    let stack = spawn_stack(1, 1);
    let mut c = HttpClient::connect(&stack.addr()).unwrap();
    // valid JSON, wrong shape
    for body in [
        Json::obj(vec![]),
        Json::obj(vec![("sequence", Json::Arr(vec![]))]),
        Json::obj(vec![("sequence", "nope".into())]),
        Json::obj(vec![("sequence", Json::Arr(vec!["x".into()]))]),
    ] {
        let r = c.request("POST", "/v1/classify", Some(&body)).unwrap();
        assert_eq!(r.status, 400, "{body}: {}", r.text());
        assert_eq!(r.json().unwrap().req_str("error").unwrap(), "bad_request");
    }
    // invalid JSON text, and bytes that are not UTF-8 at all
    let raw_cases: [&[u8]; 2] = [
        b"POST /v1/classify HTTP/1.1\r\ncontent-length: 7\r\n\r\n{not js",
        b"POST /v1/classify HTTP/1.1\r\ncontent-length: 4\r\n\r\n\xff\xfe\x00\x01",
    ];
    for bytes in raw_cases {
        let resp = raw(&stack.addr(), bytes);
        assert_eq!(resp.status, 400, "{}", resp.text());
        assert_eq!(resp.json().unwrap().req_str("error").unwrap(), "bad_request");
    }
    // handler-level 400s are not protocol errors: the keep-alive
    // connection survived all of them
    assert_eq!(c.request("GET", "/healthz", None).unwrap().status, 200);
    stack.teardown();
}

#[test]
fn slot_exhaustion_maps_to_429_and_recovers() {
    let stack = spawn_stack(1, 1); // capacity: exactly one session
    let mut c = HttpClient::connect(&stack.addr()).unwrap();
    let r = c.request("POST", "/v1/session", None).unwrap();
    assert_eq!(r.status, 201, "{}", r.text());
    let sid = r.json().unwrap().req_f64("session").unwrap() as u64;
    // admission control: the second open is rejected, not queued —
    // with the status the canonical mapping assigns to Busy (429)
    let busy = c.request("POST", "/v1/session", None).unwrap();
    assert_eq!(busy.status, status_for(&ServeError::Busy), "{}", busy.text());
    assert_eq!(busy.json().unwrap().req_str("error").unwrap(), "busy");
    // closing frees the slot and the next open succeeds
    let dr = c.request("DELETE", &format!("/v1/session/{sid}"), None).unwrap();
    assert_eq!(dr.status, 200);
    let again = c.request("POST", "/v1/session", None).unwrap();
    assert_eq!(again.status, 201, "{}", again.text());
    let sid2 = again.json().unwrap().req_f64("session").unwrap() as u64;
    assert_eq!(
        c.request("DELETE", &format!("/v1/session/{sid2}"), None)
            .unwrap()
            .status,
        200
    );
    stack.teardown();
}

#[test]
fn engine_loss_maps_to_503_and_evicts_the_session() {
    // built by hand (not spawn_stack) so the engines can be shut down
    // while the front end stays up — the "serving side went away" case
    let nw = synthetic_network(&DIMS, 9);
    let server = Server::spawn_sharded(
        GoldenBackend::factory(nw.clone()),
        BatchPolicy::new(8, Duration::from_millis(1)),
        1,
    );
    let stream =
        StreamServer::spawn(GoldenBackend::streaming_factory(nw, 1), 1, 1);
    let http = HttpServer::bind(
        "127.0.0.1:0",
        Some(server.client()),
        Some(stream.client()),
        test_config(),
    )
    .unwrap();
    let addr = http.addr().to_string();
    let mut c = HttpClient::connect(&addr).unwrap();
    let r = c.request("POST", "/v1/session", None).unwrap();
    assert_eq!(r.status, 201, "{}", r.text());
    let sid = r.json().unwrap().req_f64("session").unwrap() as u64;

    server.shutdown();
    stream.shutdown();

    let body = Json::obj(vec![("values", vec![0.5f64].into())]);
    let pr = c
        .request("POST", &format!("/v1/session/{sid}/frames"), Some(&body))
        .unwrap();
    assert_eq!(pr.status, status_for(&ServeError::Lost), "{}", pr.text());
    assert_eq!(pr.json().unwrap().req_str("error").unwrap(), "lost");
    // the stale handle was evicted: the id now 404s instead of 503ing
    let gone = c
        .request("GET", &format!("/v1/session/{sid}/logits"), None)
        .unwrap();
    assert_eq!(gone.status, 404, "{}", gone.text());
    // one-shot classification over a dead engine is 503 too
    let cb = Json::obj(vec![("sequence", vec![0.5f64].into())]);
    let cr = c.request("POST", "/v1/classify", Some(&cb)).unwrap();
    assert_eq!(cr.status, status_for(&ServeError::Lost), "{}", cr.text());
    http.shutdown();
}

#[test]
fn connection_semantics_follow_the_http_defaults() {
    let stack = spawn_stack(1, 1);
    let addr = stack.addr();
    // HTTP/1.1 + `Connection: close`: answered, then hung up
    {
        let s = TcpStream::connect(addr.as_str()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut w = s.try_clone().unwrap();
        w.write_all(b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n")
            .unwrap();
        let mut r = BufReader::new(s);
        let resp = read_response(&mut r).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("connection"), Some("close"));
        // EOF follows (a timeout would error, failing the unwrap_or)
        assert_eq!(r.read(&mut [0u8; 1]).unwrap_or(1), 0);
    }
    // HTTP/1.0 with no connection header: closed after one response
    {
        let s = TcpStream::connect(addr.as_str()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut w = s.try_clone().unwrap();
        w.write_all(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        let mut r = BufReader::new(s);
        let resp = read_response(&mut r).unwrap();
        assert_eq!(resp.header("connection"), Some("close"));
        assert_eq!(r.read(&mut [0u8; 1]).unwrap_or(1), 0);
    }
    // HTTP/1.1 default: keep-alive — two pipelined requests, one socket
    {
        let s = TcpStream::connect(addr.as_str()).unwrap();
        let mut w = s.try_clone().unwrap();
        w.write_all(
            b"GET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n",
        )
        .unwrap();
        let mut r = BufReader::new(s);
        let first = read_response(&mut r).unwrap();
        assert_eq!(first.header("connection"), Some("keep-alive"));
        assert_eq!(read_response(&mut r).unwrap().status, 200);
    }
    stack.teardown();
}

#[test]
fn shutdown_drains_and_then_refuses_connections() {
    let stack = spawn_stack(1, 1);
    let addr = stack.addr();
    let mut c = HttpClient::connect(&addr).unwrap();
    assert_eq!(c.request("GET", "/healthz", None).unwrap().status, 200);
    let metrics = stack.http.shutdown();
    assert!(metrics.requests() >= 1);
    assert_eq!(metrics.protocol_errors, 0);
    // the listener is gone: new dials are refused at the socket level
    // (or, losing a race with the kernel backlog, die on first use)
    match HttpClient::connect(&addr) {
        Err(_) => {}
        Ok(mut c2) => assert!(c2.request("GET", "/healthz", None).is_err()),
    }
    stack.server.shutdown();
    stack.stream.shutdown();
}

#[test]
fn loadgen_completes_sessions_cleanly_end_to_end() {
    let stack = spawn_stack(2, 2); // capacity 4 = the loadgen connections
    let opts = LoadGenOpts {
        connections: 4,
        sessions_per_conn: 2,
        frames: 8,
        frames_per_push: 4,
        frame_width: 1,
        poll_logits: true,
    };
    let report = loadgen::run(&stack.addr(), &opts);
    assert_eq!(report.sessions_completed, 8, "{}", report.summary());
    assert_eq!(report.frames_pushed, 64, "{}", report.summary());
    assert_eq!(report.protocol_errors, 0, "{}", report.summary());
    assert_eq!(report.transport_errors, 0, "{}", report.summary());
    let m = stack.http.shutdown();
    assert_eq!(m.protocol_errors, 0);
    stack.server.shutdown();
    stack.stream.shutdown();
}
