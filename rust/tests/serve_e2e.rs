//! End-to-end serving: synthetic digit load through the coordinator
//! (batcher + server thread + backend), checking accuracy against the
//! golden model and that the metrics pipeline is sane.

use std::time::Duration;

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::{
    BatchPolicy, GoldenBackend, MixedSignalBackend, MixedSignalEngine, Server,
};
use minimalist::dataset::glyphs;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};

fn network() -> NetworkWeights {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    for c in ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf"] {
        let p = root.join(c);
        if p.exists() {
            if let Ok(nw) = NetworkWeights::load(p.to_str().unwrap()) {
                return nw;
            }
        }
    }
    synthetic_network(&[1, 32, 10], 9)
}

#[test]
fn golden_backend_end_to_end() {
    let nw = network();
    let img = 8usize; // short sequences keep the test fast
    let samples = glyphs::make_split(30, img, 5);

    // reference labels straight through the model
    let mut reference = GoldenNetwork::new(nw.clone());
    let expected: Vec<usize> =
        samples.iter().map(|s| reference.classify(&s.pixels)).collect();

    let server = Server::spawn(
        Box::new(GoldenBackend::new(GoldenNetwork::new(nw))),
        BatchPolicy::new(8, Duration::from_millis(2)),
    );
    let client = server.client();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
        .collect();
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.label(), want, "served label must equal direct model");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.items, 30);
    assert!(metrics.percentile(99.0) >= metrics.percentile(50.0));
}

#[test]
fn sharded_golden_backend_matches_direct_model() {
    // The tentpole property of the multi-worker engine: sharding the
    // coordinator across N backend replicas must not change a single
    // served label relative to the direct (unsharded) model.
    let nw = network();
    let samples = glyphs::make_split(24, 8, 11);
    let mut reference = GoldenNetwork::new(nw.clone());
    let expected: Vec<usize> =
        samples.iter().map(|s| reference.classify(&s.pixels)).collect();

    let server = Server::spawn_sharded(
        GoldenBackend::factory(nw),
        BatchPolicy::new(4, Duration::from_millis(1)),
        4,
    );
    assert_eq!(server.n_workers(), 4);
    let client = server.client();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
        .collect();
    for (rx, want) in rxs.into_iter().zip(expected) {
        assert_eq!(rx.recv().unwrap().label(), want);
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.items, 24);
}

#[test]
fn mixed_signal_backend_end_to_end() {
    let nw = network();
    // trim to a smaller network if loaded one is the full paper size —
    // satsim over 30 sequences × T=64 is the budget here
    let engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::ideal(),
        CoreGeometry::default(),
    )
    .unwrap();
    let server = Server::spawn_with(
        move || Box::new(MixedSignalBackend::new(engine)) as _,
        BatchPolicy::new(4, Duration::from_millis(1)),
    );
    let client = server.client();
    let samples = glyphs::make_split(8, 8, 6);
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.label() < 10);
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.items, 8);
}
