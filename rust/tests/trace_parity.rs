//! Fig 4 as a test: the switched-capacitor simulation must reproduce the
//! software model's activations (z, h̃, h) on a trained network —
//! exactly in the ideal configuration (up to the documented swap
//! granularity), and within noise bounds in the default configuration.

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::quant::codesign::snap_network;

fn load_network() -> NetworkWeights {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let raw = (|| {
        for c in ["runs/hw_s0/weights.mtf", "runs/quant_s0/weights.mtf"] {
            let p = root.join(c);
            if p.exists() {
                if let Ok(nw) = NetworkWeights::load(p.to_str().unwrap()) {
                    return nw;
                }
            }
        }
        synthetic_network(&[1, 64, 64, 64, 64, 10], 42)
    })();
    // Fig 4 compares software and circuit on the *deployed* parameters:
    // snap α to the ADC slope grid and β to the DAC offset range.
    snap_network(&raw, &CircuitConfig::ideal(), 64).unwrap()
}

fn test_sequence(t_len: usize) -> Vec<f32> {
    // a deterministic pseudo-digit: smooth bumps over the scan
    (0..t_len)
        .map(|t| {
            let x = t as f32 / t_len as f32;
            (0.6 * (x * 13.0).sin().powi(2) + 0.4 * (x * 5.0).cos().powi(2))
                .clamp(0.0, 1.0)
        })
        .collect()
}

#[test]
fn ideal_circuit_tracks_golden_model() {
    let nw = load_network();
    let mut engine = MixedSignalEngine::new(
        nw.clone(),
        CircuitConfig::ideal(),
        CoreGeometry::default(),
    )
    .unwrap();
    let mut golden = GoldenNetwork::new(nw);
    let seq = test_sequence(64);

    engine.reset();
    golden.reset();
    let mut worst_h = 0.0f32;
    let mut worst_z = 0.0f32;
    for (t, &x) in seq.iter().enumerate() {
        let mut traces = Vec::new();
        engine.step(t as u32, &[x], Some(&mut traces));
        let mut gtraces = Vec::new();
        golden.step(&[x], Some(&mut gtraces));
        for l in 0..gtraces.len() {
            for (a, b) in traces[l].h.last().unwrap().iter().zip(&gtraces[l].h) {
                worst_h = worst_h.max((a - b).abs());
            }
            for (a, b) in traces[l].z.last().unwrap().iter().zip(&gtraces[l].z) {
                worst_z = worst_z.max((a - b).abs());
            }
        }
    }
    // Deviations decompose as: SAR bisection acts as floor() while the
    // golden quantizer rounds (≤ 1.5 codes), the DAC offset pre-set
    // rounds β to its code grid (≤ 1 code), and boundary decisions at
    // exact half-LSB inputs add ≤ 1 — worst |Δz| ≤ 3.5 codes. h adds the
    // 1/64 swap granularity per step on top.
    assert!(worst_z <= 3.5 / 63.0 + 1e-6, "worst |Δz| = {worst_z}");
    // h drift: a Δz of k codes shifts one convex update by
    // (k/63)·|h̃−h_prev| and partially accumulates along the recurrence;
    // with |h̃−h| = O(1) (logical units) and Δz ≤ 3.5 codes the observed
    // worst drift stays ≈ 0.12–0.13 on trained checkpoints.
    assert!(worst_h < 0.15, "worst |Δh| = {worst_h}");
}

#[test]
fn noisy_circuit_stays_close_and_classification_mostly_agrees() {
    let nw = load_network();
    let mut engine = MixedSignalEngine::new(
        nw.clone(),
        CircuitConfig::default(),
        CoreGeometry::default(),
    )
    .unwrap();
    let mut golden = GoldenNetwork::new(nw);
    let seq = test_sequence(64);
    let sim = engine.classify(&seq);
    let gold = golden.classify(&seq);
    // One sequence: noise may flip a borderline class, but the analog
    // readout values must stay close.
    let lg = golden.logits();
    let ls = engine.logits();
    let mut worst = 0.0f32;
    for (a, b) in ls.iter().zip(lg.iter()) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 0.25, "readout drifted: worst |Δlogit| = {worst}");
    // classification agreement is expected (not guaranteed); record it
    eprintln!("class sim={sim} gold={gold} (worst Δlogit {worst:.4})");
}
