//! Dedicated unit/integration tests for the §4.2 energy layer — the
//! first test file to target `energy/` directly (the module previously
//! rode along inside engine and property tests).
//!
//! Covered here:
//! * ½·C·ΔV² capacitor-event accounting and its direction symmetry;
//! * transmission-gate toggle pricing (C_gate·V_DD² per toggle);
//! * the bound invariant: simulated per-step energy never exceeds the
//!   analytic worst case, on real engines at several activity levels;
//! * meter merging across lockstep cores (steps max) and across serving
//!   workers (steps sum);
//! * golden event-count parity: one engine step must log exactly the
//!   closed-form event counts of the circuit schedule — per column with
//!   `n` active rows: `5n+6` cap events, `7` comparator decisions, one
//!   SAR conversion, and `7n+6 + 2k` switch toggles with `k ∈ [0, n]`
//!   capacitor-pair swaps;
//! * lockstep-batch vs sequential event parity: same physics, same
//!   counters, regardless of the serving path.

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::energy::{
    paper_network_bound, worst_case_step_bound, EnergyMeter,
};
use minimalist::nn::synthetic_network;

// ---------------------------------------------------------------------------
// meter arithmetic
// ---------------------------------------------------------------------------

#[test]
fn cap_event_is_half_c_delta_v_squared() {
    let mut m = EnergyMeter::new();
    m.cap_charge(2e-15, 0.1, 0.7); // ΔV = 0.6
    let want = 0.5 * 2e-15 * 0.6 * 0.6;
    assert!((m.cap_energy_j - want).abs() < 1e-30);
    assert_eq!(m.cap_events, 1);
    assert_eq!(m.switch_toggles, 0);
    // dissipation is direction-symmetric: discharging through the same
    // switch burns the same ½·C·ΔV²
    let mut down = EnergyMeter::new();
    down.cap_charge(2e-15, 0.7, 0.1);
    assert!((down.cap_energy_j - m.cap_energy_j).abs() < 1e-30);
    // and a no-op "recharge" to the same voltage costs nothing
    let mut idle = EnergyMeter::new();
    idle.cap_charge(2e-15, 0.4, 0.4);
    assert_eq!(idle.cap_energy_j, 0.0);
    assert_eq!(idle.cap_events, 1); // the event is still counted
}

#[test]
fn toggle_pricing_matches_gate_cap() {
    let cfg = CircuitConfig::default();
    let mut m = EnergyMeter::new();
    m.toggles(&cfg, 10);
    let want = 10.0 * cfg.c_gate * cfg.v_dd * cfg.v_dd;
    assert!((m.gate_energy_j - want).abs() < 1e-28);
    assert_eq!(m.switch_toggles, 10);
    // the hot-path cached variant prices identically
    let mut c = EnergyMeter::new();
    c.toggles_cached(10, cfg.c_gate * cfg.v_dd * cfg.v_dd);
    assert_eq!(c.gate_energy_j, m.gate_energy_j);
    // totals split cleanly into the two families
    m.cap_charge(1e-15, 0.0, 0.5);
    assert!((m.total_j() - (m.cap_energy_j + m.gate_energy_j)).abs() < 1e-30);
}

#[test]
fn merge_semantics_lockstep_vs_disjoint() {
    // cores stepped in lockstep describe the SAME time steps: merge()
    // maxes the step count (this is what MixedSignalEngine::energy
    // does across its cores)...
    let mut a = EnergyMeter::new();
    let mut b = EnergyMeter::new();
    for _ in 0..5 {
        a.cap_charge(1e-15, 0.0, 0.5);
        a.step_done();
        b.cap_charge(1e-15, 0.0, 0.3);
        b.step_done();
    }
    let mut lock = a.clone();
    lock.merge(&b);
    assert_eq!(lock.steps, 5);
    assert_eq!(lock.cap_events, 10);
    // ...while serving workers each stepped through their OWN requests:
    // merge_disjoint() sums steps, so the fleet per-step average is over
    // every step any worker ran
    let mut fleet = a.clone();
    fleet.merge_disjoint(&b);
    assert_eq!(fleet.steps, 10);
    assert_eq!(fleet.cap_events, 10);
    assert!((fleet.per_step_j() - fleet.total_j() / 10.0).abs() < 1e-30);
    // the energy totals agree either way — only the step base differs
    assert!((fleet.total_j() - lock.total_j()).abs() < 1e-30);
}

// ---------------------------------------------------------------------------
// bound invariant on real engines
// ---------------------------------------------------------------------------

#[test]
fn simulated_energy_stays_under_bound_across_activity_levels() {
    // the analytic worst case assumes every cap at full swing and every
    // switch toggling — real activity (silence, mid-scale, saturating)
    // must land at or below it, per step, for each engine core count
    let cfg = CircuitConfig::default();
    let geometry = CoreGeometry { rows: 16, cols: 16 };
    for (name, frame) in
        [("silence", 0.0f32), ("mid-scale", 0.5), ("saturating", 1.0)]
    {
        let nw = synthetic_network(&[1, 12, 10], 3);
        let mut engine =
            MixedSignalEngine::new(nw, cfg.clone(), geometry).unwrap();
        engine.classify(&vec![frame; 24]);
        let m = engine.energy();
        let bound = engine.n_cores() as f64
            * worst_case_step_bound(&cfg, geometry.rows, geometry.cols);
        assert!(
            m.per_step_j() <= bound,
            "{name}: simulated {} pJ/step exceeds the worst-case bound \
             {} pJ/step",
            m.per_step_j() * 1e12,
            bound * 1e12
        );
        assert!(m.total_j() > 0.0, "{name}: meter stayed silent");
    }
    // the paper's reference bound is 4 bound(64,64) by construction
    let four = paper_network_bound(&cfg);
    assert!((four - 4.0 * worst_case_step_bound(&cfg, 64, 64)).abs() < 1e-24);
}

// ---------------------------------------------------------------------------
// golden event-count parity
// ---------------------------------------------------------------------------

#[test]
fn one_step_logs_the_closed_form_event_counts() {
    // Single-layer, replication-free placement: the engine runs exactly
    // `c` GRU columns over `n = d` active rows per step, so the meter
    // must log, per column and step:
    //   cap events            5n + 6
    //   comparator decisions  7        (6 SAR bit trials + 1 binary h)
    //   SAR conversions       1
    //   switch toggles        7n + 6 + 2k,  k ∈ [0, n] pair swaps
    for (d, c) in [(4usize, 6usize), (8, 10)] {
        let nw = synthetic_network(&[d, c], 11);
        let mut engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::default(),
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap();
        assert_eq!(engine.n_cores(), 1, "replication-free placement expected");
        let x: Vec<f32> = (0..d).map(|i| (i % 2) as f32).collect();
        engine.step(0, &x, None);
        let m = engine.energy();
        let (n, cols) = (d as u64, c as u64);
        assert_eq!(m.steps, 1);
        assert_eq!(
            m.cap_events,
            cols * (5 * n + 6),
            "d={d} c={c}: cap events off the closed form"
        );
        assert_eq!(m.comparator_decisions, 7 * cols, "d={d} c={c}");
        assert_eq!(m.adc_conversions, cols, "d={d} c={c}");
        assert!(
            m.switch_toggles >= cols * (7 * n + 6)
                && m.switch_toggles <= cols * (9 * n + 6),
            "d={d} c={c}: toggles {} outside [{}, {}]",
            m.switch_toggles,
            cols * (7 * n + 6),
            cols * (9 * n + 6)
        );
    }
}

#[test]
fn batched_and_sequential_paths_log_identical_event_counts() {
    // serving-path independence: B sequences through the lockstep batch
    // equal B sequential classifications — not just in logits
    // (tests/batch_parity.rs) but in every event the meter saw. Joules
    // agree to summation order (the batch interleaves slots, so the f64
    // additions associate differently).
    let nw = synthetic_network(&[1, 16, 10], 23);
    let mut seq_engine = MixedSignalEngine::new(
        nw,
        CircuitConfig::default(),
        CoreGeometry { rows: 16, cols: 16 },
    )
    .unwrap();
    let mut bat_engine = seq_engine.replicate().unwrap();
    let seqs: Vec<Vec<f32>> = (0..3)
        .map(|s| (0..12).map(|t| ((t + s) % 4) as f32 / 3.0).collect())
        .collect();
    for s in &seqs {
        seq_engine.classify(s);
    }
    let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
    bat_engine.classify_batch(&refs);
    let (a, b) = (seq_engine.energy(), bat_engine.energy());
    assert_eq!(a.cap_events, b.cap_events);
    assert_eq!(a.switch_toggles, b.switch_toggles);
    assert_eq!(a.comparator_decisions, b.comparator_decisions);
    assert_eq!(a.adc_conversions, b.adc_conversions);
    let rel = (a.total_j() - b.total_j()).abs() / a.total_j();
    assert!(rel < 1e-12, "energy diverged beyond summation order: {rel}");
}
