//! Randomized system-level invariants (in-repo property harness;
//! proptest is not in the offline crate set).

use minimalist::config::{CircuitConfig, CoreGeometry};
use minimalist::coordinator::MixedSignalEngine;
use minimalist::energy::{worst_case_step_bound, EnergyMeter};
use minimalist::montecarlo::DeviceSweep;
use minimalist::nn::weights::synthetic_network;
use minimalist::nn::GoldenNetwork;
use minimalist::quant::{gate_transfer, Z6};
use minimalist::satsim::adc::{SarAdc, OFFSET_NEUTRAL};
use minimalist::satsim::caps::CapBank;
use minimalist::util::check;
use minimalist::util::rng::Rng;
use minimalist::{prop_assert, prop_close};

#[test]
fn charge_is_never_created() {
    check::property("charge conservation under mismatch", 200, |rng| {
        let mut cfg = CircuitConfig::default();
        cfg.sigma_c = 0.08;
        cfg.ideal = true; // noiseless share, mismatched caps
        let n = 2 + rng.below(62) as usize;
        let mut bank = CapBank::new(n, cfg.c_unit, &cfg, rng);
        for i in 0..n {
            bank.v[i] = rng.uniform_in(0.0, cfg.v_dd);
        }
        let idx: Vec<usize> = (0..n).collect();
        let q0 = bank.charge(&idx);
        let mut m = EnergyMeter::new();
        bank.share(&idx, None, &cfg, rng, &mut m);
        prop_close!(bank.charge(&idx), q0, 1e-24);
        Ok(())
    });
}

#[test]
fn adc_is_monotone_for_every_slope_and_offset() {
    check::property("ADC monotonicity", 60, |rng| {
        let cfg = CircuitConfig::ideal();
        let adc = SarAdc::new(&cfg, rng);
        let c_ext = rng.below(65) as f64 * cfg.c_unit;
        let off = rng.below(64) as u8;
        let mut last = 0u8;
        for i in 0..100 {
            let v = cfg.v_0 - 0.1 + 0.2 * i as f64 / 100.0;
            let code = adc.ideal_code(v, c_ext, off, &cfg);
            prop_assert!(code >= last, "non-monotone at sweep index {i}");
            last = code;
        }
        Ok(())
    });
}

#[test]
fn state_update_is_convex_everywhere() {
    check::property("convex state update", 500, |rng| {
        let z = Z6::new(rng.below(64) as u8);
        let h = rng.uniform_in(-1.5, 1.5) as f32;
        let ht = rng.uniform_in(-1.5, 1.5) as f32;
        let mixed = z.value() * ht + (1.0 - z.value()) * h;
        let lo = h.min(ht) - 1e-6;
        let hi = h.max(ht) + 1e-6;
        prop_assert!(mixed >= lo && mixed <= hi, "left convex hull: {mixed}");
        Ok(())
    });
}

#[test]
fn gate_transfer_matches_hard_sigmoid_grid() {
    check::property("gate transfer on 6-bit grid", 300, |rng| {
        let u = rng.uniform_in(-5.0, 5.0) as f32;
        let z = gate_transfer(u);
        let expect = ((u / 6.0 + 0.5).clamp(0.0, 1.0) * 63.0).round() as u8;
        prop_assert!(z.0 == expect, "u={u}: {} vs {expect}", z.0);
        Ok(())
    });
}

#[test]
fn simulated_energy_never_exceeds_bound_per_step() {
    // The analytic worst case must dominate the simulated energy for any
    // input activity — the definition of a bound.
    check::property("energy bound dominates", 8, |rng| {
        let cfg = CircuitConfig::default();
        let dims = [1usize, 24, 10];
        let nw = synthetic_network(&dims, rng.next_u64());
        let geometry = CoreGeometry { rows: 32, cols: 32 };
        let mut engine =
            MixedSignalEngine::new(nw, cfg.clone(), geometry).unwrap();
        let seq: Vec<f32> = (0..24).map(|_| rng.uniform() as f32).collect();
        engine.classify(&seq);
        let m = engine.energy();
        // per step, per core bound (engine cores have ≤32×32 synapses)
        let bound = engine.n_cores() as f64
            * worst_case_step_bound(&cfg, geometry.rows, geometry.cols);
        prop_assert!(
            m.per_step_j() <= bound,
            "simulated {} pJ/step exceeds bound {} pJ/step",
            m.per_step_j() * 1e12,
            bound * 1e12
        );
        Ok(())
    });
}

#[test]
fn extreme_noise_never_breaks_physics() {
    // Failure injection: pathological non-ideality settings must degrade
    // accuracy, never produce NaNs, out-of-rail voltages, or panics.
    check::property("extreme noise keeps invariants", 6, |rng| {
        let mut cfg = CircuitConfig::default();
        cfg.sigma_c = 0.2;               // 20 % mismatch
        cfg.sigma_comp_offset = 0.05;    // 50 mV comparator offset
        cfg.sigma_comp_noise = 0.02;
        cfg.c_inj = 1e-15;               // brutal injection
        cfg.temp_k = 500.0;
        cfg.seed = rng.next_u64();
        let nw = synthetic_network(&[1, 16, 10], rng.next_u64());
        let mut engine = MixedSignalEngine::new(
            nw,
            cfg,
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap();
        let seq: Vec<f32> = (0..32).map(|_| rng.uniform() as f32).collect();
        let label = engine.classify(&seq);
        prop_assert!(label < 10);
        for c in &engine.cores {
            for v in c.state_voltages() {
                prop_assert!(v.is_finite(), "non-finite state voltage");
                prop_assert!((-1.0..2.0).contains(&v), "state escaped: {v}");
            }
        }
        let m = engine.energy();
        prop_assert!(m.total_j().is_finite() && m.total_j() > 0.0);
        Ok(())
    });
}

#[test]
fn empty_and_degenerate_inputs() {
    // A zero-length sequence classifies from the reset state; a
    // constant-zero sequence stays near V_0 everywhere.
    let nw = synthetic_network(&[1, 8, 10], 5);
    let mut engine = MixedSignalEngine::new(
        nw.clone(),
        CircuitConfig::ideal(),
        CoreGeometry { rows: 8, cols: 16 },
    )
    .unwrap();
    let l0 = engine.classify(&[]);
    assert!(l0 < 10);
    engine.classify(&vec![0.0f32; 16]);
    // zero input → layer 0's imc = 0 every step → its state pinned at
    // V_0. (Deeper layers may legitimately move: units whose comparator
    // threshold sits below V_0 fire events on silence.)
    for v in engine.cores[0].state_voltages() {
        assert!((v - 0.4).abs() < 1e-6, "layer-0 state moved: {v}");
    }
}

#[test]
fn delta_skip_decisions_match_golden() {
    // The engine's per-component fire/skip decisions (ADR-005) must be
    // exactly the golden model's: same accumulating rule, same
    // threshold, same counting — checked on replication-free
    // single-layer placements, where the engine's layer input is the
    // raw frame we control.
    check::property("delta skip decisions, golden vs engine", 20, |rng| {
        let d = 2 + rng.below(10) as usize;
        let c = 2 + rng.below(8) as usize;
        let delta = rng.uniform_in(0.02, 0.5);
        let nw = synthetic_network(&[d, c], rng.next_u64());
        let mut engine = MixedSignalEngine::new(
            nw.clone(),
            CircuitConfig { delta, ..CircuitConfig::default() },
            CoreGeometry { rows: d, cols: 16 },
        )
        .unwrap();
        prop_assert!(
            engine.n_cores() == 1,
            "replication-free placement expected"
        );
        let mut golden = GoldenNetwork::with_delta(nw, delta);
        for t in 0..24u32 {
            // coarsely quantized frames make exact repeats (skips) and
            // sub-threshold drifts both common
            let x: Vec<f32> =
                (0..d).map(|_| rng.below(5) as f32 / 4.0).collect();
            engine.step(t, &x, None);
            golden.step(&x, None);
            let stats = engine.delta_stats();
            prop_assert!(
                stats.components_fired == golden.delta_fired
                    && stats.components_skipped == golden.delta_skipped,
                "step {t}: engine fired/skipped {}/{} vs golden {}/{}",
                stats.components_fired,
                stats.components_skipped,
                golden.delta_fired,
                golden.delta_skipped
            );
        }
        Ok(())
    });
}

#[test]
fn accuracy_never_improves_with_mismatch_on_average() {
    // Monte-Carlo monotonicity: over a fabricated device population,
    // growing the capacitor-mismatch σ from 0 to a brutal 10 % must not
    // help. Two statistics, aggregated over independent trials so one
    // lucky instance can't flip the verdict:
    //   (a) the label-flip rate against the ideal device is
    //       non-decreasing in σ in (almost) every trial — mismatch only
    //       adds perturbation;
    //   (b) the population-mean accuracy at σ=0 beats (or ties, within
    //       noise) the σ=10 % mean in aggregate.
    let mut flips_ordered = 0;
    let mut trials = 0;
    let mut acc_gap = 0.0;
    for trial in 0..4u64 {
        let sweep = DeviceSweep {
            instances: 8,
            mismatch_levels: vec![0.0, 0.1],
            samples: 4,
            img: 8,
            master_seed: 0xACC0 + trial,
            geometry: CoreGeometry { rows: 16, cols: 16 },
            ..DeviceSweep::default()
        };
        let nw = synthetic_network(&[1, 12, 10], 40 + trial);
        let r = sweep.run(&nw).unwrap();
        assert_eq!(r.levels.len(), 2);
        flips_ordered +=
            (r.levels[0].flip_rate <= r.levels[1].flip_rate + 1e-12) as usize;
        acc_gap += r.levels[0].acc_mean - r.levels[1].acc_mean;
        trials += 1;
    }
    assert!(
        flips_ordered * 4 >= trials * 3,
        "flip rate decreased with mismatch in {}/{trials} trials",
        trials - flips_ordered
    );
    assert!(
        acc_gap >= -0.05 * trials as f64,
        "mean accuracy improved with 10 % mismatch: aggregate gap {acc_gap}"
    );
}

#[test]
fn golden_and_engine_agree_on_most_classifications_ideal() {
    // statistical agreement over random networks and inputs
    let mut agree = 0;
    let mut total = 0;
    let mut rng = Rng::new(0xFEED);
    for trial in 0..6 {
        let dims = [1usize, 24, 10];
        let nw = synthetic_network(&dims, 100 + trial);
        let mut engine = MixedSignalEngine::new(
            nw.clone(),
            CircuitConfig::ideal(),
            CoreGeometry { rows: 48, cols: 48 },
        )
        .unwrap();
        let mut golden = GoldenNetwork::new(nw);
        for _ in 0..4 {
            let seq: Vec<f32> =
                (0..36).map(|_| rng.uniform() as f32).collect();
            agree += (engine.classify(&seq) == golden.classify(&seq)) as usize;
            total += 1;
        }
    }
    assert!(
        agree * 10 >= total * 7,
        "ideal engine agrees with golden on only {agree}/{total}"
    );
}
