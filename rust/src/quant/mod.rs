//! Quantized code types shared across the stack (mirror of
//! `python/compile/quant.py`) and the codesign mapping from trained
//! parameters to circuit configuration.
//!
//! Conventions (paper §3.1–3.2):
//! * 2-bit weight codes `w ∈ {0,1,2,3}` → effective value `(w−1.5)·scale`
//!   — the four equidistant rails `V_00..V_11` around `V_0`.
//! * 6-bit bias codes `b ∈ {−32..31}` → `b·scale`.
//! * 6-bit gate codes `z ∈ {0..63}` → `z/63`; the capacitor-swap count of
//!   a 64-cap bank is `k = round(z·64/63) ∈ {0..64}`.

pub mod codesign;

/// A 2-bit weight code (one SRAM cell of a synapse).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct W2(pub u8);

impl W2 {
    /// A 2-bit weight from code 0..3.
    pub fn new(code: u8) -> W2 {
        assert!(code < 4, "W2 code out of range: {code}");
        W2(code)
    }

    /// Quantize an fp weight (already divided by the per-tensor scale).
    pub fn from_scaled(w_over_scale: f32) -> W2 {
        let idx = (w_over_scale + 1.5).round().clamp(0.0, 3.0);
        W2(idx as u8)
    }

    /// Effective value in units of the per-tensor scale.
    pub fn value(self) -> f32 {
        self.0 as f32 - 1.5
    }
}

/// Per-tensor 2-bit quantization scale: mean(|w|) (python `weight_scale`).
pub fn weight_scale(w: &[f32]) -> f32 {
    let mean_abs = w.iter().map(|x| x.abs()).sum::<f32>() / w.len().max(1) as f32;
    mean_abs.max(1e-8)
}

/// A signed 6-bit bias code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct B6(pub i8);

impl B6 {
    /// A 6-bit bias from a signed code.
    pub fn new(code: i32) -> B6 {
        assert!((-32..=31).contains(&code), "B6 code out of range: {code}");
        B6(code as i8)
    }

    /// Quantize `b_over_scale` to the nearest 6-bit code.
    pub fn from_scaled(b_over_scale: f32) -> B6 {
        B6(b_over_scale.round().clamp(-32.0, 31.0) as i8)
    }

    /// The dequantized value.
    pub fn value(self) -> f32 {
        self.0 as f32
    }
}

/// Per-tensor 6-bit bias scale: code range covers max|b| (python
/// `bias_scale`; max-based so near-constant bias vectors survive).
pub fn bias_scale(b: &[f32]) -> f32 {
    let max_abs = b.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    (max_abs / 31.0).max(1e-8)
}

/// An unsigned 6-bit gate code (the SAR ADC output).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Z6(pub u8);

impl Z6 {
    /// A 6-bit gate code (0..63).
    pub fn new(code: u8) -> Z6 {
        assert!(code < 64, "Z6 code out of range: {code}");
        Z6(code)
    }

    /// Quantize a gate value z ∈ [0, 1].
    pub fn from_unit(z: f32) -> Z6 {
        Z6((z.clamp(0.0, 1.0) * 63.0).round() as u8)
    }

    /// Gate value in [0, 1].
    pub fn value(self) -> f32 {
        self.0 as f32 / 63.0
    }

    /// Number of capacitors to swap in a bank of `n_caps` (paper Eq. 1).
    pub fn swap_count(self, n_caps: usize) -> usize {
        ((self.0 as f32 / 63.0) * n_caps as f32).round() as usize
    }
}

/// The hard sigmoid σ^z (paper Eq. 5).
pub fn hard_sigmoid(u: f32) -> f32 {
    (u / 6.0 + 0.5).clamp(0.0, 1.0)
}

/// Hard sigmoid followed by 6-bit quantization — the logical transfer
/// function the SAR ADC implements (Fig 3).
pub fn gate_transfer(u: f32) -> Z6 {
    Z6::from_unit(hard_sigmoid(u))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn w2_codes_cover_levels() {
        assert_eq!(W2::from_scaled(-2.0).value(), -1.5);
        assert_eq!(W2::from_scaled(-0.6).value(), -0.5);
        assert_eq!(W2::from_scaled(0.4).value(), 0.5);
        assert_eq!(W2::from_scaled(9.0).value(), 1.5);
    }

    #[test]
    fn b6_clamps() {
        assert_eq!(B6::from_scaled(-100.0).0, -32);
        assert_eq!(B6::from_scaled(100.0).0, 31);
        assert_eq!(B6::from_scaled(2.4).0, 2);
    }

    #[test]
    fn z6_roundtrip_and_swap() {
        assert_eq!(Z6::from_unit(0.0).0, 0);
        assert_eq!(Z6::from_unit(1.0).0, 63);
        assert_eq!(Z6::from_unit(1.0).swap_count(64), 64);
        assert_eq!(Z6::from_unit(0.0).swap_count(64), 0);
        // z = 32/63 ≈ 0.508 → swap 33 of 64
        assert_eq!(Z6(32).swap_count(64), 33);
    }

    #[test]
    fn hard_sigmoid_matches_eq5() {
        assert_eq!(hard_sigmoid(-3.0), 0.0);
        assert_eq!(hard_sigmoid(3.0), 1.0);
        assert_eq!(hard_sigmoid(0.0), 0.5);
        assert!((hard_sigmoid(1.5) - 0.75).abs() < 1e-6);
    }

    #[test]
    fn quantizer_idempotent_property() {
        check::property("w2 idempotent", 200, |rng| {
            let x = rng.uniform_in(-4.0, 4.0) as f32;
            let q1 = W2::from_scaled(x).value();
            let q2 = W2::from_scaled(q1).value();
            crate::prop_close!(q1 as f64, q2 as f64, 1e-9);
            Ok(())
        });
        check::property("z6 idempotent + monotone", 200, |rng| {
            let a = rng.uniform() as f32;
            let b = rng.uniform() as f32;
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            crate::prop_assert!(Z6::from_unit(lo) <= Z6::from_unit(hi));
            let q = Z6::from_unit(a).value();
            crate::prop_close!(
                Z6::from_unit(q).value() as f64,
                q as f64,
                1e-9
            );
            Ok(())
        });
    }

    #[test]
    fn scales_match_python_conventions() {
        let w = [0.5f32, -1.0, 1.5, -2.0];
        assert!((weight_scale(&w) - 1.25).abs() < 1e-6);
        let b = [1.0f32, -4.0, 2.0, -1.0];
        // max|b| = 4, scale = 4/31; a constant vector must not collapse
        assert!((bias_scale(&b) - 4.0 / 31.0).abs() < 1e-6);
        let bc = [-4.0f32; 8];
        assert!((bias_scale(&bc) - 4.0 / 31.0).abs() < 1e-6);
    }
}
