//! Codesign mapping: trained `hw`-variant parameters → per-layer circuit
//! configuration (paper §3.2).
//!
//! The software model works in *logical* units: effective weights
//! `codes·scale`, IMC means, a gate pre-activation `u = α·imc + β` pushed
//! through the hard sigmoid, and a comparator threshold θ. The hardware
//! works in *volts*: rails at V_0 + (w−1.5)·Δw, an ADC whose slope and
//! offset realize α and β, a comparator reference realizing θ.
//!
//! Conversions (layer with weight scales s_h, s_z):
//!   V_col − V_0 = Δw·imc/s          (charge share of the rails)
//!   codes/volt  = (63/6)·α·s_z/Δw   (so that code = 63·hardsig(u))
//!   offset code = round(31.5 + 10.5·β)
//!   V_θ         = V_0 + θ·Δw/s_h
//!
//! The ADC slope is realized by choosing how many IMC caps stay connected
//! during conversion (`slope_m`); the achievable slopes are quantized by
//! the segment granularity, so the fitter reports the relative error —
//! an honest knob-vs-wish gap the mixed-signal trace test (Fig 4)
//! absorbs.

use anyhow::{bail, Result};

use crate::config::CircuitConfig;
use crate::nn::weights::LayerWeights;
use crate::quant::W2;
use crate::satsim::adc::SarAdc;
use crate::satsim::column::ColumnConfig;

/// Circuit realization of one trained layer. `columns` hold the *full*
/// logical column (replication included); for a row-split layer the
/// engine slices each column into the row ranges of its plan tiles.
#[derive(Debug, Clone)]
pub struct LayerCircuit {
    /// Full logical columns (replication applied).
    pub columns: Vec<ColumnConfig>,
    /// Row replication factor: a layer with n_in ≪ core rows is mapped
    /// with each logical input repeated r times across physical rows.
    /// The charge-share mean is invariant (identical rails replicated),
    /// but the state bank grows to r·n_in capacitors — restoring the
    /// fine swap granularity a 64-row column provides. This is how the
    /// 1-wide input layer of the paper's 1-64-… network occupies a full
    /// core column. Always 1 for row-split layers.
    pub replication: usize,
    /// Diagnostics: desired vs realized ADC slope (codes/V).
    pub slope_desired: f64,
    /// The slope realized by the segment-switch setting.
    pub slope_realized: f64,
}

impl LayerCircuit {
    /// Relative error of realized vs desired slope.
    pub fn slope_rel_error(&self) -> f64 {
        (self.slope_realized - self.slope_desired).abs() / self.slope_desired
    }
}

/// Snap a trained network to the circuit-realizable parameter grid:
/// the gate gain α is quantized by the ADC slope segments (one IMC cap
/// of C_unit per step), and the gate offset β by the ±3 range of the
/// 6-bit DAC pre-set. The returned network is what the hardware actually
/// computes — the software model of Fig 4 is evaluated on *these*
/// deployed parameters ("equivalent weights and biases").
pub fn snap_network(
    nw: &crate::nn::weights::NetworkWeights,
    cfg: &CircuitConfig,
    max_rows: usize,
) -> Result<crate::nn::weights::NetworkWeights> {
    let mut out = nw.clone();
    for lw in out.layers.iter_mut() {
        let lc = map_layer(lw, cfg, max_rows)?;
        // realized slope → realized α (inverse of the slope equation)
        lw.alpha =
            (lc.slope_realized * cfg.delta_w / (10.5 * lw.wz_scale as f64)) as f32;
        for b in lw.bz.iter_mut() {
            // offset code grid: round(31.5 + 10.5·β) → β = (code−31.5)/10.5
            let code = (31.5 + 10.5 * *b as f64).round().clamp(0.0, 63.0);
            *b = ((code - 31.5) / 10.5) as f32;
        }
    }
    Ok(out)
}

/// Map one layer's trained weights to column configurations under the
/// *default* planner policy. `max_rows` is the physical row count of
/// the target cores: narrow layers are row-replicated up to it, and
/// wider layers produce plain full-length columns that a
/// [`crate::mapping::Plan`] slices into row tiles (the ADC slope is
/// then realized on the owner tile, whose row count caps `slope_m`).
pub fn map_layer(lw: &LayerWeights, cfg: &CircuitConfig,
                 max_rows: usize) -> Result<LayerCircuit> {
    if max_rows == 0 {
        bail!("core geometry has zero rows");
    }
    let n = lw.n_in;
    let r = if n <= max_rows { (max_rows / n).max(1) } else { 1 };
    // physical rows of the owner tile: r·n for a replicated/unsplit
    // layer, the full core height for a row-split one
    map_layer_with(lw, cfg, r, r * n.min(max_rows))
}

/// Plan-aware layer mapping: `replication` and `slope_rows` (the owner
/// tile's physical row count — the segment budget available to realize
/// the ADC slope) come from a [`crate::mapping::LayerPlan`], so the
/// engine and the codesign fitter cannot disagree about either.
pub fn map_layer_with(
    lw: &LayerWeights,
    cfg: &CircuitConfig,
    replication: usize,
    slope_rows: usize,
) -> Result<LayerCircuit> {
    let (n, h) = (lw.n_in, lw.n_out);
    if lw.wh_codes.len() != n * h || lw.wz_codes.len() != n * h {
        bail!("weight plane shape mismatch");
    }
    if replication == 0 {
        bail!("zero replication factor");
    }
    let r = replication;
    let rows_phys = r * n;

    // -- ADC slope: codes/volt = 10.5·α·s_z/Δw --------------------------
    // (independent of the replication factor: the replicated mean equals
    // the logical mean)
    let slope_desired = 10.5 * lw.alpha as f64 * lw.wz_scale as f64 / cfg.delta_w;
    let c_ext_desired = SarAdc::c_ext_for_slope(slope_desired, cfg);
    // segment granularity: connected caps come in units of c_unit
    let m = ((c_ext_desired - cfg.c_line) / cfg.c_unit).round().max(0.0) as usize;
    let slope_m = m.min(slope_rows.min(rows_phys));
    let slope_realized = SarAdc::slope_codes_per_volt(
        slope_m as f64 * cfg.c_unit + cfg.c_line,
        cfg,
    );

    let mut columns = Vec::with_capacity(h);
    for j in 0..h {
        // column-major gather of the code planes (row-major [n, h]),
        // tiled r times across the physical rows
        let gather = |codes: &[i32]| -> Vec<W2> {
            let mut out = Vec::with_capacity(rows_phys);
            for _ in 0..r {
                for i in 0..n {
                    out.push(W2::new(codes[i * h + j] as u8));
                }
            }
            out
        };
        let w_h = gather(&lw.wh_codes);
        let w_z = gather(&lw.wz_codes);

        // -- ADC offset code: round(31.5 + 10.5·β) -----------------------
        let beta = lw.bz[j] as f64; // already 6-bit quantized in training
        let offset_code = (31.5 + 10.5 * beta).round().clamp(0.0, 63.0) as u8;

        // -- comparator reference: V_0 + θ·Δw/s_h ------------------------
        let theta = lw.bh[j] as f64;
        let v_theta = cfg.v_0 + theta * cfg.delta_w / lw.wh_scale as f64;

        columns.push(ColumnConfig { w_h, w_z, slope_m, offset_code, v_theta });
    }
    Ok(LayerCircuit { columns, replication: r, slope_desired, slope_realized })
}

/// Convert a simulated state voltage back to logical units (Fig 4 traces
/// compare in logical units).
pub fn volts_to_logical(v: f64, wh_scale: f32, cfg: &CircuitConfig) -> f64 {
    (v - cfg.v_0) * wh_scale as f64 / cfg.delta_w
}

/// Logical candidate/hidden value → the voltage the core would hold.
pub fn logical_to_volts(x: f64, wh_scale: f32, cfg: &CircuitConfig) -> f64 {
    cfg.v_0 + x * cfg.delta_w / wh_scale as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::weights::LayerWeights;

    fn toy_layer(n: usize, h: usize, alpha: f32) -> LayerWeights {
        LayerWeights {
            n_in: n,
            n_out: h,
            wh_codes: (0..n * h).map(|i| (i % 4) as i32).collect(),
            wz_codes: (0..n * h).map(|i| ((i + 1) % 4) as i32).collect(),
            wh_scale: 0.8,
            wz_scale: 0.9,
            bh: vec![0.1; h],
            bz: vec![-0.5; h],
            alpha,
            bh_raw: vec![0.1; h],
            bz_raw: vec![-0.5; h],
        }
    }

    #[test]
    fn map_produces_column_per_unit() {
        let cfg = CircuitConfig::default();
        let lc = map_layer(&toy_layer(16, 8, 10.0), &cfg, 16).unwrap();
        assert_eq!(lc.columns.len(), 8);
        assert_eq!(lc.columns[0].w_h.len(), 16);
    }

    #[test]
    fn slope_fit_reasonable() {
        let cfg = CircuitConfig::default();
        let lc = map_layer(&toy_layer(64, 8, 12.0), &cfg, 64).unwrap();
        assert!(
            lc.slope_rel_error() < 0.05,
            "slope err {} (desired {}, got {})",
            lc.slope_rel_error(),
            lc.slope_desired,
            lc.slope_realized
        );
    }

    #[test]
    fn offset_code_encodes_beta() {
        let cfg = CircuitConfig::default();
        let mut lw = toy_layer(8, 2, 5.0);
        lw.bz = vec![0.0, 3.0];
        let lc = map_layer(&lw, &cfg, 8).unwrap();
        assert_eq!(lc.columns[0].offset_code, 32); // β=0 → neutral
        assert_eq!(lc.columns[1].offset_code, 63); // β=+3 → full shift
    }

    #[test]
    fn row_split_layer_maps_with_plain_columns() {
        // input dim wider than the core rows: no replication, columns
        // keep the full logical length (the engine slices them per
        // tile), and the slope budget is capped by the owner tile
        let cfg = CircuitConfig::default();
        let lc = map_layer(&toy_layer(100, 4, 12.0), &cfg, 64).unwrap();
        assert_eq!(lc.replication, 1);
        assert_eq!(lc.columns.len(), 4);
        assert_eq!(lc.columns[0].w_h.len(), 100);
        assert!(lc.columns[0].slope_m <= 64);
    }

    #[test]
    fn volts_logical_roundtrip() {
        let cfg = CircuitConfig::default();
        for x in [-1.2, -0.3, 0.0, 0.7, 1.4] {
            let v = logical_to_volts(x, 0.8, &cfg);
            let back = volts_to_logical(v, 0.8, &cfg);
            assert!((back - x).abs() < 1e-12);
        }
    }
}
