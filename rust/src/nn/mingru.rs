//! Golden software model of the `hw`-variant MINIMALIST network in
//! logical units — the rust mirror of `python/compile/kernels/ref.py`.
//!
//! This is the arithmetic oracle the mixed-signal simulator is compared
//! against (Fig 4), and the fast reference path the coordinator can serve
//! from when no PJRT artifact is loaded.

use crate::nn::weights::{LayerWeights, NetworkWeights};
use crate::quant::{hard_sigmoid, Z6};

/// Number of final time steps averaged by the classifier head (mirror of
/// python `model.READOUT_STEPS`).
pub const READOUT_STEPS: usize = 8;

/// Per-layer recurrent state.
#[derive(Debug, Clone)]
pub struct LayerState {
    /// Hidden state, length n_out.
    pub h: Vec<f32>,
}

impl LayerState {
    /// All-zero state of width `n`.
    pub fn zeros(n: usize) -> LayerState {
        LayerState { h: vec![0.0; n] }
    }
}

/// Observables of one layer step (the Fig 4 trace quantities, logical).
#[derive(Debug, Clone)]
pub struct LayerTrace {
    /// Gate values.
    pub z: Vec<f32>,
    /// Candidate states.
    pub htilde: Vec<f32>,
    /// Updated hidden states.
    pub h: Vec<f32>,
    /// Readout/event outputs.
    pub y: Vec<f32>,
}

/// IMC projection (Eq. 6): out_j = (1/N)·Σ_i x_i·w_eff[i,j].
/// `w_eff` is row-major [n_in, n_out].
pub fn imc_matmul(x: &[f32], w_eff: &[f32], n_out: usize, out: &mut [f32]) {
    imc_matmul_partial(x, w_eff, n_out, (0, x.len()), out);
}

/// Partial IMC projection over the row slice [r0, r1) — what one row
/// tile of a split layer computes: out_j = (1/(r1−r0))·Σ_{i∈[r0,r1)}
/// x_i·w_eff[i,j]. `x` and `w_eff` are the *full* input frame and
/// weight plane; combining the slices with [`imc_combine`] reproduces
/// [`imc_matmul`] exactly (the charge-share semantics of shorting the
/// tiles' column lines together).
pub fn imc_matmul_partial(
    x: &[f32],
    w_eff: &[f32],
    n_out: usize,
    rows: (usize, usize),
    out: &mut [f32],
) {
    let (r0, r1) = rows;
    let n_in = x.len();
    debug_assert!(r0 < r1 && r1 <= n_in);
    debug_assert_eq!(w_eff.len(), n_in * n_out);
    debug_assert_eq!(out.len(), n_out);
    out.fill(0.0);
    for (i, &xi) in x[r0..r1].iter().enumerate().map(|(i, v)| (i + r0, v)) {
        if xi == 0.0 {
            continue; // event-coded input: skip silent rows
        }
        let row = &w_eff[i * n_out..(i + 1) * n_out];
        for (o, &w) in out.iter_mut().zip(row.iter()) {
            *o += xi * w;
        }
    }
    let inv_n = 1.0 / (r1 - r0) as f32;
    for o in out.iter_mut() {
        *o *= inv_n;
    }
}

/// Combine per-slice partial IMC means into the full-input mean with
/// row-count weights: out_j = Σ_t n_t·p_t[j] / Σ_t n_t — the weighted
/// average `(n₁·out₁ + n₂·out₂)/(n₁+n₂)` of shorted column lines.
pub fn imc_combine(partials: &[(usize, Vec<f32>)], out: &mut [f32]) {
    let n_total: usize = partials.iter().map(|(n, _)| n).sum();
    debug_assert!(n_total > 0, "imc_combine of zero rows");
    out.fill(0.0);
    for (n_rows, p) in partials {
        debug_assert_eq!(p.len(), out.len());
        let w = *n_rows as f32;
        for (o, &v) in out.iter_mut().zip(p.iter()) {
            *o += w * v;
        }
    }
    let inv = 1.0 / n_total as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// One hardware-exact layer step: IMC projections, 6-bit hard-sigmoid
/// gate, convex state update, comparator output. Mirrors
/// `ref.gate_update_ref` (the swap-granularity refinement of the satsim
/// is intentionally *not* modeled here — this is the software model the
/// paper's Fig 4 compares the circuit against).
pub fn layer_step(
    lw: &LayerWeights,
    wh_eff: &[f32],
    wz_eff: &[f32],
    x: &[f32],
    state: &mut LayerState,
    imc_h: &mut [f32],
    imc_z: &mut [f32],
) -> LayerTrace {
    let n_out = lw.n_out;
    imc_matmul(x, wh_eff, n_out, imc_h);
    imc_matmul(x, wz_eff, n_out, imc_z);
    let mut z = vec![0.0f32; n_out];
    let mut y = vec![0.0f32; n_out];
    for j in 0..n_out {
        let u = lw.alpha * imc_z[j] + lw.bz[j];
        let zq = Z6::from_unit(hard_sigmoid(u)).value();
        let h_new = zq * imc_h[j] + (1.0 - zq) * state.h[j];
        state.h[j] = h_new;
        z[j] = zq;
        y[j] = (h_new > lw.bh[j]) as u8 as f32;
    }
    LayerTrace { z, htilde: imc_h.to_vec(), h: state.h.clone(), y }
}

/// Full-network streaming evaluator (hardware-exact, logical units).
pub struct GoldenNetwork {
    /// The trained network being evaluated.
    pub weights: NetworkWeights,
    wh_eff: Vec<Vec<f32>>,
    wz_eff: Vec<Vec<f32>>,
    /// Per-layer recurrent state.
    pub states: Vec<LayerState>,
    /// readout accumulator: last READOUT_STEPS analog states of the head
    readout_ring: Vec<Vec<f32>>,
    ring_pos: usize,
    /// time steps since the last reset (readout normalization)
    steps_seen: usize,
    scratch_h: Vec<f32>,
    scratch_z: Vec<f32>,
    scratch_x: Vec<f32>,
    /// Delta-sparsity threshold mirroring `CircuitConfig::delta`
    /// (ADR-005): 0.0 = exact evaluation, the default.
    delta: f64,
    /// Per-layer last-*fired* input values (accumulating-delta
    /// trackers), NaN-seeded like the satsim cores' so the first step
    /// fires everything. Only maintained at `delta > 0`.
    x_last: Vec<Vec<f32>>,
    scratch_xeff: Vec<f32>,
    /// Cumulative delta accounting, comparable 1:1 with the engine's
    /// `DeltaCounters` components on an unreplicated single-layer plan
    /// (tests/properties.rs pins the skip decisions identical).
    pub delta_fired: u64,
    /// Components held under the delta threshold (see `delta_fired`).
    pub delta_skipped: u64,
}

impl GoldenNetwork {
    /// An evaluator over `weights`, state zeroed.
    pub fn new(weights: NetworkWeights) -> GoldenNetwork {
        GoldenNetwork::with_delta(weights, 0.0)
    }

    /// A golden network applying the accumulating-delta rule at
    /// threshold `delta` before every layer — the software counterpart
    /// of the engine's `CircuitConfig::delta` fast path, so
    /// engine-vs-golden parity can run at `delta > 0` too.
    pub fn with_delta(weights: NetworkWeights, delta: f64) -> GoldenNetwork {
        let wh_eff: Vec<Vec<f32>> =
            weights.layers.iter().map(|l| l.wh_eff()).collect();
        let wz_eff: Vec<Vec<f32>> =
            weights.layers.iter().map(|l| l.wz_eff()).collect();
        let states = weights
            .layers
            .iter()
            .map(|l| LayerState::zeros(l.n_out))
            .collect();
        let max_h = weights.dims.iter().copied().max().unwrap_or(1);
        let head = *weights.dims.last().unwrap();
        let x_last = (0..weights.n_layers())
            .map(|l| vec![f32::NAN; weights.dims[l]])
            .collect();
        GoldenNetwork {
            wh_eff,
            wz_eff,
            states,
            readout_ring: vec![vec![0.0; head]; READOUT_STEPS],
            ring_pos: 0,
            steps_seen: 0,
            scratch_h: vec![0.0; max_h],
            scratch_z: vec![0.0; max_h],
            scratch_x: vec![0.0; max_h],
            delta,
            x_last,
            scratch_xeff: vec![0.0; max_h],
            delta_fired: 0,
            delta_skipped: 0,
            weights,
        }
    }

    /// Zero all recurrent state and the readout ring.
    pub fn reset(&mut self) {
        for s in self.states.iter_mut() {
            s.h.fill(0.0);
        }
        for r in self.readout_ring.iter_mut() {
            r.fill(0.0);
        }
        self.ring_pos = 0;
        self.steps_seen = 0;
        for xl in self.x_last.iter_mut() {
            xl.fill(f32::NAN);
        }
    }

    /// One time step; `x` is the network input (dims[0] values).
    /// Returns the binary events of the last layer (rarely needed) via
    /// the trace of each layer if `traces` is Some.
    pub fn step(&mut self, x: &[f32], mut traces: Option<&mut Vec<LayerTrace>>) {
        debug_assert_eq!(x.len(), self.weights.dims[0]);
        let n_layers = self.weights.n_layers();
        self.scratch_x[..x.len()].copy_from_slice(x);
        let mut x_len = x.len();
        for l in 0..n_layers {
            // delta-sparsity mask (ADR-005): each layer input component
            // fires only when it moved past the threshold since the
            // value it last fired with; quiescent components hold that
            // last-fired value — the same accumulating-delta rule the
            // engine's cores apply per slot
            let x_in: &[f32] = if self.delta > 0.0 {
                let x_last = &mut self.x_last[l];
                for i in 0..x_len {
                    let xi = self.scratch_x[i];
                    if crate::config::delta_fires(
                        xi as f64,
                        x_last[i] as f64,
                        self.delta,
                    ) {
                        x_last[i] = xi;
                        self.delta_fired += 1;
                    } else {
                        self.delta_skipped += 1;
                    }
                    self.scratch_xeff[i] = x_last[i];
                }
                &self.scratch_xeff[..x_len]
            } else {
                &self.scratch_x[..x_len]
            };
            let lw = &self.weights.layers[l];
            let trace = layer_step(
                lw,
                &self.wh_eff[l],
                &self.wz_eff[l],
                x_in,
                &mut self.states[l],
                &mut self.scratch_h[..lw.n_out],
                &mut self.scratch_z[..lw.n_out],
            );
            self.scratch_x[..lw.n_out].copy_from_slice(&trace.y);
            x_len = lw.n_out;
            if let Some(ts) = traces.as_deref_mut() {
                ts.push(trace);
            }
        }
        // head readout ring: analog states of the last layer
        let head = &self.states[n_layers - 1].h;
        self.readout_ring[self.ring_pos].copy_from_slice(head);
        self.ring_pos = (self.ring_pos + 1) % READOUT_STEPS;
        self.steps_seen += 1;
    }

    /// Classifier logits after a sequence: mean of the last
    /// READOUT_STEPS head states plus the digital readout bias.
    /// Sequences shorter than READOUT_STEPS average only the steps
    /// actually seen — the ring's zero padding carries no weight.
    pub fn logits(&self) -> Vec<f32> {
        let head_lw = self.weights.layers.last().unwrap();
        let n = head_lw.n_out;
        let mut out = vec![0.0f32; n];
        for r in &self.readout_ring {
            for j in 0..n {
                out[j] += r[j];
            }
        }
        let denom = self.steps_seen.clamp(1, READOUT_STEPS) as f32;
        for j in 0..n {
            out[j] = out[j] / denom + head_lw.bh[j];
        }
        out
    }

    /// Run a full sequence (T × dims[0], row-major) and classify.
    pub fn classify(&mut self, x_seq: &[f32]) -> usize {
        let d_in = self.weights.dims[0];
        assert_eq!(x_seq.len() % d_in, 0);
        self.reset();
        for t in 0..x_seq.len() / d_in {
            self.step(&x_seq[t * d_in..(t + 1) * d_in], None);
        }
        argmax(&self.logits())
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::weights::synthetic_network;

    #[test]
    fn imc_mean_semantics() {
        // x = [1, 0, 1], w column of ones → (1+0+1)/3
        let x = [1.0, 0.0, 1.0];
        let w = [1.0, 1.0, 1.0]; // [3,1]
        let mut out = [0.0f32];
        imc_matmul(&x, &w, 1, &mut out);
        assert!((out[0] - 2.0 / 3.0).abs() < 1e-7);
    }

    #[test]
    fn partial_matmul_combines_to_full_mean() {
        // splitting the rows into k arbitrary slices and row-count
        // weighting the partial means reproduces the full IMC mean
        use crate::util::check;
        check::property("partial IMC combine == full matmul", 300, |rng| {
            let n_in = 2 + rng.below(96) as usize;
            let n_out = 1 + rng.below(24) as usize;
            let x: Vec<f32> =
                (0..n_in).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            let w: Vec<f32> = (0..n_in * n_out)
                .map(|_| rng.uniform_in(-1.2, 1.2) as f32)
                .collect();
            let mut full = vec![0.0f32; n_out];
            imc_matmul(&x, &w, n_out, &mut full);
            // k-way split at sorted random interior cut points
            let k = 1 + rng.below(5) as usize;
            let mut cuts: Vec<usize> =
                (0..k - 1).map(|_| 1 + rng.below(n_in as u64 - 1) as usize).collect();
            cuts.push(0);
            cuts.push(n_in);
            cuts.sort_unstable();
            cuts.dedup();
            let mut partials = Vec::new();
            for pair in cuts.windows(2) {
                let (r0, r1) = (pair[0], pair[1]);
                let mut p = vec![0.0f32; n_out];
                imc_matmul_partial(&x, &w, n_out, (r0, r1), &mut p);
                partials.push((r1 - r0, p));
            }
            let mut combined = vec![0.0f32; n_out];
            imc_combine(&partials, &mut combined);
            for j in 0..n_out {
                crate::prop_close!(combined[j] as f64, full[j] as f64, 1e-6);
            }
            Ok(())
        });
    }

    #[test]
    fn short_sequence_logits_average_only_seen_steps() {
        let nw = synthetic_network(&[2, 8], 3);
        let mut net = GoldenNetwork::new(nw);
        // 3 steps < READOUT_STEPS: the mean must be over 3 states
        let mut sum = vec![0.0f32; 8];
        for t in 0..3 {
            net.step(&[(t % 2) as f32, 1.0], None);
            for (s, &h) in sum.iter_mut().zip(net.states[0].h.iter()) {
                *s += h;
            }
        }
        let logits = net.logits();
        for j in 0..8 {
            let expect = sum[j] / 3.0 + net.weights.layers[0].bh[j];
            assert!(
                (logits[j] - expect).abs() < 1e-6,
                "logit {j}: {} vs {expect}",
                logits[j]
            );
        }
        // zero-length sequence: logits fall back to the bias alone
        net.reset();
        let l0 = net.logits();
        for j in 0..8 {
            assert_eq!(l0[j], net.weights.layers[0].bh[j]);
        }
    }

    #[test]
    fn state_is_convex_mixture_and_bounded() {
        let nw = synthetic_network(&[4, 8], 1);
        let mut net = GoldenNetwork::new(nw);
        for step in 0..100 {
            let x: Vec<f32> = (0..4).map(|i| ((step + i) % 2) as f32).collect();
            net.step(&x, None);
            for &h in &net.states[0].h {
                assert!(
                    h.abs() <= 1.5 * 0.8 + 1e-5,
                    "state escaped rail range: {h}"
                );
            }
        }
    }

    #[test]
    fn z6_quantization_visible_in_traces() {
        let nw = synthetic_network(&[4, 8], 2);
        let mut net = GoldenNetwork::new(nw);
        let mut traces = Vec::new();
        net.step(&[1.0, 0.0, 1.0, 1.0], Some(&mut traces));
        for &z in &traces[0].z {
            let code = (z * 63.0).round();
            assert!((z - code / 63.0).abs() < 1e-6, "z not on the 6-bit grid");
        }
    }

    #[test]
    fn classify_is_deterministic() {
        let nw = synthetic_network(&[1, 16, 10], 7);
        let mut net = GoldenNetwork::new(nw);
        let seq: Vec<f32> = (0..64).map(|t| (t % 5) as f32 / 4.0).collect();
        let a = net.classify(&seq);
        let b = net.classify(&seq);
        assert_eq!(a, b);
    }

    #[test]
    fn reset_clears_state() {
        let nw = synthetic_network(&[2, 8], 3);
        let mut net = GoldenNetwork::new(nw);
        net.step(&[1.0, 1.0], None);
        net.reset();
        assert!(net.states[0].h.iter().all(|&h| h == 0.0));
    }

    #[test]
    fn delta_zero_is_exact_and_nonzero_delta_skips() {
        let nw = synthetic_network(&[1, 16, 10], 7);
        let mut exact = GoldenNetwork::new(nw.clone());
        let mut zero = GoldenNetwork::with_delta(nw.clone(), 0.0);
        let mut sparse = GoldenNetwork::with_delta(nw, 0.2);
        let seq: Vec<f32> = (0..64).map(|t| (t % 5) as f32 / 4.0).collect();
        let a = exact.classify(&seq);
        assert_eq!(zero.classify(&seq), a);
        assert_eq!(zero.logits(), exact.logits());
        assert_eq!(
            zero.delta_fired + zero.delta_skipped,
            0,
            "delta=0 must bypass the tracker entirely"
        );
        let _ = sparse.classify(&seq);
        assert!(sparse.delta_skipped > 0, "binary hidden frames must skip");
        assert!(sparse.delta_fired > 0);
    }

    #[test]
    fn accumulating_delta_fires_on_drift_not_step_size() {
        // A slow ramp whose per-step move is under the threshold still
        // fires once the *accumulated* move since the last fire exceeds
        // it — the EdgeDRNN rule that bounds quantization drift. An
        // instantaneous-delta rule would never fire after the first
        // step here.
        let nw = synthetic_network(&[1, 8], 3);
        let mut net = GoldenNetwork::with_delta(nw, 0.25);
        for x in [0.0f32, 0.1, 0.2, 0.3] {
            net.step(&[x], None);
        }
        // layer 0 input: fires at x=0.0 (NaN seed) and x=0.3 (drift
        // 0.3 > 0.25); 0.1 and 0.2 stay quiescent
        let layer0_fired = 2;
        assert!(
            net.delta_fired >= layer0_fired,
            "fired {} < {layer0_fired}",
            net.delta_fired
        );
        // per-component accounting covers both layers each step
        assert_eq!(net.delta_fired + net.delta_skipped, 4 * (1 + 8));
    }
}
