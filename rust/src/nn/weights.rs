//! Trained-network checkpoint loading (the MTF files written by
//! `python/compile/train.py::export_checkpoint`).
//!
//! A checkpoint carries, per layer, the raw fp parameters *and* — for
//! quantized variants — the integer code planes + per-tensor scales that
//! become the SRAM images and the codesign inputs.

use anyhow::{bail, Context, Result};

use crate::io::tensorfile::TensorFile;

/// One layer of a trained `hw`-variant network, in the form the golden
/// model and the codesign mapping consume.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Logical input width.
    pub n_in: usize,
    /// Logical output width.
    pub n_out: usize,
    /// 2-bit code planes, row-major [n_in, n_out], values 0..3.
    pub wh_codes: Vec<i32>,
    /// Gate 2-bit code plane, row-major [n_in, n_out].
    pub wz_codes: Vec<i32>,
    /// Per-tensor weight scales (effective weight = (code−1.5)·scale).
    pub wh_scale: f32,
    /// Gate weight scale.
    pub wz_scale: f32,
    /// 6-bit-quantized biases in logical units (code·scale), length n_out.
    /// bh = comparator threshold θ (hidden layers) / digital readout bias.
    pub bh: Vec<f32>,
    /// bz = gate offset β (the ADC DAC offset).
    pub bz: Vec<f32>,
    /// Gate gain α (the ADC slope), shared per layer.
    pub alpha: f32,
    /// Unquantized fp biases (diagnostics / re-export).
    pub bh_raw: Vec<f32>,
    /// Unquantized fp gate biases (diagnostics / re-export).
    pub bz_raw: Vec<f32>,
}

impl LayerWeights {
    /// Effective fp weight matrices (row-major [n_in, n_out]).
    pub fn wh_eff(&self) -> Vec<f32> {
        self.wh_codes
            .iter()
            .map(|&c| (c as f32 - 1.5) * self.wh_scale)
            .collect()
    }

    /// Effective fp gate weights (row-major [n_in, n_out]).
    pub fn wz_eff(&self) -> Vec<f32> {
        self.wz_codes
            .iter()
            .map(|&c| (c as f32 - 1.5) * self.wz_scale)
            .collect()
    }
}

/// A full trained network.
#[derive(Debug, Clone)]
pub struct NetworkWeights {
    /// Layer widths, input first.
    pub dims: Vec<usize>,
    /// Training variant tag (e.g. `hw`).
    pub variant: String,
    /// Scale applied to the readout logits.
    pub logit_scale: f32,
    /// Per-layer quantized weights.
    pub layers: Vec<LayerWeights>,
}

impl NetworkWeights {
    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Load weights from a tensorfile at `path`.
    pub fn load(path: &str) -> Result<NetworkWeights> {
        let tf = TensorFile::load(path)?;
        Self::from_tensorfile(&tf)
    }

    /// Decode weights from a parsed tensorfile.
    pub fn from_tensorfile(tf: &TensorFile) -> Result<NetworkWeights> {
        let dims: Vec<usize> = tf
            .req("meta.dims")?
            .as_i32()?
            .iter()
            .map(|&d| d as usize)
            .collect();
        let variant_bytes = tf.req("meta.variant")?.as_i32()?;
        let variant: String = variant_bytes
            .iter()
            .take_while(|&&b| b != 0)
            .map(|&b| b as u8 as char)
            .collect();
        let logit_scale = tf.req("meta.logit_scale")?.scalar()?;
        if variant == "fp32" {
            bail!("fp32 checkpoints carry no code planes; the mixed-signal \
                   path requires a quantized variant (got '{variant}')");
        }
        let n_layers = dims.len() - 1;
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let pre = format!("l{l}.");
            let (n_in, n_out) = (dims[l], dims[l + 1]);
            let grab_codes = |k: &str| -> Result<Vec<i32>> {
                tf.req(&format!("{pre}{k}"))?
                    .as_i32()
                    .with_context(|| format!("layer {l} tensor {k}"))
            };
            let grab_scalar = |k: &str| -> Result<f32> {
                tf.req(&format!("{pre}{k}"))?.scalar()
            };
            let bh_codes = grab_codes("bh_codes")?;
            let bz_codes = grab_codes("bz_codes")?;
            let bh_scale = grab_scalar("bh_scale")?;
            let bz_scale = grab_scalar("bz_scale")?;
            let lw = LayerWeights {
                n_in,
                n_out,
                wh_codes: grab_codes("wh_codes")?,
                wz_codes: grab_codes("wz_codes")?,
                wh_scale: grab_scalar("wh_scale")?,
                wz_scale: grab_scalar("wz_scale")?,
                bh: bh_codes.iter().map(|&c| c as f32 * bh_scale).collect(),
                bz: bz_codes.iter().map(|&c| c as f32 * bz_scale).collect(),
                alpha: grab_scalar("alpha")?,
                bh_raw: tf.req(&format!("{pre}bh"))?.as_f32(),
                bz_raw: tf.req(&format!("{pre}bz"))?.as_f32(),
            };
            if lw.wh_codes.len() != n_in * n_out {
                bail!("layer {l}: wh_codes length {} != {}x{}",
                      lw.wh_codes.len(), n_in, n_out);
            }
            if lw.bh.len() != n_out || lw.bz.len() != n_out {
                bail!("layer {l}: bias length mismatch");
            }
            for &c in &lw.wh_codes {
                if !(0..4).contains(&c) {
                    bail!("layer {l}: invalid 2-bit code {c}");
                }
            }
            layers.push(lw);
        }
        Ok(NetworkWeights { dims, variant, logit_scale, layers })
    }
}

/// Build a deterministic synthetic network (for tests/benches that must
/// not depend on a training run having happened).
pub fn synthetic_network(dims: &[usize], seed: u64) -> NetworkWeights {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for l in 0..dims.len() - 1 {
        let (n_in, n_out) = (dims[l], dims[l + 1]);
        let codes = |rng: &mut Rng| -> Vec<i32> {
            (0..n_in * n_out).map(|_| rng.below(4) as i32).collect()
        };
        let biases = |rng: &mut Rng, lo: f64, hi: f64| -> Vec<f32> {
            (0..n_out).map(|_| rng.uniform_in(lo, hi) as f32).collect()
        };
        layers.push(LayerWeights {
            n_in,
            n_out,
            wh_codes: codes(&mut rng),
            wz_codes: codes(&mut rng),
            wh_scale: 0.8,
            wz_scale: 0.8,
            bh: biases(&mut rng, -0.05, 0.05),
            bz: biases(&mut rng, -1.5, 0.5),
            alpha: 6.0 * (n_in as f32).sqrt().max(1.0) / 4.0,
            bh_raw: vec![0.0; n_out],
            bz_raw: vec![0.0; n_out],
        });
    }
    NetworkWeights {
        dims: dims.to_vec(),
        variant: "hw".to_string(),
        logit_scale: 10.0,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tensorfile::{Tensor, TensorFile};

    fn toy_tf() -> TensorFile {
        let mut tf = TensorFile::new();
        let dims = vec![2usize, 3];
        tf.insert("meta.dims", Tensor::i32(vec![2], vec![2, 3]));
        tf.insert(
            "meta.variant",
            Tensor {
                shape: vec![8],
                data: crate::io::tensorfile::TensorData::U8(
                    b"hw\0\0\0\0\0\0".to_vec(),
                ),
            },
        );
        tf.insert("meta.logit_scale", Tensor::scalar_f32(10.0));
        let (n, h) = (dims[0], dims[1]);
        tf.insert("l0.wh", Tensor::f32(vec![n, h], vec![0.0; n * h]));
        tf.insert("l0.wz", Tensor::f32(vec![n, h], vec![0.0; n * h]));
        tf.insert("l0.bh", Tensor::f32(vec![h], vec![0.0; h]));
        tf.insert("l0.bz", Tensor::f32(vec![h], vec![0.0; h]));
        tf.insert("l0.alpha", Tensor::scalar_f32(5.0));
        tf.insert("l0.gamma", Tensor::scalar_f32(1.0));
        tf.insert("l0.wh_codes", Tensor::i32(vec![n, h], vec![0, 1, 2, 3, 1, 2]));
        tf.insert("l0.wh_scale", Tensor::scalar_f32(0.5));
        tf.insert("l0.wz_codes", Tensor::i32(vec![n, h], vec![3, 2, 1, 0, 2, 1]));
        tf.insert("l0.wz_scale", Tensor::scalar_f32(0.25));
        tf.insert("l0.bh_codes", Tensor::i32(vec![h], vec![-1, 0, 1]));
        tf.insert("l0.bh_scale", Tensor::scalar_f32(0.1));
        tf.insert("l0.bz_codes", Tensor::i32(vec![h], vec![-31, 0, 31]));
        tf.insert("l0.bz_scale", Tensor::scalar_f32(0.05));
        tf
    }

    #[test]
    fn loads_and_dequantizes() {
        let nw = NetworkWeights::from_tensorfile(&toy_tf()).unwrap();
        assert_eq!(nw.dims, vec![2, 3]);
        assert_eq!(nw.variant, "hw");
        let l = &nw.layers[0];
        assert_eq!(l.wh_eff()[0], -0.75); // (0−1.5)·0.5
        assert_eq!(l.wh_eff()[3], 0.75); // (3−1.5)·0.5
        assert!((l.bh[0] + 0.1).abs() < 1e-6);
        assert!((l.bz[2] - 31.0 * 0.05).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_codes() {
        let mut tf = toy_tf();
        tf.insert("l0.wh_codes", Tensor::i32(vec![2, 3], vec![0, 1, 2, 3, 1, 7]));
        assert!(NetworkWeights::from_tensorfile(&tf).is_err());
    }

    #[test]
    fn synthetic_network_valid() {
        let nw = synthetic_network(&[1, 16, 10], 3);
        assert_eq!(nw.layers.len(), 2);
        for l in &nw.layers {
            assert!(l.wh_codes.iter().all(|&c| (0..4).contains(&c)));
        }
    }
}
