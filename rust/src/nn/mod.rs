//! Software reference model + checkpoint handling.
//!
//! * [`weights`] — MTF checkpoint loading (code planes, scales, biases)
//! * [`mingru`] — the golden hardware-exact network in logical units

pub mod mingru;
pub mod weights;

pub use mingru::{argmax, GoldenNetwork, LayerTrace, READOUT_STEPS};
pub use weights::{synthetic_network, LayerWeights, NetworkWeights};
