//! # MINIMALIST — switched-capacitor in-memory computation of gated
//! recurrent units
//!
//! Full-system reproduction of Billaudelle, Kriener, et al. (2025):
//! a hardware-amenable minGRU architecture (2-bit weights, 6-bit biases,
//! binary activations, hard-sigmoid 6-bit gates) together with a
//! behavioral switched-capacitor implementation — charge-sharing IMC,
//! SAR-ADC gate digitization with tunable slope/offset, and the
//! capacitor-swap state update — plus the serving infrastructure around
//! it (event router, batched and streaming coordinator, PJRT runtime
//! for the AOT-compiled JAX reference model).
//!
//! ## Layer map
//!
//! * Layers 1/2 (python, build-time only): Pallas kernels + JAX model,
//!   trained and AOT-lowered to `artifacts/*.hlo.txt`.
//! * Layer 3 (this crate): everything on the request path.
//!
//! ## Module graph
//!
//! Physics, bottom-up: [`satsim`] resolves the charge-domain circuits
//! (cap banks → ADC → GRU columns → cores), [`router`] carries binary
//! events between cores, and [`energy`] accounts every cap event and
//! conversion. The model side: [`nn`] is the golden software network in
//! logical units plus checkpoint loading, [`quant`] holds the 2-/6-bit
//! code types and the codesign mapping from trained parameters to
//! circuit knobs, and [`mapping`] plans validated layer→core placements
//! ([`mapping::Plan`]) over a fixed [`config::CoreGeometry`]. Serving,
//! on top: [`coordinator`] executes plans on simulated cores
//! ([`coordinator::MixedSignalEngine`]) and serves them — batched
//! one-shot requests ([`coordinator::Server`]), streaming stateful
//! sessions ([`coordinator::StreamServer`]), and both over the wire
//! through a dependency-free HTTP/1.1 front end
//! ([`coordinator::HttpServer`]; wire contract in docs/http-api.md,
//! design in docs/adr/004, load generator in [`coordinator::loadgen`]);
//! [`runtime`] runs the AOT artifacts through PJRT (feature-gated);
//! [`montecarlo`] reuses the lockstep batch substrate to sweep
//! fabricated device populations (one instance per slot, ADR-008);
//! [`dataset`], [`io`], [`util`], [`bench_suite`], and [`config`]
//! supply data, containers, and knobs throughout.
//!
//! ## The two parity invariants
//!
//! Everything above the circuit level is pinned by two equivalences,
//! enforced as equality in the test suite:
//!
//! 1. **Engine ≡ golden** (physics vs arithmetic): an ideal-circuit
//!    [`coordinator::MixedSignalEngine`] tracks the exact
//!    [`nn::GoldenNetwork`] recurrence up to the capacitor-swap
//!    granularity, for unsplit, replicated, column-split, and row-split
//!    placements alike (engine tests, tests/row_split.rs).
//! 2. **Batched/streamed ≡ sequential** (serving vs physics): lockstep
//!    batches and frame-by-frame streaming sessions produce logits
//!    **bit-identical** to one-shot sequential classification, under
//!    full circuit noise — the slot-RNG seeding convention of
//!    docs/adr/001 (tests/batch_parity.rs, tests/stream_parity.rs).
//!
//! Architecture decision records live in `docs/adr/` (slot-RNG seeding,
//! lockstep batching, the streaming slot-lease design, the hand-rolled
//! HTTP front end); the wire protocol reference is `docs/http-api.md`.
//! The contracts no compiler checks — zero-alloc hot paths, RNG
//! draw-burn pairing, enum↔status↔docs lock step, panic hygiene — are
//! enforced statically by [`lint`] through the `repolint` binary
//! (docs/adr/006).

pub mod bench_suite;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod energy;
pub mod io;
pub mod lint;
pub mod mapping;
pub mod montecarlo;
pub mod nn;
pub mod quant;
pub mod router;
pub mod runtime;
pub mod satsim;
pub mod util;
