//! # MINIMALIST — switched-capacitor in-memory computation of gated
//! recurrent units
//!
//! Full-system reproduction of Billaudelle, Kriener, et al. (2025):
//! a hardware-amenable minGRU architecture (2-bit weights, 6-bit biases,
//! binary activations, hard-sigmoid 6-bit gates) together with a
//! behavioral switched-capacitor implementation — charge-sharing IMC,
//! SAR-ADC gate digitization with tunable slope/offset, and the
//! capacitor-swap state update — plus the serving infrastructure around
//! it (event router, multi-core coordinator, PJRT runtime for the
//! AOT-compiled JAX reference model).
//!
//! Layer map (see DESIGN.md):
//! * Layer 1/2 (python, build-time only): Pallas kernels + JAX model,
//!   AOT-lowered to `artifacts/*.hlo.txt`.
//! * Layer 3 (this crate): everything on the request path.

pub mod bench_suite;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod energy;
pub mod io;
pub mod mapping;
pub mod nn;
pub mod quant;
pub mod router;
pub mod runtime;
pub mod satsim;
pub mod util;
