//! Binary interchange with the python build path.

pub mod tensorfile;

pub use tensorfile::{Tensor, TensorData, TensorFile};
