//! MTF — the minimalist tensor file container (reader + writer).
//!
//! Byte-level mirror of `python/compile/export.py` (see that docstring for
//! the layout). Little-endian throughout; dtype codes:
//! 0=f32, 1=i32, 2=u8, 3=i64, 4=f64.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// File magic of the MTF container format.
pub const MAGIC: &[u8; 4] = b"MTF1";

/// One tensor: shape + flat data in one of the supported dtypes.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// The payload, in one of the supported dtypes.
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
/// Typed payload of a tensor.
pub enum TensorData {
    /// 32-bit floats.
    F32(Vec<f32>),
    /// 32-bit signed integers.
    I32(Vec<i32>),
    /// Bytes.
    U8(Vec<u8>),
    /// 64-bit signed integers.
    I64(Vec<i64>),
    /// 64-bit floats.
    F64(Vec<f64>),
}

impl Tensor {
    /// An f32 tensor.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    /// An i32 tensor.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    /// A rank-0 f32 tensor.
    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::f32(vec![1], vec![x])
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat f32 view (converting from any numeric dtype).
    pub fn as_f32(&self) -> Vec<f32> {
        match &self.data {
            TensorData::F32(v) => v.clone(),
            TensorData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::I64(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::F64(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    /// The data as i32s (integer dtypes only).
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        Ok(match &self.data {
            TensorData::I32(v) => v.clone(),
            TensorData::U8(v) => v.iter().map(|&x| x as i32).collect(),
            TensorData::I64(v) => v.iter().map(|&x| x as i32).collect(),
            _ => bail!("tensor is not integer-typed"),
        })
    }

    /// The value of a one-element tensor, as f32.
    pub fn scalar(&self) -> Result<f32> {
        let v = self.as_f32();
        if v.len() != 1 {
            bail!("expected scalar tensor, got shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    fn dtype_code(&self) -> u8 {
        match self.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
            TensorData::U8(_) => 2,
            TensorData::I64(_) => 3,
            TensorData::F64(_) => 4,
        }
    }
}

/// An ordered named-tensor container.
#[derive(Debug, Default, Clone)]
pub struct TensorFile {
    /// Ordered (name, tensor) pairs; `index` maps name → position.
    pub items: Vec<(String, Tensor)>,
    index: BTreeMap<String, usize>,
}

impl TensorFile {
    /// An empty container.
    pub fn new() -> TensorFile {
        TensorFile::default()
    }

    /// Add or replace tensor `name`.
    pub fn insert(&mut self, name: &str, t: Tensor) {
        if let Some(&i) = self.index.get(name) {
            self.items[i].1 = t;
        } else {
            self.index.insert(name.to_string(), self.items.len());
            self.items.push((name.to_string(), t));
        }
    }

    /// Look up tensor `name`.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.items[i].1)
    }

    /// Look up tensor `name`, erroring if absent.
    pub fn req(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .with_context(|| format!("tensor '{name}' missing from MTF file"))
    }

    /// Iterate the stored tensor names.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.items.iter().map(|(n, _)| n.as_str())
    }

    // -- serialization -----------------------------------------------------

    /// Serialize the container to MTF bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.items.len() as u32).to_le_bytes());
        for (name, t) in &self.items {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            out.push(t.dtype_code());
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match &t.data {
                TensorData::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::I32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::U8(v) => out.extend_from_slice(v),
                TensorData::I64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::F64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Parse an MTF byte buffer.
    pub fn from_bytes(buf: &[u8]) -> Result<TensorFile> {
        if buf.len() < 8 || &buf[..4] != MAGIC {
            bail!("not an MTF file (bad magic)");
        }
        let count = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        let mut off = 8usize;
        let mut tf = TensorFile::new();
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > buf.len() {
                bail!("truncated MTF file at byte {}", *off);
            }
            let s = &buf[*off..*off + n];
            *off += n;
            Ok(s)
        };
        for _ in 0..count {
            let nlen =
                u16::from_le_bytes(take(&mut off, 2)?.try_into()?) as usize;
            let name = std::str::from_utf8(take(&mut off, nlen)?)?.to_string();
            let dtype = take(&mut off, 1)?[0];
            let ndim = take(&mut off, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(
                    u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize,
                );
            }
            let n: usize = shape.iter().product();
            let data = match dtype {
                0 => TensorData::F32(
                    take(&mut off, n * 4)?
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                1 => TensorData::I32(
                    take(&mut off, n * 4)?
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                2 => TensorData::U8(take(&mut off, n)?.to_vec()),
                3 => TensorData::I64(
                    take(&mut off, n * 8)?
                        .chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                4 => TensorData::F64(
                    take(&mut off, n * 8)?
                        .chunks_exact(8)
                        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                d => bail!("unknown MTF dtype code {d}"),
            };
            tf.insert(&name, Tensor { shape, data });
        }
        Ok(tf)
    }

    /// Write the container to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref()).with_context(|| {
            format!("creating {}", path.as_ref().display())
        })?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Read a container from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<TensorFile> {
        let buf = std::fs::read(path.as_ref()).with_context(|| {
            format!("reading {}", path.as_ref().display())
        })?;
        Self::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let mut tf = TensorFile::new();
        tf.insert("a", Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5]));
        tf.insert("b", Tensor::i32(vec![4], vec![-1, 0, 1, 2]));
        tf.insert(
            "c",
            Tensor { shape: vec![3], data: TensorData::U8(vec![7, 8, 9]) },
        );
        tf.insert(
            "d",
            Tensor { shape: vec![2], data: TensorData::I64(vec![-5, 5]) },
        );
        tf.insert(
            "e",
            Tensor { shape: vec![1], data: TensorData::F64(vec![0.25]) },
        );
        let back = TensorFile::from_bytes(&tf.to_bytes()).unwrap();
        for (name, t) in &tf.items {
            assert_eq!(back.get(name).unwrap(), t, "{name}");
        }
        assert_eq!(back.items.len(), 5);
    }

    #[test]
    fn insertion_order_preserved() {
        let mut tf = TensorFile::new();
        tf.insert("z", Tensor::scalar_f32(1.0));
        tf.insert("a", Tensor::scalar_f32(2.0));
        let names: Vec<_> = tf.names().collect();
        assert_eq!(names, vec!["z", "a"]);
    }

    #[test]
    fn insert_overwrites() {
        let mut tf = TensorFile::new();
        tf.insert("x", Tensor::scalar_f32(1.0));
        tf.insert("x", Tensor::scalar_f32(9.0));
        assert_eq!(tf.get("x").unwrap().scalar().unwrap(), 9.0);
        assert_eq!(tf.items.len(), 1);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(TensorFile::from_bytes(b"NOPE0000").is_err());
        let mut tf = TensorFile::new();
        tf.insert("a", Tensor::f32(vec![8], vec![0.0; 8]));
        let bytes = tf.to_bytes();
        assert!(TensorFile::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn scalar_accessor() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.scalar().unwrap(), 3.5);
        let t2 = Tensor::f32(vec![2], vec![1.0, 2.0]);
        assert!(t2.scalar().is_err());
    }
}
