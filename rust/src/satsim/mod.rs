//! Behavioral switched-capacitor simulator — the substitution for the
//! paper's Cadence Spectre AMS mixed-signal verification (§4).
//!
//! Everything the MINIMALIST cores do is charge-domain arithmetic:
//! pre-charge capacitors to rail voltages, short groups of capacitors,
//! strobe a comparator. This module resolves exactly that, with the
//! physically relevant non-idealities: capacitor mismatch, kT/C sampling
//! noise, switch charge injection, line parasitics, comparator offset and
//! noise, DAC mismatch in the SAR ADC.
//!
//! Module map:
//! * [`caps`] — capacitor banks + charge-conserving share (the primitive)
//! * [`adc`] — clocked comparator and the 6-bit SAR ADC with the paper's
//!   slope/offset tuning (Fig 3)
//! * [`column`] — one GRU unit: synapse caps, swap-update, output event
//! * [`core`] — the R×C array (one GRU block or a slice of one)

pub mod adc;
pub mod caps;
pub mod column;
pub mod core;

pub use self::core::{Core, CoreStep, DeltaCounters};
pub use adc::{Comparator, SarAdc, ADC_BITS, ADC_CODES, OFFSET_NEUTRAL};
pub use caps::CapBank;
pub use column::{Column, ColumnConfig, ColumnStep};
