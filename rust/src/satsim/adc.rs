//! Clocked comparator and 6-bit charge-redistribution SAR ADC with the
//! paper's two tuning knobs (Fig 3):
//!
//! * **slope** — the IMC sampling capacitors *stay connected* to the
//!   comparator input during successive approximation, attenuating every
//!   DAC step by C_DAC/(C_DAC + C_IMC^conn). Disconnecting binary-scaled
//!   segments of the array after the charge share tunes this ratio and
//!   with it the ADC's dynamic range — i.e. the gain of the realized
//!   hard-sigmoid.
//! * **offset** — during sampling the DAC bottom plates are pre-set to a
//!   6-bit offset code; conversion then starts from 0b100000, shifting
//!   the transfer characteristic by up to ± half the range.
//!
//! The conversion is simulated decision-by-decision (six comparator
//! strobes with per-instance offset and per-decision noise, DAC cap
//! mismatch included), not as a closed-form quantizer — Fig 3C's
//! characteristics emerge from the physics.

use crate::config::CircuitConfig;
use crate::energy::EnergyMeter;
use crate::util::rng::Rng;

/// Clocked comparator with input-referred offset (static, mismatch) and
/// noise (per decision).
#[derive(Debug, Clone)]
pub struct Comparator {
    /// Input-referred offset voltage.
    pub offset_v: f64,
}

impl Comparator {
    /// Draw a comparator with mismatch-sampled offset.
    pub fn new(cfg: &CircuitConfig, rng: &mut Rng) -> Comparator {
        let offset_v = if cfg.ideal {
            0.0
        } else {
            rng.normal_scaled(0.0, cfg.sigma_comp_offset)
        };
        Comparator { offset_v }
    }

    /// Strobe: returns v_pos > v_neg (with offset + noise).
    #[inline]
    pub fn decide(
        &self,
        v_pos: f64,
        v_neg: f64,
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> bool {
        meter.comparator();
        let noise = if cfg.ideal {
            0.0
        } else {
            rng.normal_scaled(0.0, cfg.sigma_comp_noise)
        };
        v_pos - v_neg + self.offset_v + noise > 0.0
    }
}

/// 6-bit SAR ADC channel. One per GRU column (z path); its comparator is
/// re-used for the binary output activation (paper §3.1.4).
#[derive(Debug, Clone)]
pub struct SarAdc {
    /// Binary-weighted DAC caps for bits 0..5 (c_adc_unit·2^bit, with
    /// mismatch) plus one terminating unit cap → total ≈ 64 units.
    dac_c: [f64; 6],
    c_term: f64,
    /// The decision comparator.
    pub comparator: Comparator,
}

/// SAR resolution in bits.
pub const ADC_BITS: u32 = 6;
/// Number of output codes (2^bits).
pub const ADC_CODES: u32 = 64;
/// Neutral offset code: input = V_0 maps to mid-scale (hardsig(0)=0.5).
pub const OFFSET_NEUTRAL: u8 = 32;

impl SarAdc {
    /// Draw an ADC instance with mismatch-sampled DAC caps.
    pub fn new(cfg: &CircuitConfig, rng: &mut Rng) -> SarAdc {
        let sigma = if cfg.ideal { 0.0 } else { cfg.sigma_c };
        let mut dac_c = [0.0; 6];
        for (bit, c) in dac_c.iter_mut().enumerate() {
            let nominal = cfg.c_adc_unit * (1 << bit) as f64;
            // mismatch σ scales with 1/sqrt(area) ⇒ relative σ / sqrt(2^bit)
            let rel = sigma / ((1u64 << bit) as f64).sqrt();
            *c = nominal * (1.0 + rel * rng.normal()).max(0.1);
        }
        let c_term = cfg.c_adc_unit * (1.0 + sigma * rng.normal()).max(0.1);
        SarAdc { dac_c, c_term, comparator: Comparator::new(cfg, rng) }
    }

    /// Total DAC capacitance (loads the shared node during conversion).
    pub fn c_dac(&self) -> f64 {
        self.dac_c.iter().sum::<f64>() + self.c_term
    }

    /// Weighted capacitance of the bits set in `code`.
    fn w(&self, code: u8) -> f64 {
        let mut acc = 0.0;
        for bit in 0..6 {
            if code & (1 << bit) != 0 {
                acc += self.dac_c[bit];
            }
        }
        acc
    }

    /// Convert the voltage `v_col` sitting on an external capacitance
    /// `c_ext` (the still-connected IMC segment + line parasitics).
    ///
    /// `offset_code` is the 6-bit DAC pre-set (OFFSET_NEUTRAL = no shift).
    /// Returns the 6-bit output code.
    ///
    /// Node equation: switching the DAC bottom plates from the offset
    /// pattern `o` to the trial pattern `t` moves the input node by
    /// ΔV = −V_ref·(W(t) − W(o))/C_tot, so larger input voltages sustain
    /// larger trial codes — code grows with (v_col − V_0) at a slope of
    /// C_tot/(c_adc_unit·V_ref) codes per volt.
    pub fn convert(
        &self,
        v_col: f64,
        c_ext: f64,
        offset_code: u8,
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> u8 {
        debug_assert!(offset_code < 64);
        let c_tot = self.c_dac() + c_ext;
        let v_ref = cfg.v_dd;
        let w_off = self.w(offset_code);
        let mut code: u8 = 0;
        for bit in (0..6).rev() {
            let trial = code | (1 << bit);
            let v_node = v_col - v_ref * (self.w(trial) - w_off) / c_tot;
            // keep the bit while the node stays above the common mode
            if self.comparator.decide(v_node, cfg.v_0, cfg, rng, meter) {
                code = trial;
            }
            // bottom-plate switching energy for this trial
            meter.cap_charge(self.dac_c[bit], 0.0, v_ref);
            meter.toggles(cfg, 1);
        }
        meter.adc_conversion();
        code
    }

    /// Ideal (noise-free) transfer for analysis: the code the SAR would
    /// produce with a perfect comparator. Used by the codesign fitter.
    pub fn ideal_code(&self, v_col: f64, c_ext: f64, offset_code: u8,
                      cfg: &CircuitConfig) -> u8 {
        let c_tot = self.c_dac() + c_ext;
        let v_ref = cfg.v_dd;
        let w_off = self.w(offset_code);
        let mut code: u8 = 0;
        for bit in (0..6).rev() {
            let trial = code | (1 << bit);
            let v_node = v_col - v_ref * (self.w(trial) - w_off) / c_tot;
            if v_node > cfg.v_0 {
                code = trial;
            }
        }
        code
    }

    /// Analytic slope in codes/volt (nominal, ignoring mismatch).
    pub fn slope_codes_per_volt(c_ext: f64, cfg: &CircuitConfig) -> f64 {
        let c_dac = 64.0 * cfg.c_adc_unit;
        (c_dac + c_ext) / (cfg.c_adc_unit * cfg.v_dd)
    }

    /// Invert `slope_codes_per_volt`: the external capacitance needed for
    /// a desired slope (may be negative → slope unreachable, clamp to 0).
    pub fn c_ext_for_slope(slope: f64, cfg: &CircuitConfig) -> f64 {
        (slope * cfg.c_adc_unit * cfg.v_dd - 64.0 * cfg.c_adc_unit).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(ideal: bool) -> (SarAdc, CircuitConfig, Rng, EnergyMeter) {
        let cfg = if ideal { CircuitConfig::ideal() } else { CircuitConfig::default() };
        let mut rng = Rng::new(21);
        let adc = SarAdc::new(&cfg, &mut rng);
        (adc, cfg, rng, EnergyMeter::new())
    }

    #[test]
    fn neutral_offset_maps_v0_to_midscale() {
        let (adc, cfg, mut rng, mut m) = setup(true);
        let code = adc.convert(cfg.v_0, 0.0, OFFSET_NEUTRAL, &cfg, &mut rng, &mut m);
        assert!((31..=32).contains(&code), "code = {code}");
    }

    #[test]
    fn transfer_is_monotone_in_input() {
        let (adc, cfg, mut rng, mut m) = setup(true);
        let c_ext = 20.0 * cfg.c_unit;
        let mut last = 0u8;
        for i in 0..200 {
            let v = cfg.v_0 - 0.05 + 0.1 * (i as f64) / 200.0;
            let code = adc.convert(v, c_ext, OFFSET_NEUTRAL, &cfg, &mut rng, &mut m);
            assert!(code >= last, "non-monotone at step {i}");
            last = code;
        }
        assert_eq!(last, 63, "range should saturate");
    }

    #[test]
    fn slope_grows_with_connected_caps() {
        let (adc, cfg, mut rng, mut m) = setup(true);
        let slope = |c_ext: f64, rng: &mut Rng, m: &mut EnergyMeter| {
            let dv = 0.01;
            let lo = adc.convert(cfg.v_0 - dv, c_ext, OFFSET_NEUTRAL, &cfg, rng, m) as f64;
            let hi = adc.convert(cfg.v_0 + dv, c_ext, OFFSET_NEUTRAL, &cfg, rng, m) as f64;
            (hi - lo) / (2.0 * dv)
        };
        let s_small = slope(4.0 * cfg.c_unit, &mut rng, &mut m);
        let s_large = slope(40.0 * cfg.c_unit, &mut rng, &mut m);
        assert!(
            s_large > 2.0 * s_small,
            "slopes: {s_small} vs {s_large} codes/V"
        );
        // and they should match the analytic expression within quantization
        let s_pred = SarAdc::slope_codes_per_volt(40.0 * cfg.c_unit, &cfg);
        assert!(
            (s_large / s_pred - 1.0).abs() < 0.2,
            "measured {s_large}, predicted {s_pred}"
        );
    }

    #[test]
    fn offset_code_shifts_transfer() {
        let (adc, cfg, mut rng, mut m) = setup(true);
        let c_ext = 10.0 * cfg.c_unit;
        let at_v0 = |off: u8, rng: &mut Rng, m: &mut EnergyMeter| {
            adc.convert(cfg.v_0, c_ext, off, &cfg, rng, m)
        };
        let lo = at_v0(8, &mut rng, &mut m);
        let mid = at_v0(OFFSET_NEUTRAL, &mut rng, &mut m);
        let hi = at_v0(56, &mut rng, &mut m);
        assert!(lo < mid && mid < hi, "{lo} {mid} {hi}");
        // the pre-set code itself is the code at V_0 (paper Fig 3C)
        assert!((lo as i32 - 8).abs() <= 1);
        assert!((hi as i32 - 56).abs() <= 1);
    }

    #[test]
    fn noisy_conversion_is_close_to_ideal() {
        let (adc, cfg, mut rng, mut m) = setup(false);
        let c_ext = 20.0 * cfg.c_unit;
        let mut worst = 0i32;
        for i in 0..50 {
            let v = cfg.v_0 - 0.02 + 0.04 * (i as f64) / 50.0;
            let noisy =
                adc.convert(v, c_ext, OFFSET_NEUTRAL, &cfg, &mut rng, &mut m) as i32;
            let ideal = adc.ideal_code(v, c_ext, OFFSET_NEUTRAL, &cfg) as i32;
            worst = worst.max((noisy - ideal).abs());
        }
        assert!(worst <= 3, "worst |Δcode| = {worst}");
    }

    #[test]
    fn energy_and_counters_logged() {
        let (adc, cfg, mut rng, mut m) = setup(true);
        adc.convert(cfg.v_0, 0.0, OFFSET_NEUTRAL, &cfg, &mut rng, &mut m);
        assert_eq!(m.adc_conversions, 1);
        assert_eq!(m.comparator_decisions, 6);
        assert!(m.cap_energy_j > 0.0);
    }
}
