//! Capacitor banks and charge-sharing arithmetic — the primitive every
//! MINIMALIST operation reduces to.
//!
//! Physics: shorting a set of capacitors {C_i, V_i}
//! settles, by charge conservation, at V = Σ C_i·V_i / Σ C_i. Mismatch
//! makes C_i = C_unit·(1+ε_i); sampling adds kT/C noise; turning a
//! transmission gate off injects a deterministic channel-charge kick.

use crate::config::CircuitConfig;
use crate::energy::EnergyMeter;
use crate::util::rng::Rng;

/// Explicit lane width of the vectorized hot-loop reductions (ADR-007).
/// Energy sums accumulate into `LANES` independent partial accumulators
/// over fixed-stride chunks, then collapse through [`lane_sum`]'s fixed
/// pairwise tree — the reassociation that lets the compiler keep the
/// loop in vector registers while the result stays deterministic (the
/// same value on every run and every thread count).
pub const LANES: usize = 8;

/// Deterministic pairwise collapse of the `LANES` partial accumulators.
#[inline]
fn lane_sum(e: &[f64; LANES]) -> f64 {
    ((e[0] + e[4]) + (e[1] + e[5])) + ((e[2] + e[6]) + (e[3] + e[7]))
}

/// A bank of capacitors with individual (mismatched) capacitances and
/// per-capacitor top-plate voltages.
#[derive(Debug, Clone)]
pub struct CapBank {
    /// Per-capacitor capacitances (farads).
    pub c: Vec<f64>,
    /// Per-capacitor top-plate voltages.
    pub v: Vec<f64>,
    /// Cached per-cap kT/C sampling noise σ (capacitances are fixed at
    /// construction, so the sqrt is hoisted out of the hot loop).
    ktc: Vec<f64>,
    /// Cached per-cap charge-injection kick −½·C_inj·V_DD/C.
    inj: Vec<f64>,
    /// Cached switch-gate energy per toggle (C_gate·V_DD²).
    gate_e: f64,
}

impl CapBank {
    /// Build a bank of `n` caps of nominal value `c_nom`, drawing the
    /// mismatch from `rng` (σ relative = cfg.sigma_c unless ideal).
    pub fn new(n: usize, c_nom: f64, cfg: &CircuitConfig, rng: &mut Rng) -> CapBank {
        let sigma = if cfg.ideal { 0.0 } else { cfg.sigma_c };
        let c: Vec<f64> = (0..n)
            .map(|_| c_nom * (1.0 + sigma * rng.normal()).max(0.5))
            .collect();
        let ktc = c.iter().map(|&ci| cfg.ktc_sigma(ci)).collect();
        let inj = c
            .iter()
            .map(|&ci| {
                if cfg.ideal { 0.0 } else { -0.5 * cfg.c_inj * cfg.v_dd / ci }
            })
            .collect();
        CapBank {
            c,
            v: vec![cfg.v_0; n],
            ktc,
            inj,
            gate_e: cfg.c_gate * cfg.v_dd * cfg.v_dd,
        }
    }

    /// Swap this bank's *device identity* — capacitances and the derived
    /// kT/C and injection caches — with externally held vectors, leaving
    /// the top-plate voltages (analog state) in place. This is the
    /// per-slot Monte-Carlo device-swap primitive (ADR-008): a batch
    /// slot carrying its own fabricated device instance swaps its cap
    /// population in on `bind_slot` and back out on the next swap. The
    /// gate energy cache is config-derived (identical across devices)
    /// and stays put. Three `mem::swap`s — allocation-free, O(1).
    pub fn swap_device(
        &mut self,
        c: &mut Vec<f64>,
        ktc: &mut Vec<f64>,
        inj: &mut Vec<f64>,
    ) {
        debug_assert_eq!(c.len(), self.c.len());
        std::mem::swap(&mut self.c, c);
        std::mem::swap(&mut self.ktc, ktc);
        std::mem::swap(&mut self.inj, inj);
    }

    /// Move the bank's device identity out (capacitances plus the
    /// derived kT/C and injection caches), consuming the bank. Used
    /// once per provisioned slot to turn a freshly constructed bank
    /// into a [`ColumnDevice`](crate::satsim::column::ColumnDevice)
    /// payload.
    pub fn into_device_parts(self) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        (self.c, self.ktc, self.inj)
    }

    /// Number of capacitors.
    pub fn len(&self) -> usize {
        self.c.len()
    }

    /// Whether the bank is empty.
    pub fn is_empty(&self) -> bool {
        self.c.is_empty()
    }

    /// Sample capacitor `i` onto the rail voltage `v_rail`: charge through
    /// the selected transmission gate, accumulate the dissipated energy,
    /// then add kT/C noise and the turn-off charge injection.
    pub fn sample(
        &mut self,
        i: usize,
        v_rail: f64,
        _cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) {
        let c = self.c[i];
        meter.cap_charge(c, self.v[i], v_rail);
        // select switch on + off (gate energy pre-multiplied)
        meter.toggles_cached(2, self.gate_e);
        let s = self.ktc[i];
        let noise = if s > 0.0 { s * rng.normal_fast() } else { 0.0 };
        // NMOS-dominated turn-off: half the channel charge kicks the
        // sampled node downward (deterministic sign) — cached per cap.
        self.v[i] = v_rail + noise + self.inj[i];
    }

    /// Noise-deferred sampling for caps that are *immediately shorted*
    /// afterwards (the P1→P2 pattern of every column phase): the
    /// per-cap kT/C draws and injection kicks are exactly equivalent —
    /// the share node only ever sees their capacitance-weighted mean —
    /// to one aggregated draw applied at the share
    /// (`aggregate_sample_sigma` / `aggregate_injection_shift`).
    /// Removes tens of thousands of Gaussian draws per core step.
    #[inline]
    pub fn sample_deferred(&mut self, i: usize, v_rail: f64,
                           meter: &mut EnergyMeter) {
        meter.cap_charge(self.c[i], self.v[i], v_rail);
        meter.toggles_cached(2, self.gate_e);
        self.v[i] = v_rail;
    }

    /// Lane variant of [`CapBank::sample_deferred`] over the gathered
    /// cap set `idx` (cap `idx[k]` charges to `rails[k]`): a fixed-stride
    /// chunked loop with no per-element branches — the charge-event
    /// energies accumulate into [`LANES`] partial sums and the meter is
    /// updated once, hoisted out of the loop. Replaces N calls of the
    /// scalar helper in the column P1 phase (ADR-007).
    pub fn sample_deferred_lane(
        &mut self,
        idx: &[usize],
        rails: &[f64],
        meter: &mut EnergyMeter,
    ) {
        let n = idx.len();
        debug_assert_eq!(rails.len(), n);
        let mut e = [0.0f64; LANES];
        let chunks = n / LANES;
        for ch in 0..chunks {
            for l in 0..LANES {
                let k = ch * LANES + l;
                let i = idx[k];
                let dv = rails[k] - self.v[i];
                e[l] += 0.5 * self.c[i] * dv * dv;
                self.v[i] = rails[k];
            }
        }
        for k in chunks * LANES..n {
            let i = idx[k];
            let dv = rails[k] - self.v[i];
            e[0] += 0.5 * self.c[i] * dv * dv;
            self.v[i] = rails[k];
        }
        meter.cap_energy_j += lane_sum(&e);
        meter.cap_events += n as u64;
        meter.toggles_cached(2 * n as u64, self.gate_e);
    }

    /// [`CapBank::sample_deferred_lane`] with a per-element fire mask
    /// (the delta-sparsity P1, ADR-005/ADR-007): every cap's voltage is
    /// written unconditionally — a quiescent cap already holds the rail
    /// of the value it last fired with, so rewriting it is the identity
    /// — while the metered charge/toggle work is *selected* by the mask
    /// (`if fired {e} else {0.0}`, a cmov/blend, never a branch). With
    /// every element fired this is bit-identical to the unmasked lane,
    /// meter included.
    pub fn sample_deferred_lane_masked(
        &mut self,
        idx: &[usize],
        rails: &[f64],
        fired: &[bool],
        meter: &mut EnergyMeter,
    ) {
        let n = idx.len();
        debug_assert_eq!(rails.len(), n);
        debug_assert_eq!(fired.len(), n);
        let mut e = [0.0f64; LANES];
        let mut n_fired = 0u64;
        let chunks = n / LANES;
        for ch in 0..chunks {
            for l in 0..LANES {
                let k = ch * LANES + l;
                let i = idx[k];
                let dv = rails[k] - self.v[i];
                let ek = 0.5 * self.c[i] * dv * dv;
                e[l] += if fired[k] { ek } else { 0.0 };
                n_fired += fired[k] as u64;
                self.v[i] = rails[k];
            }
        }
        for k in chunks * LANES..n {
            let i = idx[k];
            let dv = rails[k] - self.v[i];
            let ek = 0.5 * self.c[i] * dv * dv;
            e[0] += if fired[k] { ek } else { 0.0 };
            n_fired += fired[k] as u64;
            self.v[i] = rails[k];
        }
        meter.cap_energy_j += lane_sum(&e);
        meter.cap_events += n_fired;
        meter.toggles_cached(2 * n_fired, self.gate_e);
    }

    /// Contiguous-prefix sibling of [`CapBank::sample_deferred_lane`]:
    /// caps `0..rails.len()` charge to `rails` with unit stride (no
    /// gather) — the z-bank layout, where cap `i` belongs to row `i`.
    pub fn sample_deferred_lane_contig(
        &mut self,
        rails: &[f64],
        meter: &mut EnergyMeter,
    ) {
        let n = rails.len();
        debug_assert!(n <= self.v.len());
        let mut e = [0.0f64; LANES];
        let chunks = n / LANES;
        for ch in 0..chunks {
            for l in 0..LANES {
                let k = ch * LANES + l;
                let dv = rails[k] - self.v[k];
                e[l] += 0.5 * self.c[k] * dv * dv;
                self.v[k] = rails[k];
            }
        }
        for k in chunks * LANES..n {
            let dv = rails[k] - self.v[k];
            e[0] += 0.5 * self.c[k] * dv * dv;
            self.v[k] = rails[k];
        }
        meter.cap_energy_j += lane_sum(&e);
        meter.cap_events += n as u64;
        meter.toggles_cached(2 * n as u64, self.gate_e);
    }

    /// Masked contiguous lane — see
    /// [`CapBank::sample_deferred_lane_masked`] for the select-not-branch
    /// mask semantics. Bit-identical to the unmasked contiguous lane
    /// when every element is fired.
    pub fn sample_deferred_lane_contig_masked(
        &mut self,
        rails: &[f64],
        fired: &[bool],
        meter: &mut EnergyMeter,
    ) {
        let n = rails.len();
        debug_assert!(n <= self.v.len());
        debug_assert_eq!(fired.len(), n);
        let mut e = [0.0f64; LANES];
        let mut n_fired = 0u64;
        let chunks = n / LANES;
        for ch in 0..chunks {
            for l in 0..LANES {
                let k = ch * LANES + l;
                let dv = rails[k] - self.v[k];
                let ek = 0.5 * self.c[k] * dv * dv;
                e[l] += if fired[k] { ek } else { 0.0 };
                n_fired += fired[k] as u64;
                self.v[k] = rails[k];
            }
        }
        for k in chunks * LANES..n {
            let dv = rails[k] - self.v[k];
            let ek = 0.5 * self.c[k] * dv * dv;
            e[0] += if fired[k] { ek } else { 0.0 };
            n_fired += fired[k] as u64;
            self.v[k] = rails[k];
        }
        meter.cap_energy_j += lane_sum(&e);
        meter.cap_events += n_fired;
        meter.toggles_cached(2 * n_fired, self.gate_e);
    }

    /// σ of the capacitance-weighted mean of fresh per-cap sampling
    /// noise over `idx`: sqrt(Σ C_i²σ_i²)/Σ C_i.
    pub fn aggregate_sample_sigma(&self, idx: &[usize]) -> f64 {
        let num: f64 = idx
            .iter()
            .map(|&i| (self.c[i] * self.ktc[i]).powi(2))
            .sum();
        let den: f64 = idx.iter().map(|&i| self.c[i]).sum();
        num.sqrt() / den
    }

    /// Deterministic injection shift of the shared node:
    /// Σ C_i·inj_i / Σ C_i.
    pub fn aggregate_injection_shift(&self, idx: &[usize]) -> f64 {
        let num: f64 = idx.iter().map(|&i| self.c[i] * self.inj[i]).sum();
        let den: f64 = idx.iter().map(|&i| self.c[i]).sum();
        num / den
    }

    /// Total charge of the caps selected by `idx` (Q = Σ C·V).
    pub fn charge(&self, idx: &[usize]) -> f64 {
        idx.iter().map(|&i| self.c[i] * self.v[i]).sum()
    }

    /// Short the selected caps together (plus an optional extra fixed
    /// capacitance at voltage v_extra, e.g. the column line parasitic).
    /// Returns the settled voltage. Charge-conserving by construction.
    pub fn share(
        &mut self,
        idx: &[usize],
        extra: Option<(f64, f64)>,
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> f64 {
        self.share_with(idx, extra, 0.0, 0.0, cfg, rng, meter)
    }

    /// `share` plus an extra Gaussian term (deferred sampling noise) and
    /// a deterministic shift (deferred injection) applied to the settled
    /// node — see `sample_deferred`.
    ///
    /// The charge/total-capacitance reduction and the dissipation sum
    /// both run as fixed-stride [`LANES`]-chunked loops with per-lane
    /// partial accumulators (ADR-007): branch-free bodies the compiler
    /// can keep in vector registers, collapsed through the deterministic
    /// [`lane_sum`] tree, meter updated once outside the loop.
    pub fn share_with(
        &mut self,
        idx: &[usize],
        extra: Option<(f64, f64)>,
        add_sigma: f64,
        add_shift: f64,
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> f64 {
        let n = idx.len();
        let chunks = n / LANES;
        let mut qs = [0.0f64; LANES];
        let mut cs = [0.0f64; LANES];
        for ch in 0..chunks {
            for l in 0..LANES {
                let i = idx[ch * LANES + l];
                qs[l] += self.c[i] * self.v[i];
                cs[l] += self.c[i];
            }
        }
        for k in chunks * LANES..n {
            let i = idx[k];
            qs[0] += self.c[i] * self.v[i];
            cs[0] += self.c[i];
        }
        let mut q = lane_sum(&qs);
        let mut ctot = lane_sum(&cs);
        if let Some((ce, ve)) = extra {
            q += ce * ve;
            ctot += ce;
        }
        let v_settled = q / ctot;
        // Dissipation in the share switches: ΔE = ½·Σ C_i (V_i − V̄)²
        // (energy difference before/after at equal charge).
        let mut es = [0.0f64; LANES];
        for ch in 0..chunks {
            for l in 0..LANES {
                let i = idx[ch * LANES + l];
                let dv = self.v[i] - v_settled;
                es[l] += 0.5 * self.c[i] * dv * dv;
            }
        }
        for k in chunks * LANES..n {
            let i = idx[k];
            let dv = self.v[i] - v_settled;
            es[0] += 0.5 * self.c[i] * dv * dv;
        }
        meter.cap_energy_j += lane_sum(&es);
        meter.cap_events += n as u64;
        meter.toggles_cached(n as u64, self.gate_e);
        // Thermal noise of the share (kT/C_total) combined with any
        // deferred sampling noise — independent Gaussians, one draw.
        let share_sigma = cfg.ktc_sigma(ctot);
        let sigma = (share_sigma * share_sigma + add_sigma * add_sigma).sqrt();
        let noise = if sigma > 0.0 { sigma * rng.normal_fast() } else { 0.0 };
        let v_final = v_settled + noise + add_shift;
        for &i in idx {
            self.v[i] = v_final;
        }
        v_final
    }

    /// Mean voltage over `idx` weighted by capacitance (diagnostic).
    pub fn weighted_mean(&self, idx: &[usize]) -> f64 {
        let q: f64 = self.charge(idx);
        let c: f64 = idx.iter().map(|&i| self.c[i]).sum();
        q / c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn ideal_bank(n: usize) -> (CapBank, CircuitConfig, Rng, EnergyMeter) {
        let cfg = CircuitConfig::ideal();
        let mut rng = Rng::new(1);
        let bank = CapBank::new(n, cfg.c_unit, &cfg, &mut rng);
        (bank, cfg, rng, EnergyMeter::new())
    }

    #[test]
    fn ideal_share_is_arithmetic_mean() {
        let (mut bank, cfg, mut rng, mut m) = ideal_bank(4);
        for (i, v) in [0.1, 0.2, 0.3, 0.8].iter().enumerate() {
            bank.v[i] = *v;
        }
        let v = bank.share(&[0, 1, 2, 3], None, &cfg, &mut rng, &mut m);
        assert!((v - 0.35).abs() < 1e-12);
        for i in 0..4 {
            assert_eq!(bank.v[i], v);
        }
    }

    #[test]
    fn share_conserves_charge_under_mismatch() {
        check::property("charge conservation", 300, |rng| {
            let mut cfg = CircuitConfig::default();
            cfg.sigma_c = 0.05;
            let n = 2 + rng.below(30) as usize;
            let mut bank = CapBank::new(n, cfg.c_unit, &mut cfg.clone(), rng);
            for i in 0..n {
                bank.v[i] = rng.uniform_in(0.0, 0.8);
            }
            let idx: Vec<usize> = (0..n).collect();
            let q_before = bank.charge(&idx);
            // noiseless share: use ideal-noise cfg but keep mismatch caps
            let mut cfg2 = cfg.clone();
            cfg2.ideal = true;
            let mut m = EnergyMeter::new();
            bank.share(&idx, None, &cfg2, rng, &mut m);
            let q_after = bank.charge(&idx);
            crate::prop_close!(q_before, q_after, 1e-25);
            Ok(())
        });
    }

    #[test]
    fn share_with_line_parasitic_pulls_toward_line() {
        let (mut bank, cfg, mut rng, mut m) = ideal_bank(2);
        bank.v[0] = 0.6;
        bank.v[1] = 0.6;
        let c_line = bank.c[0]; // as big as one cap
        let v = bank.share(&[0, 1], Some((c_line, 0.0)), &cfg, &mut rng, &mut m);
        assert!((v - 0.4).abs() < 1e-12); // (0.6·2C + 0·C)/3C
    }

    #[test]
    fn sampling_tracks_rail_and_costs_energy() {
        let (mut bank, cfg, mut rng, mut m) = ideal_bank(1);
        bank.sample(0, 0.55, &cfg, &mut rng, &mut m);
        assert_eq!(bank.v[0], 0.55);
        assert!(m.cap_energy_j > 0.0);
        assert_eq!(m.switch_toggles, 2);
    }

    #[test]
    fn ktc_noise_statistics() {
        let mut cfg = CircuitConfig::default();
        cfg.sigma_c = 0.0;
        cfg.c_inj = 0.0;
        let mut rng = Rng::new(3);
        let mut bank = CapBank::new(1, cfg.c_unit, &cfg, &mut rng);
        let mut m = EnergyMeter::new();
        let n = 4000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            bank.sample(0, 0.5, &cfg, &mut rng, &mut m);
            let e = bank.v[0] - 0.5;
            sum += e;
            sum2 += e * e;
        }
        let sigma_meas = (sum2 / n as f64 - (sum / n as f64).powi(2)).sqrt();
        let sigma_exp = cfg.ktc_sigma(cfg.c_unit);
        assert!(
            (sigma_meas / sigma_exp - 1.0).abs() < 0.1,
            "measured {sigma_meas}, expected {sigma_exp}"
        );
    }

    #[test]
    fn lane_sampling_matches_scalar_voltages() {
        // the lane loops write exactly the voltages the scalar helper
        // writes (the rails), gather and contiguous layouts alike, for
        // lengths straddling the chunk boundary
        let cfg = CircuitConfig::default();
        for n in [1usize, 7, 8, 9, 19] {
            let mut rng = Rng::new(21);
            let mut a = CapBank::new(2 * n, cfg.c_unit, &cfg, &mut rng);
            let mut b = a.clone();
            let (mut ma, mut mb) = (EnergyMeter::new(), EnergyMeter::new());
            let idx: Vec<usize> = (0..n).map(|i| 2 * i + (i % 2)).collect();
            let rails: Vec<f64> = (0..n).map(|i| 0.3 + 0.01 * i as f64).collect();
            for (k, &i) in idx.iter().enumerate() {
                a.sample_deferred(i, rails[k], &mut ma);
            }
            b.sample_deferred_lane(&idx, &rails, &mut mb);
            assert_eq!(a.v, b.v, "n={n}");
            assert_eq!(ma.cap_events, mb.cap_events);
            assert_eq!(ma.switch_toggles, mb.switch_toggles);
            // energy agrees up to the hoisted-accumulator reassociation
            crate::prop_close!(ma.cap_energy_j, mb.cap_energy_j, 1e-25);
        }
    }

    #[test]
    fn masked_lane_all_fired_is_bit_identical_to_unmasked() {
        let cfg = CircuitConfig::default();
        for n in [5usize, 8, 13] {
            let mut rng = Rng::new(33);
            let mut a = CapBank::new(n, cfg.c_unit, &cfg, &mut rng);
            let mut b = a.clone();
            let (mut ma, mut mb) = (EnergyMeter::new(), EnergyMeter::new());
            let rails: Vec<f64> = (0..n).map(|i| 0.5 - 0.02 * i as f64).collect();
            let fired = vec![true; n];
            a.sample_deferred_lane_contig(&rails, &mut ma);
            b.sample_deferred_lane_contig_masked(&rails, &fired, &mut mb);
            assert_eq!(a.v, b.v, "n={n}");
            assert_eq!(ma, mb, "all-fired mask must be the identity, n={n}");
        }
    }

    #[test]
    fn masked_lane_quiescent_elements_write_but_meter_nothing() {
        let cfg = CircuitConfig::default();
        let n = 10;
        let mut rng = Rng::new(44);
        let mut bank = CapBank::new(n, cfg.c_unit, &cfg, &mut rng);
        let mut m = EnergyMeter::new();
        let rails: Vec<f64> = (0..n).map(|i| 0.4 + 0.03 * i as f64).collect();
        let fired = vec![false; n];
        bank.sample_deferred_lane_contig_masked(&rails, &fired, &mut m);
        // voltages are rewritten (held rails) ...
        for k in 0..n {
            assert_eq!(bank.v[k], rails[k]);
        }
        // ... but nothing toggles and nothing dissipates
        assert_eq!(m, EnergyMeter::new());
    }

    #[test]
    fn mismatch_distribution() {
        let cfg = CircuitConfig::default();
        let mut rng = Rng::new(9);
        let bank = CapBank::new(4096, cfg.c_unit, &cfg, &mut rng);
        let mean: f64 = bank.c.iter().sum::<f64>() / 4096.0;
        let rel_std = (bank.c.iter().map(|c| (c - mean).powi(2)).sum::<f64>()
            / 4096.0)
            .sqrt()
            / mean;
        assert!((rel_std / cfg.sigma_c - 1.0).abs() < 0.15, "rel σ {rel_std}");
    }
}
