//! A MINIMALIST mixed-signal computing core: an R×C array of synapse
//! columns sharing row drivers, executing one GRU block (or a slice of
//! one — the mapping planner, [`crate::mapping::Plan`], splits wider
//! layers across cores).
//!
//! The core is the unit of physical mapping (paper §3: "Depending on
//! their dimensionality, these GRU blocks can be mapped to one or
//! multiple cores, which are connected through an event-based routing
//! fabric").

use crate::config::{delta_fires, CircuitConfig, CoreGeometry};
use crate::energy::EnergyMeter;
use crate::satsim::column::{Column, ColumnConfig, ColumnStep};
use crate::util::rng::Rng;

/// Cumulative delta-sparsity skip accounting of one core (ADR-005) —
/// the observable behind the engine's skip-ratio metrics. Like the
/// [`EnergyMeter`], counters accumulate across the core's lifetime and
/// are *not* cleared by sequence resets, so serving-side merges see
/// monotone totals.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaCounters {
    /// Input components that moved past the threshold and drove a fresh
    /// rail sample (counted once per core step; every component of
    /// every step counts when `delta == 0` semantics apply — but the
    /// delta machinery only runs at `delta > 0`, so both counters stay
    /// 0 on the default path).
    pub components_fired: u64,
    /// Input components held under the threshold — their P1 sampling
    /// work (cap charge + switch toggles) was elided.
    pub components_skipped: u64,
    /// Whole column charge-shares replayed from cache because the
    /// core's entire input slice was quiescent.
    pub shares_skipped: u64,
    /// Column charge-shares actually executed on the delta path.
    pub shares_done: u64,
}

impl DeltaCounters {
    /// Fold another core's (or worker's) counters into this one.
    pub fn merge(&mut self, other: &DeltaCounters) {
        self.components_fired += other.components_fired;
        self.components_skipped += other.components_skipped;
        self.shares_skipped += other.shares_skipped;
        self.shares_done += other.shares_done;
    }

    /// Fraction of input components whose sampling work was skipped
    /// (0.0 when nothing has been stepped through the delta path).
    pub fn skip_ratio(&self) -> f64 {
        let total = self.components_fired + self.components_skipped;
        if total == 0 {
            0.0
        } else {
            self.components_skipped as f64 / total as f64
        }
    }
}

#[derive(Debug, Clone)]
/// One physical switched-capacitor core.
pub struct Core {
    /// Physical row/column capacity.
    pub geometry: CoreGeometry,
    /// Rows actually connected (≤ geometry.rows). Unused rows' caps are
    /// disconnected via their segment switches — the same mechanism the
    /// ADC slope control uses — so they do not load the charge share.
    pub active_rows: usize,
    /// Output columns, left to right.
    pub columns: Vec<Column>,
    /// Switching-energy accounting for this core.
    pub meter: EnergyMeter,
    /// Per-slot master noise streams: slot `s` drives sequence `s` of a
    /// lockstep batch. By default every slot starts as a clone of
    /// `rng0`, so each slot replays exactly the noise realization a
    /// fresh sequential run sees — the seeding convention that makes
    /// batched and sequential execution bit-identical (ADR-001; see
    /// `MixedSignalEngine::classify_batch`). A Monte-Carlo provisioning
    /// ([`Core::provision_slot_devices`], ADR-008) replaces a slot's
    /// stream root so each slot carries an independent device *and*
    /// noise realization.
    slot_rngs: Vec<Rng>,
    /// RNG state at construction: `reset()` restores it so that a given
    /// seed reproduces a trial exactly (deterministic simulation; fresh
    /// noise across trials is obtained by changing the config seed).
    rng0: Rng,
    /// Per-slot stream *roots*: what `reset`/`reset_slot` restore each
    /// slot's stream to. All clones of `rng0` by default (ADR-001);
    /// rewritten per slot by a Monte-Carlo provisioning (ADR-008).
    slot_rng0s: Vec<Rng>,
    /// The seed tag `Core::new` mixed into `cfg.seed` — kept so a
    /// per-slot provisioning can derive instance streams through the
    /// same mix, making a provisioned slot bit-identical to a whole
    /// fresh core built with the instance seed as its config seed.
    seed_tag: u64,
    /// Scratch output buffer (events) of the most recent `step_finish`,
    /// whichever slot it served; reused across steps.
    out_events: Vec<bool>,
    /// Per-slot, per-column noise streams of in-flight two-phase steps
    /// (forked in `step_partial_slot`, consumed by `step_finish_slot`) —
    /// per slot so the batched engine can interleave the phases of
    /// several slots across the tiles of a row-split layer.
    col_rngs: Vec<Vec<Rng>>,
    /// Scratch partial-share buffer filled by `step_partial` — owned by
    /// the core so the steady-state step makes no heap allocation.
    partials: Vec<(f64, f64)>,
    /// Per-slot last-*fired* input values (EdgeDRNN accumulating-delta
    /// trackers, ADR-005). NaN-seeded: the first step of a slot always
    /// fires every component. Only consulted when `cfg.delta > 0`;
    /// sized with the slots at `set_slots` (a batch boundary), never in
    /// the steady-state step.
    x_last: Vec<Vec<f64>>,
    /// Scratch fire mask / effective-input buffers of the delta path.
    fired: Vec<bool>,
    x_eff: Vec<f64>,
    /// Cumulative skip accounting (delta path only).
    delta: DeltaCounters,
}

/// Per-step observables for every column (Fig 4 traces; readout states).
#[derive(Debug, Clone, Default)]
pub struct CoreStep {
    pub steps: Vec<ColumnStep>,
}

impl CoreStep {
    /// The per-column comparator events of this step.
    pub fn events(&self) -> impl Iterator<Item = bool> + '_ {
        self.steps.iter().map(|s| s.y)
    }
}

impl Core {
    /// Build a core from per-column configs. `rows` is fixed by the
    /// geometry; configs must match it.
    pub fn new(
        geometry: CoreGeometry,
        col_cfgs: Vec<ColumnConfig>,
        cfg: &CircuitConfig,
        seed_tag: u64,
    ) -> Core {
        assert!(col_cfgs.len() <= geometry.cols,
                "core supports {} columns, got {}", geometry.cols, col_cfgs.len());
        let active_rows = col_cfgs.first().map(|c| c.w_h.len()).unwrap_or(0);
        assert!(active_rows <= geometry.rows,
                "core supports {} rows, got {}", geometry.rows, active_rows);
        let mut rng = Rng::new(cfg.seed ^ seed_tag.wrapping_mul(0x9E37));
        let columns = col_cfgs
            .into_iter()
            .map(|cc| {
                assert_eq!(cc.w_h.len(), active_rows,
                           "all columns must use the same active row count");
                let mut col_rng = rng.fork(0xC01);
                Column::new(cc, cfg, &mut col_rng)
            })
            .collect::<Vec<_>>();
        let n_cols = columns.len();
        Core {
            geometry,
            active_rows,
            columns,
            meter: EnergyMeter::new(),
            rng0: rng.clone(),
            slot_rng0s: vec![rng.clone()],
            seed_tag,
            slot_rngs: vec![rng],
            out_events: vec![false; n_cols],
            col_rngs: vec![Vec::with_capacity(n_cols)],
            partials: Vec::with_capacity(n_cols),
            x_last: vec![vec![f64::NAN; active_rows]],
            fired: Vec::with_capacity(active_rows),
            x_eff: Vec::with_capacity(active_rows),
            delta: DeltaCounters::default(),
        }
    }

    /// Number of instantiated columns.
    pub fn n_cols(&self) -> usize {
        self.columns.len()
    }

    /// Number of lockstep batch slots provisioned on this core.
    pub fn n_slots(&self) -> usize {
        self.slot_rngs.len()
    }

    /// Provision `n` lockstep batch slots (clamped to ≥ 1) across every
    /// column and reset them all — a batch boundary. Allocation happens
    /// here, never in the per-slot steady-state step. Any per-slot
    /// Monte-Carlo devices are dissolved (the columns' `set_slots`
    /// restores the construction hardware) and every slot's stream root
    /// returns to the ADR-001 clone convention.
    pub fn set_slots(&mut self, n: usize, cfg: &CircuitConfig) {
        let n = n.max(1);
        for c in self.columns.iter_mut() {
            c.set_slots(n, cfg);
        }
        let n_cols = self.columns.len();
        let rng0 = self.rng0.clone();
        self.slot_rngs.clear();
        self.slot_rngs.resize_with(n, || rng0.clone());
        self.slot_rng0s.clear();
        self.slot_rng0s.resize_with(n, || rng0.clone());
        self.col_rngs.clear();
        self.col_rngs.resize_with(n, || Vec::with_capacity(n_cols));
        let rows = self.active_rows;
        self.x_last.clear();
        self.x_last.resize_with(n, || vec![f64::NAN; rows]);
    }

    /// Whether any slot of this core carries its own Monte-Carlo device
    /// instance (ADR-008).
    pub fn has_slot_devices(&self) -> bool {
        self.columns.iter().any(|c| c.has_slot_devices())
    }

    /// Opt every provisioned slot into its own fabricated device
    /// instance and noise stream (ADR-008): slot `s` is rebuilt from
    /// `seeds[s]` through exactly the construction path [`Core::new`]
    /// runs — the same seed-tag mix, the same per-column `fork(0xC01)`,
    /// the same device draw order — so slot `s` afterwards behaves
    /// bit-identically (device and runtime noise alike) to a whole
    /// fresh core built with `cfg.seed = seeds[s]`. `seeds` must have
    /// one entry per provisioned slot. Cold path: call at a batch
    /// boundary, then [`Core::reset`] before stepping.
    pub fn provision_slot_devices(&mut self, cfg: &CircuitConfig, seeds: &[u64]) {
        assert_eq!(
            seeds.len(),
            self.n_slots(),
            "provision_slot_devices needs one seed per provisioned slot"
        );
        for (s, &seed) in seeds.iter().enumerate() {
            // the Core::new seeding mix, with the instance seed in
            // place of cfg.seed
            let mut rng = Rng::new(seed ^ self.seed_tag.wrapping_mul(0x9E37));
            for col in self.columns.iter_mut() {
                let mut col_rng = rng.fork(0xC01);
                col.install_slot_device(s, cfg, &mut col_rng);
            }
            // what remains of the stream after fabrication is exactly
            // the runtime noise root a fresh core would carry
            self.slot_rng0s[s] = rng.clone();
            self.slot_rngs[s] = rng;
        }
    }

    /// Drop every slot's Monte-Carlo device and return to the ADR-001
    /// shared-hardware, cloned-stream convention. Cold path.
    pub fn dissolve_slot_devices(&mut self) {
        for c in self.columns.iter_mut() {
            c.dissolve_devices();
        }
        let rng0 = self.rng0.clone();
        for r0 in self.slot_rng0s.iter_mut() {
            *r0 = rng0.clone();
        }
        for r in self.slot_rngs.iter_mut() {
            *r = rng0.clone();
        }
    }

    /// Reset all column states (every slot) to V_0 (sequence boundary)
    /// and restore each slot's noise stream to its root — by default
    /// the construction state, making per-sequence simulation
    /// deterministic and every slot's stream identical to a fresh
    /// sequential run's (ADR-001); under a Monte-Carlo provisioning,
    /// each slot's own instance stream (ADR-008). Device identities
    /// (mismatch draws) are construction-time and survive resets.
    pub fn reset(&mut self, cfg: &CircuitConfig) {
        for c in self.columns.iter_mut() {
            c.reset(cfg);
        }
        for (r, r0) in self.slot_rngs.iter_mut().zip(self.slot_rng0s.iter()) {
            *r = r0.clone();
        }
        for cr in self.col_rngs.iter_mut() {
            cr.clear();
        }
        for xl in self.x_last.iter_mut() {
            xl.fill(f64::NAN);
        }
    }

    /// Reset batch slot `slot` **alone** to sequence-boundary state:
    /// its column state returns to V_0, its noise stream to the
    /// construction state, and any in-flight two-phase step of the slot
    /// is discarded — the other slots keep running untouched. This is
    /// the lease path of streaming sessions: a slot freed by one
    /// sequence is handed to the next mid-flight, and the recycled slot
    /// replays exactly the stream a fresh [`Core::reset`] run sees
    /// (bit-identical results, pinned by tests/stream_parity.rs).
    pub fn reset_slot(&mut self, slot: usize, cfg: &CircuitConfig) {
        for c in self.columns.iter_mut() {
            c.reset_slot(slot, cfg);
        }
        self.slot_rngs[slot] = self.slot_rng0s[slot].clone();
        self.col_rngs[slot].clear();
        self.x_last[slot].fill(f64::NAN);
    }

    /// One time step over the full array on batch slot 0. `x` has
    /// `active_rows` entries. Per-column observables are written into
    /// `out` (a reusable buffer — the steady-state step allocates
    /// nothing); binary events are also kept in an internal buffer
    /// accessible via `last_events`.
    ///
    /// Equivalent (bit-for-bit, noise stream included) to
    /// `step_partial` followed by `step_finish` with the core's own
    /// partial results — the two-phase path row-split layers use.
    pub fn step(&mut self, x: &[f64], cfg: &CircuitConfig, out: &mut CoreStep) {
        self.step_slot(0, x, cfg, out);
    }

    /// One time step of batch slot `slot` — `step` is the `slot == 0`
    /// special case, and slot 0 of a freshly reset core is bit-identical
    /// to the sequential path regardless of how many slots exist.
    pub fn step_slot(
        &mut self,
        slot: usize,
        x: &[f64],
        cfg: &CircuitConfig,
        out: &mut CoreStep,
    ) {
        self.step_partial_slot(slot, x, cfg);
        // lend the scratch partials out so `step_finish_slot` can borrow
        // `self` mutably — a pointer swap, not an allocation
        let partials = std::mem::take(&mut self.partials);
        self.step_finish_slot(slot, &partials, cfg, out);
        self.partials = partials;
    }

    /// First half of a time step: sample + charge-share (P1–P2) on every
    /// column, returning the per-column `(v_htilde, v_z)` node voltages
    /// — partial IMC means when this core is a row tile of a split
    /// layer. The returned slice borrows a core-owned scratch buffer
    /// (overwritten by the next `step_partial`). Complete the step with
    /// [`Core::step_finish`] (owner tile) or
    /// [`Core::finish_partial_only`] (non-owner tiles).
    pub fn step_partial(&mut self, x: &[f64], cfg: &CircuitConfig) -> &[(f64, f64)] {
        self.step_partial_slot(0, x, cfg)
    }

    /// [`Core::step_partial`] on batch slot `slot`. In-flight per-column
    /// noise streams are kept per slot, so the phases of different slots
    /// may interleave freely between `step_partial_slot` and the
    /// matching `step_finish_slot`; the shared `partials` scratch is
    /// overwritten by the next call, whatever its slot — consume it
    /// before issuing another partial.
    // lint: rng-draws(2, core-share)
    pub fn step_partial_slot(
        &mut self,
        slot: usize,
        x: &[f64],
        cfg: &CircuitConfig,
    ) -> &[(f64, f64)] {
        assert_eq!(x.len(), self.active_rows);
        if cfg.delta > 0.0 {
            return self.step_partial_slot_delta(slot, x, cfg);
        }
        self.col_rngs[slot].clear();
        self.partials.clear();
        for (j, col) in self.columns.iter_mut().enumerate() {
            col.bind_slot(slot);
            let mut col_rng = self.slot_rngs[slot].fork(j as u64);
            self.partials
                .push(col.phase_share(x, cfg, &mut col_rng, &mut self.meter)); // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all columns)
            self.col_rngs[slot].push(col_rng); // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all columns)
        }
        &self.partials
    }

    /// Delta-sparsity variant of [`Core::step_partial_slot`] (ADR-005),
    /// taken only at `cfg.delta > 0` — the default path above is the
    /// exact pre-delta code. Per component, the accumulating-delta rule
    /// ([`delta_fires`]) decides against the slot's last *fired* value;
    /// quiescent components skip their P1 sampling work, and a fully
    /// quiescent frame skips every column's charge share outright,
    /// replaying the cached share results ([`Column::skip_share`]).
    /// Fired components update the tracker; the share sees the held
    /// last-fired value for quiescent ones, so error stays bounded by
    /// the threshold instead of accumulating.
    // lint: rng-draws(2, core-share)
    fn step_partial_slot_delta(
        &mut self,
        slot: usize,
        x: &[f64],
        cfg: &CircuitConfig,
    ) -> &[(f64, f64)] {
        let x_last = &mut self.x_last[slot];
        self.fired.clear();
        self.x_eff.clear();
        let mut n_fired: u64 = 0;
        // fire-mask lane (ADR-007): tracker update and effective input
        // via select, not branch — `if fire {xi} else {held}` lowers to
        // a cmov/blend, so the loop stays a fixed-stride vector body
        for (i, &xi) in x.iter().enumerate() {
            let fire = delta_fires(xi, x_last[i], cfg.delta);
            let held = if fire { xi } else { x_last[i] };
            x_last[i] = held;
            n_fired += fire as u64;
            self.fired.push(fire); // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all rows)
            self.x_eff.push(held); // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all rows)
        }
        self.delta.components_fired += n_fired;
        self.delta.components_skipped += x.len() as u64 - n_fired;
        let quiescent = n_fired == 0;
        self.col_rngs[slot].clear();
        self.partials.clear();
        for (j, col) in self.columns.iter_mut().enumerate() {
            col.bind_slot(slot);
            let mut col_rng = self.slot_rngs[slot].fork(j as u64);
            let share = if quiescent {
                self.delta.shares_skipped += 1;
                col.skip_share(cfg, &mut col_rng)
            } else {
                self.delta.shares_done += 1;
                col.phase_share_masked(
                    &self.x_eff,
                    &self.fired,
                    cfg,
                    &mut col_rng,
                    &mut self.meter,
                )
            };
            self.partials.push(share); // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all columns)
            self.col_rngs[slot].push(col_rng); // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all columns)
        }
        &self.partials
    }

    /// Cumulative delta-sparsity skip counters of this core (all slots;
    /// zeros unless the core has stepped with `cfg.delta > 0`).
    pub fn delta_counters(&self) -> DeltaCounters {
        self.delta
    }

    /// Second half of a time step on the owner tile: short every
    /// column's h̃/z lines to the `combined` voltages (the row-count
    /// weighted mean across row tiles — a no-op when they are this
    /// core's own partials), then digitize, swap, and strobe (P3–P4).
    /// Observables are appended into the cleared `out` buffer.
    pub fn step_finish(
        &mut self,
        combined: &[(f64, f64)],
        cfg: &CircuitConfig,
        out: &mut CoreStep,
    ) {
        self.step_finish_slot(0, combined, cfg, out);
    }

    /// [`Core::step_finish`] on batch slot `slot`, consuming the noise
    /// streams its `step_partial_slot` forked.
    pub fn step_finish_slot(
        &mut self,
        slot: usize,
        combined: &[(f64, f64)],
        cfg: &CircuitConfig,
        out: &mut CoreStep,
    ) {
        assert_eq!(combined.len(), self.columns.len());
        assert_eq!(
            self.col_rngs[slot].len(),
            self.columns.len(),
            "step_finish without a preceding step_partial (slot {slot})"
        );
        out.steps.clear();
        for (j, col) in self.columns.iter_mut().enumerate() {
            col.bind_slot(slot);
            let (v_htilde, v_z) = combined[j];
            col.override_share(v_htilde, v_z);
            let s = col.phase_update(
                v_htilde,
                v_z,
                cfg,
                &mut self.col_rngs[slot][j],
                &mut self.meter,
            );
            self.out_events[j] = s.y;
            out.steps.push(s); // lint: allow(alloc, push into the caller's cleared per-step buffer which reuses its capacity)
        }
        self.col_rngs[slot].clear();
        self.meter.step_done();
    }

    /// End the time step of a non-owner row tile: its columns only
    /// contribute partial shares — no gate, swap, or comparator happens
    /// here. Accounts the step and discards the pending noise streams.
    pub fn finish_partial_only(&mut self) {
        self.finish_partial_only_slot(0);
    }

    /// [`Core::finish_partial_only`] for batch slot `slot`.
    pub fn finish_partial_only_slot(&mut self, slot: usize) {
        self.col_rngs[slot].clear();
        self.meter.step_done();
    }

    /// Events of the most recent `step_finish`, whichever slot ran last.
    pub fn last_events(&self) -> &[bool] {
        &self.out_events
    }

    /// Analog hidden-state voltages of all columns — the slot each
    /// column currently has bound (diagnostic; after a sequential run or
    /// a single-slot batch this is slot 0).
    pub fn state_voltages(&self) -> Vec<f64> {
        self.columns.iter().map(|c| c.v_h()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::W2;
    use crate::satsim::adc::OFFSET_NEUTRAL;

    fn mk_core(rows: usize, cols: usize) -> (Core, CircuitConfig) {
        let cfg = CircuitConfig::ideal();
        let col_cfgs: Vec<ColumnConfig> = (0..cols)
            .map(|j| ColumnConfig {
                w_h: (0..rows).map(|i| W2::new(((i + j) % 4) as u8)).collect(),
                w_z: (0..rows).map(|i| W2::new(((i + 2 * j) % 4) as u8)).collect(),
                slope_m: rows / 2,
                offset_code: OFFSET_NEUTRAL,
                v_theta: cfg.v_0,
            })
            .collect();
        let core = Core::new(
            CoreGeometry { rows, cols },
            col_cfgs,
            &cfg,
            7,
        );
        (core, cfg)
    }

    #[test]
    fn step_produces_all_columns() {
        let (mut core, cfg) = mk_core(16, 8);
        let x = vec![1.0; 16];
        let mut out = CoreStep::default();
        core.step(&x, &cfg, &mut out);
        assert_eq!(out.steps.len(), 8);
        assert_eq!(core.last_events().len(), 8);
        assert_eq!(core.meter.steps, 1);
        assert!(core.meter.total_j() > 0.0);
    }

    #[test]
    fn reset_restores_v0() {
        let (mut core, cfg) = mk_core(8, 4);
        let mut out = CoreStep::default();
        core.step(&vec![1.0; 8], &cfg, &mut out);
        core.reset(&cfg);
        for v in core.state_voltages() {
            assert!((v - cfg.v_0).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut a, cfg) = mk_core(8, 4);
        let (mut b, _) = mk_core(8, 4);
        let x = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let (mut sa, mut sb) = (CoreStep::default(), CoreStep::default());
        a.step(&x, &cfg, &mut sa);
        b.step(&x, &cfg, &mut sb);
        for (p, q) in sa.steps.iter().zip(sb.steps.iter()) {
            assert_eq!(p, q);
        }
    }

    #[test]
    fn two_phase_step_matches_monolithic_step() {
        let cfg = CircuitConfig::default(); // noisy: exercises rng order
        let (mut a, _) = mk_core(12, 6);
        let (mut b, _) = mk_core(12, 6);
        let (mut sa, mut sb) = (CoreStep::default(), CoreStep::default());
        for t in 0..20 {
            let x: Vec<f64> = (0..12).map(|i| ((t + i) % 2) as f64).collect();
            a.step(&x, &cfg, &mut sa);
            let partials = b.step_partial(&x, &cfg).to_vec();
            b.step_finish(&partials, &cfg, &mut sb);
            for (p, q) in sa.steps.iter().zip(sb.steps.iter()) {
                assert_eq!(p, q, "diverged at step {t}");
            }
        }
        assert_eq!(a.meter, b.meter);
    }

    #[test]
    fn partial_only_core_accounts_steps_without_outputs() {
        let (mut core, cfg) = mk_core(8, 4);
        let partials = core.step_partial(&vec![1.0; 8], &cfg);
        assert_eq!(partials.len(), 4);
        core.finish_partial_only();
        assert_eq!(core.meter.steps, 1);
        assert_eq!(core.meter.adc_conversions, 0); // no gate ran here
    }

    #[test]
    fn batch_slots_replay_the_sequential_noise_stream() {
        // The seeding convention: every slot's stream is a clone of the
        // construction stream, so a lockstep batch fed the same inputs
        // on every slot produces the sequential run's outputs on every
        // slot — under full noise, not just ideally.
        let cfg = CircuitConfig::default();
        let mk = || {
            let col_cfgs: Vec<ColumnConfig> = (0..5)
                .map(|j| ColumnConfig {
                    w_h: (0..12).map(|i| W2::new(((i + j) % 4) as u8)).collect(),
                    w_z: (0..12).map(|i| W2::new(((i + 2 * j) % 4) as u8)).collect(),
                    slope_m: 6,
                    offset_code: OFFSET_NEUTRAL,
                    v_theta: cfg.v_0,
                })
                .collect();
            Core::new(CoreGeometry { rows: 12, cols: 8 }, col_cfgs, &cfg, 3)
        };
        let mut seq = mk();
        let mut bat = mk();
        bat.set_slots(3, &cfg);
        let (mut so, mut bo) = (CoreStep::default(), CoreStep::default());
        for t in 0..15 {
            let x: Vec<f64> = (0..12).map(|i| ((t + i) % 2) as f64).collect();
            seq.step(&x, &cfg, &mut so);
            for s in 0..3 {
                bat.step_slot(s, &x, &cfg, &mut bo);
                for (p, q) in so.steps.iter().zip(bo.steps.iter()) {
                    assert_eq!(p, q, "slot {s} diverged at step {t}");
                }
            }
        }
    }

    #[test]
    fn slots_carry_distinct_sequences_without_crosstalk() {
        // all-positive weights so the driven slot visibly moves off V_0
        let cfg = CircuitConfig::ideal();
        let col_cfgs: Vec<ColumnConfig> = (0..4)
            .map(|_| ColumnConfig {
                w_h: vec![W2::new(3); 8],
                w_z: vec![W2::new(3); 8],
                slope_m: 4,
                offset_code: OFFSET_NEUTRAL,
                v_theta: cfg.v_0,
            })
            .collect();
        let mut core =
            Core::new(CoreGeometry { rows: 8, cols: 4 }, col_cfgs, &cfg, 7);
        core.set_slots(2, &cfg);
        let mut out = CoreStep::default();
        let active = vec![1.0; 8];
        let silent = vec![0.0; 8];
        for _ in 0..4 {
            core.step_slot(0, &active, &cfg, &mut out);
            core.step_slot(1, &silent, &cfg, &mut out);
        }
        // slot 1 (bound last) stayed at V_0; slot 0's state moved
        for v in core.state_voltages() {
            assert!((v - cfg.v_0).abs() < 1e-9, "silent slot moved: {v}");
        }
        for c in core.columns.iter_mut() {
            c.bind_slot(0);
        }
        assert!(
            core.state_voltages().iter().any(|v| (v - cfg.v_0).abs() > 1e-3),
            "driven slot never moved"
        );
        // 2 slots × 4 lockstep steps = 8 accounted sequence-steps
        assert_eq!(core.meter.steps, 8);
    }

    #[test]
    fn reset_slot_replays_the_construction_stream() {
        // A recycled slot must be indistinguishable from a fresh one:
        // after reset_slot, its step outputs equal a freshly reset
        // core's slot-0 outputs — under full noise (stream included) —
        // while a neighbor slot keeps its state.
        let cfg = CircuitConfig::default();
        let mk = || {
            let col_cfgs: Vec<ColumnConfig> = (0..6)
                .map(|j| ColumnConfig {
                    w_h: (0..12).map(|i| W2::new(((i + j) % 4) as u8)).collect(),
                    w_z: (0..12).map(|i| W2::new(((i + 2 * j) % 4) as u8)).collect(),
                    slope_m: 6,
                    offset_code: OFFSET_NEUTRAL,
                    v_theta: cfg.v_0,
                })
                .collect();
            Core::new(CoreGeometry { rows: 12, cols: 12 }, col_cfgs, &cfg, 9)
        };
        let mut fresh = mk();
        let mut used = mk();
        used.set_slots(2, &cfg);
        let (mut fo, mut uo) = (CoreStep::default(), CoreStep::default());
        let x: Vec<f64> = (0..12).map(|i| (i % 2) as f64).collect();
        // burn some steps on both slots of `used`
        for _ in 0..5 {
            used.step_slot(0, &x, &cfg, &mut uo);
            used.step_slot(1, &x, &cfg, &mut uo);
        }
        let v1_before = {
            for c in used.columns.iter_mut() {
                c.bind_slot(1);
            }
            used.state_voltages()
        };
        used.reset_slot(0, &cfg);
        for t in 0..10 {
            let y: Vec<f64> = (0..12).map(|i| ((t + i) % 3) as f64 / 2.0).collect();
            fresh.step_slot(0, &y, &cfg, &mut fo);
            used.step_slot(0, &y, &cfg, &mut uo);
            for (p, q) in fo.steps.iter().zip(uo.steps.iter()) {
                assert_eq!(p, q, "recycled slot diverged at step {t}");
            }
        }
        // slot 1 was not disturbed by the slot-0 reset
        for c in used.columns.iter_mut() {
            c.bind_slot(1);
        }
        assert_eq!(used.state_voltages(), v1_before);
    }

    #[test]
    fn delta_path_with_tiny_threshold_matches_default_bitwise() {
        // Every component moves every step (alternating frame), so the
        // masked sampling fires everywhere, the whole-share skip never
        // engages, and the delta path must reproduce the default path
        // bit-for-bit — outputs, noise stream, and energy meter.
        let cfg0 = CircuitConfig::default(); // noisy: exercises rng order
        let cfgd = CircuitConfig { delta: 1e-9, ..Default::default() };
        let (mut a, _) = mk_core(12, 6);
        let (mut b, _) = mk_core(12, 6);
        let (mut sa, mut sb) = (CoreStep::default(), CoreStep::default());
        for t in 0..25 {
            let x: Vec<f64> = (0..12).map(|i| ((t + i) % 2) as f64).collect();
            a.step(&x, &cfg0, &mut sa);
            b.step(&x, &cfgd, &mut sb);
            for (p, q) in sa.steps.iter().zip(sb.steps.iter()) {
                assert_eq!(p, q, "diverged at step {t}");
            }
        }
        assert_eq!(a.meter, b.meter);
        let d = b.delta_counters();
        assert_eq!(d.components_skipped, 0);
        assert_eq!(d.components_fired, 25 * 12);
        assert_eq!(d.shares_skipped, 0);
        assert_eq!(a.delta_counters(), DeltaCounters::default());
    }

    #[test]
    fn delta_path_goes_quiescent_on_repeated_inputs() {
        let cfg = CircuitConfig { delta: 0.25, ..Default::default() };
        let (mut core, _) = mk_core(8, 4);
        let mut out = CoreStep::default();
        let x = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        core.step(&x, &cfg, &mut out);
        let d1 = core.delta_counters();
        // the NaN-seeded tracker fires everything on the first step
        assert_eq!(d1.components_fired, 8);
        assert_eq!(d1.components_skipped, 0);
        assert_eq!(d1.shares_done, 4);
        assert_eq!(d1.shares_skipped, 0);
        for _ in 0..5 {
            core.step(&x, &cfg, &mut out);
            assert_eq!(out.steps.len(), 4, "skipped steps must still output");
        }
        let d = core.delta_counters();
        assert_eq!(d.components_fired, 8);
        assert_eq!(d.components_skipped, 5 * 8);
        assert_eq!(d.shares_skipped, 5 * 4);
        assert!(d.skip_ratio() > 0.8, "skip ratio {}", d.skip_ratio());
        // the elided sampling + shares show up as energy savings vs a
        // twin core running the default path on the same inputs (the
        // gate-switch energy of every skipped sample alone guarantees a
        // strict gap, far above any noise-induced difference)
        let (mut twin, _) = mk_core(8, 4);
        let cfg0 = CircuitConfig::default();
        for _ in 0..6 {
            twin.step(&x, &cfg0, &mut out);
        }
        assert!(
            core.meter.total_j() < twin.meter.total_j(),
            "delta path must dissipate less on a quiescent stream"
        );
        // a slot reset reseeds the tracker — the next step fires again
        core.reset_slot(0, &cfg);
        core.step(&x, &cfg, &mut out);
        assert_eq!(core.delta_counters().components_fired, 16);
    }

    #[test]
    fn provisioned_slot_matches_fresh_core_with_instance_seed() {
        // The ADR-008 anchor: after provision_slot_devices, slot s of a
        // batched core is bit-identical — fabricated device AND runtime
        // noise stream — to a whole fresh core built with
        // cfg.seed = seeds[s], under full circuit noise.
        let cfg = CircuitConfig::default();
        let mk = |cfg: &CircuitConfig| {
            let col_cfgs: Vec<ColumnConfig> = (0..5)
                .map(|j| ColumnConfig {
                    w_h: (0..12).map(|i| W2::new(((i + j) % 4) as u8)).collect(),
                    w_z: (0..12)
                        .map(|i| W2::new(((i + 2 * j) % 4) as u8))
                        .collect(),
                    slope_m: 6,
                    offset_code: OFFSET_NEUTRAL,
                    v_theta: cfg.v_0,
                })
                .collect();
            Core::new(CoreGeometry { rows: 12, cols: 8 }, col_cfgs, cfg, 3)
        };
        let seeds = [0xAAAA_0001u64, 0xBBBB_0002, 0xCCCC_0003];
        let mut bat = mk(&cfg);
        bat.set_slots(3, &cfg);
        bat.provision_slot_devices(&cfg, &seeds);
        bat.reset(&cfg);
        assert!(bat.has_slot_devices());
        let (mut bo, mut fo) = (CoreStep::default(), CoreStep::default());
        for (s, &seed) in seeds.iter().enumerate() {
            let inst_cfg = CircuitConfig { seed, ..cfg.clone() };
            let mut fresh = mk(&inst_cfg);
            for t in 0..12 {
                let x: Vec<f64> =
                    (0..12).map(|i| ((t + i + s) % 2) as f64).collect();
                fresh.step(&x, &inst_cfg, &mut fo);
                bat.step_slot(s, &x, &cfg, &mut bo);
                for (p, q) in fo.steps.iter().zip(bo.steps.iter()) {
                    assert_eq!(p, q, "slot {s} diverged at step {t}");
                }
            }
        }
        // reset_slot restores the *instance* stream, not rng0
        bat.reset_slot(1, &cfg);
        let inst_cfg = CircuitConfig { seed: seeds[1], ..cfg.clone() };
        let mut fresh = mk(&inst_cfg);
        for t in 0..6 {
            let x: Vec<f64> = (0..12).map(|i| ((t + i) % 3) as f64 / 2.0).collect();
            fresh.step(&x, &inst_cfg, &mut fo);
            bat.step_slot(1, &x, &cfg, &mut bo);
            for (p, q) in fo.steps.iter().zip(bo.steps.iter()) {
                assert_eq!(p, q, "recycled instance slot diverged at {t}");
            }
        }
        // set_slots is a hard batch boundary: devices dissolve and the
        // ADR-001 clone convention returns
        bat.set_slots(2, &cfg);
        assert!(!bat.has_slot_devices());
        let mut plain = mk(&cfg);
        let x: Vec<f64> = (0..12).map(|i| (i % 2) as f64).collect();
        plain.step(&x, &cfg, &mut fo);
        bat.step_slot(0, &x, &cfg, &mut bo);
        for (p, q) in fo.steps.iter().zip(bo.steps.iter()) {
            assert_eq!(p, q, "post-dissolve slot 0 must match construction");
        }
    }

    #[test]
    fn energy_scales_with_array_size() {
        let (mut small, cfg) = mk_core(8, 4);
        let (mut big, _) = mk_core(32, 16);
        let mut out = CoreStep::default();
        small.step(&vec![1.0; 8], &cfg, &mut out);
        big.step(&vec![1.0; 32], &cfg, &mut out);
        // 16× the synapses → energy should be roughly an order more
        assert!(big.meter.total_j() > 5.0 * small.meter.total_j());
    }
}
