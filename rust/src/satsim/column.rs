//! One GRU column of a MINIMALIST core (paper Fig 2): N synapses, each
//! with three capacitors — a swappable h/h̃ pair and a z sampling cap —
//! plus the column's SAR ADC channel and output comparator.
//!
//! The four clock phases of one time step (paper §3.2):
//!   P1  sample: the *free* cap of every pair and the z cap charge to the
//!       weight rail selected by the local 2-bit SRAM code (row driver
//!       clamps to V_0 when x_i = 0; the first layer's analog pixel
//!       interpolates the rail, acting as the input DAC).
//!   P2  share: z caps short together (→ V^z, Eq. 6); free caps short
//!       together (→ V^h̃).
//!   P3  digitize: SAR conversion of V^z with the layer's slope segment
//!       and the channel's offset code → 6-bit z.
//!   P4  update: k = swap_count(z) cap pairs exchange roles; the h bank
//!       shorts → h_t = z·h̃ + (1−z)·h_{t−1} by pure charge redistribution
//!       (Eq. 1, no buffers). The ADC's comparator then strobes
//!       h_t vs the reference V_θ → binary output event (Eq. 4).

use crate::config::CircuitConfig;
use crate::energy::EnergyMeter;
use crate::quant::{Z6, W2};
use crate::satsim::adc::SarAdc;
use crate::satsim::caps::CapBank;
use crate::util::rng::Rng;

/// Static per-column configuration produced by the codesign mapping.
#[derive(Debug, Clone)]
pub struct ColumnConfig {
    /// 2-bit weight codes for the h̃ projection (one per row).
    pub w_h: Vec<W2>,
    /// 2-bit weight codes for the z projection (one per row).
    pub w_z: Vec<W2>,
    /// Number of z caps left connected during SAR conversion (slope).
    pub slope_m: usize,
    /// 6-bit ADC offset pre-set code (gate bias β).
    pub offset_code: u8,
    /// Output comparator reference (V): V_0 + θ·Δw/scale_wh.
    pub v_theta: f64,
}

/// Observables of one column step — the Fig 4 trace quantities.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ColumnStep {
    /// Converted gate code.
    pub z: Z6,
    /// Candidate-state voltage after the share.
    pub v_htilde: f64,
    /// Updated state voltage.
    pub v_h: f64,
    /// Comparator event output.
    pub y: bool,
}

/// One fabricated *device instance* of a column — the construction-time
/// mismatch draws and their derived caches, detached from any analog
/// state. By default every lockstep batch slot shares the column's one
/// construction-time device (ADR-001); a Monte-Carlo sweep opts a slot
/// into its own instance via [`Column::install_slot_device`] (ADR-008),
/// after which [`Column::bind_slot`] swaps the device identity along
/// with the slot's parked state.
#[derive(Debug, Clone)]
pub struct ColumnDevice {
    /// Pair-bank capacitances and derived kT/C / injection caches.
    pair_c: Vec<f64>,
    pair_ktc: Vec<f64>,
    pair_inj: Vec<f64>,
    /// Z-bank capacitances and derived caches.
    z_c: Vec<f64>,
    z_ktc: Vec<f64>,
    z_inj: Vec<f64>,
    /// The column's SAR ADC channel (DAC mismatch, comparator offset).
    adc: SarAdc,
    /// Deferred-noise aggregates recomputed from this instance's caps.
    agg_sigma_pair: f64,
    agg_shift_pair: f64,
    agg_sigma_z: f64,
    agg_shift_z: f64,
}

/// Parked analog state of one lockstep batch slot — everything a
/// concurrently-held sequence owns on this column, struct-of-arrays
/// across the column's capacitors. The *bound* slot's state lives in the
/// column's working fields; [`Column::bind_slot`] exchanges slots by
/// `mem::swap` of the vectors (pointer swaps — no copying, no allocation
/// in the steady state). The capacitor array itself (mismatch draws,
/// noise aggregates, the ADC) is shared hardware by default: slots only
/// multiply the held *state*, modelling a core that time-multiplexes B
/// concurrent sequences across its clock phases. A Monte-Carlo sweep
/// may opt a slot into its own fabricated [`ColumnDevice`] instance
/// (ADR-008), parked here alongside the state and swapped by the same
/// pointer-exchange discipline.
#[derive(Debug, Clone)]
struct ColumnSlot {
    pair_v: Vec<f64>,
    z_v: Vec<f64>,
    h_sel: Vec<bool>,
    idx_h: Vec<usize>,
    /// In-flight free-cap list of a two-phase step (between
    /// `phase_share` and `phase_update` of *this* slot, other slots may
    /// run their own phases — the list must park with the slot).
    idx_free: Vec<usize>,
    v_line_htilde: f64,
    v_line_z: f64,
    v_line_h: f64,
    /// Last share results (for the delta-sparsity whole-share skip —
    /// see [`Column::skip_share`]).
    last_vh: f64,
    last_vz: f64,
    /// This slot's own device instance, if opted in (ADR-008). While
    /// the slot is *parked* this holds its device; while it is *bound*
    /// its device occupies the working fields and this holds the
    /// displaced one (the construction hardware) — exactly the
    /// circulating-placeholder discipline the state vectors follow.
    device: Option<ColumnDevice>,
}

impl ColumnSlot {
    fn blank(n: usize, v_0: f64) -> ColumnSlot {
        ColumnSlot {
            pair_v: vec![v_0; 2 * n],
            z_v: vec![v_0; n],
            h_sel: vec![false; n],
            idx_h: (0..n).map(|i| 2 * i).collect(),
            idx_free: Vec::with_capacity(n),
            v_line_htilde: v_0,
            v_line_z: v_0,
            v_line_h: v_0,
            last_vh: v_0,
            last_vz: v_0,
            device: None,
        }
    }

    fn reset(&mut self, v_0: f64) {
        self.pair_v.fill(v_0);
        self.z_v.fill(v_0);
        self.h_sel.fill(false);
        self.idx_h.clear();
        for i in 0..self.h_sel.len() {
            self.idx_h.push(2 * i);
        }
        self.idx_free.clear();
        self.v_line_htilde = v_0;
        self.v_line_z = v_0;
        self.v_line_h = v_0;
        self.last_vh = v_0;
        self.last_vz = v_0;
    }
}

#[derive(Debug, Clone)]
/// One physical output column of a core.
pub struct Column {
    /// Static per-column configuration (weights, thresholds).
    pub cfg_col: ColumnConfig,
    /// 2N caps: pair i = indices (2i, 2i+1).
    pair_bank: CapBank,
    /// Which cap of pair i currently *holds the state h* (false = 2i,
    /// true = 2i+1). The other one is free for the next h̃ sampling.
    h_sel: Vec<bool>,
    /// N z sampling caps.
    z_bank: CapBank,
    /// The column's gate ADC.
    pub adc: SarAdc,
    /// Column line parasitics (track their held voltage between steps).
    v_line_htilde: f64,
    v_line_z: f64,
    v_line_h: f64,
    /// Last share results of the bound slot — the values
    /// [`Column::skip_share`] replays when the whole input frame is
    /// quiescent under the delta threshold. Valid once the slot has
    /// executed one real share (the cores' NaN-seeded delta trackers
    /// guarantee the first step of every slot always fires).
    last_vh: f64,
    last_vz: f64,
    /// Scratch index buffers (allocation-free hot path).
    idx_free: Vec<usize>,
    idx_h: Vec<usize>,
    idx_z: Vec<usize>,
    /// Per-row row-driver voltage scratch for the P1 lane loops
    /// (ADR-007): the drive voltages of a step are computed once into
    /// these fixed-stride buffers, then applied to the cap banks by the
    /// branch-free lane samplers. Transient within a phase — never
    /// parked per slot.
    drive_h: Vec<f64>,
    drive_z: Vec<f64>,
    /// Precomputed deferred-noise aggregates (see caps::sample_deferred):
    /// per-cap sampling noise and injection of a freshly sampled bank,
    /// collapsed into one share-time draw. Nominal values — the ±σ_C
    /// mismatch of which exact caps form the h̃ set changes these by
    /// O(σ_C/√N) ≈ 0.1 %, far below the noise itself.
    agg_sigma_pair: f64,
    agg_shift_pair: f64,
    agg_sigma_z: f64,
    agg_shift_z: f64,
    /// Parked per-slot state (lockstep batching). `slots[bound]` holds a
    /// placeholder while that slot's real state sits in the working
    /// fields above.
    slots: Vec<ColumnSlot>,
    bound: usize,
}

impl Column {
    /// Build a column, drawing its mismatch from `rng`.
    ///
    /// The device draw order — pair bank, z bank, ADC, in exactly three
    /// constructor sequences — is a pinned invariant:
    /// [`Column::install_slot_device`] must replay it verbatim so a
    /// Monte-Carlo slot device is bit-identical to the device a fresh
    /// column seeded the same way would fabricate (ADR-008).
    // lint: rng-draws(3, column-device)
    pub fn new(cfg_col: ColumnConfig, cfg: &CircuitConfig, rng: &mut Rng) -> Column {
        let n = cfg_col.w_h.len();
        assert_eq!(n, cfg_col.w_z.len());
        assert!(cfg_col.slope_m <= n);
        let pair_bank = CapBank::new(2 * n, cfg.c_unit, cfg, rng);
        let z_bank = CapBank::new(n, cfg.c_unit, cfg, rng);
        let adc = SarAdc::new(cfg, rng);
        let idx_z: Vec<usize> = (0..n).collect();
        // nominal "one cap per pair" set for the aggregates; also the
        // initial h index list (h_sel all false → caps 2i hold the state)
        let half: Vec<usize> = (0..n).map(|i| 2 * i).collect();
        let agg_sigma_pair = pair_bank.aggregate_sample_sigma(&half);
        let agg_shift_pair = pair_bank.aggregate_injection_shift(&half);
        let agg_sigma_z = z_bank.aggregate_sample_sigma(&idx_z);
        let agg_shift_z = z_bank.aggregate_injection_shift(&idx_z);
        Column {
            cfg_col,
            pair_bank,
            h_sel: vec![false; n],
            z_bank,
            adc,
            v_line_htilde: cfg.v_0,
            v_line_z: cfg.v_0,
            v_line_h: cfg.v_0,
            last_vh: cfg.v_0,
            last_vz: cfg.v_0,
            idx_free: Vec::with_capacity(n),
            idx_h: half,
            idx_z,
            drive_h: vec![0.0; n],
            drive_z: vec![0.0; n],
            agg_sigma_pair,
            agg_shift_pair,
            agg_sigma_z,
            agg_shift_z,
            slots: vec![ColumnSlot::blank(n, cfg.v_0)],
            bound: 0,
        }
    }

    /// Physical rows (replication included).
    pub fn rows(&self) -> usize {
        self.h_sel.len()
    }

    /// Number of lockstep batch slots this column holds state for.
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Provision `n` batch slots (clamped to ≥ 1) and reset them all —
    /// a batch boundary. Allocation happens here, never in `bind_slot`.
    /// Any per-slot Monte-Carlo devices are dissolved first: a batch
    /// boundary returns the column to the default shared-hardware
    /// convention (ADR-001), construction device back in the working
    /// fields.
    pub fn set_slots(&mut self, n: usize, cfg: &CircuitConfig) {
        self.dissolve_devices();
        let n = n.max(1);
        let rows = self.rows();
        let v_0 = cfg.v_0;
        self.slots.resize_with(n, || ColumnSlot::blank(rows, v_0));
        self.bound = 0;
        self.reset(cfg);
    }

    /// Whether any slot carries its own device instance (ADR-008).
    pub fn has_slot_devices(&self) -> bool {
        self.slots.iter().any(|s| s.device.is_some())
    }

    /// Fabricate a fresh device instance for batch slot `slot` from
    /// `rng`, replacing the shared construction hardware for that slot
    /// only (ADR-008). Replays [`Column::new`]'s exact device draw
    /// order — pair bank, z bank, ADC — so the installed device is
    /// bit-identical to what a fresh column seeded with `rng` would
    /// fabricate. Cold path: runs once per Monte-Carlo provisioning,
    /// never inside the lockstep step.
    // lint: rng-draws(3, column-device)
    pub fn install_slot_device(
        &mut self,
        slot: usize,
        cfg: &CircuitConfig,
        rng: &mut Rng,
    ) {
        assert!(
            slot < self.slots.len(),
            "slot {slot} out of range ({} provisioned)",
            self.slots.len()
        );
        let n = self.rows();
        // the pinned Column::new device sequence: pair bank → z bank → ADC
        let pair = CapBank::new(2 * n, cfg.c_unit, cfg, rng);
        let z = CapBank::new(n, cfg.c_unit, cfg, rng);
        let adc = SarAdc::new(cfg, rng);
        let half: Vec<usize> = (0..n).map(|i| 2 * i).collect();
        let idx_z: Vec<usize> = (0..n).collect();
        let agg_sigma_pair = pair.aggregate_sample_sigma(&half);
        let agg_shift_pair = pair.aggregate_injection_shift(&half);
        let agg_sigma_z = z.aggregate_sample_sigma(&idx_z);
        let agg_shift_z = z.aggregate_injection_shift(&idx_z);
        let (pair_c, pair_ktc, pair_inj) = pair.into_device_parts();
        let (z_c, z_ktc, z_inj) = z.into_device_parts();
        let mut d = ColumnDevice {
            pair_c,
            pair_ktc,
            pair_inj,
            z_c,
            z_ktc,
            z_inj,
            adc,
            agg_sigma_pair,
            agg_shift_pair,
            agg_sigma_z,
            agg_shift_z,
        };
        if slot == self.bound {
            // The bound slot's device lives in the working fields. Swap
            // the new instance in; the displaced device becomes the
            // circulating placeholder in `slots[bound]` if it is the
            // construction hardware (first install), and is dropped if
            // it is a previous install being replaced.
            self.swap_device_fields(&mut d);
            if self.slots[slot].device.is_none() {
                self.slots[slot].device = Some(d);
            }
        } else {
            self.slots[slot].device = Some(d);
        }
    }

    /// Drop every per-slot device and restore the construction
    /// hardware to the working fields — back to the ADR-001 default.
    pub fn dissolve_devices(&mut self) {
        // If the bound slot is opted in, its placeholder holds the
        // construction device: swap it back in (the bound slot's own
        // instance comes out and is dropped with the rest).
        if let Some(mut d) = self.slots[self.bound].device.take() {
            self.swap_device_fields(&mut d);
        }
        for st in self.slots.iter_mut() {
            st.device = None;
        }
    }

    /// Exchange the column's working device identity (cap populations,
    /// derived caches, ADC, aggregates) with `d`. O(1) pointer swaps.
    fn swap_device_fields(&mut self, d: &mut ColumnDevice) {
        self.pair_bank
            .swap_device(&mut d.pair_c, &mut d.pair_ktc, &mut d.pair_inj);
        self.z_bank.swap_device(&mut d.z_c, &mut d.z_ktc, &mut d.z_inj);
        std::mem::swap(&mut self.adc, &mut d.adc);
        std::mem::swap(&mut self.agg_sigma_pair, &mut d.agg_sigma_pair);
        std::mem::swap(&mut self.agg_shift_pair, &mut d.agg_shift_pair);
        std::mem::swap(&mut self.agg_sigma_z, &mut d.agg_sigma_z);
        std::mem::swap(&mut self.agg_shift_z, &mut d.agg_shift_z);
    }

    /// Make batch slot `slot` the working state: park the currently
    /// bound slot and swap `slot`'s vectors in. Pure pointer swaps — the
    /// steady-state batched step allocates nothing here.
    pub fn bind_slot(&mut self, slot: usize) {
        assert!(
            slot < self.slots.len(),
            "slot {slot} out of range ({} provisioned)",
            self.slots.len()
        );
        if slot == self.bound {
            return;
        }
        let prev = self.bound;
        self.swap_slot(prev);
        self.swap_slot(slot);
        self.bound = slot;
    }

    fn swap_slot(&mut self, s: usize) {
        let st = &mut self.slots[s];
        std::mem::swap(&mut self.pair_bank.v, &mut st.pair_v);
        std::mem::swap(&mut self.z_bank.v, &mut st.z_v);
        std::mem::swap(&mut self.h_sel, &mut st.h_sel);
        std::mem::swap(&mut self.idx_h, &mut st.idx_h);
        std::mem::swap(&mut self.idx_free, &mut st.idx_free);
        std::mem::swap(&mut self.v_line_htilde, &mut st.v_line_htilde);
        std::mem::swap(&mut self.v_line_z, &mut st.v_line_z);
        std::mem::swap(&mut self.v_line_h, &mut st.v_line_h);
        std::mem::swap(&mut self.last_vh, &mut st.last_vh);
        std::mem::swap(&mut self.last_vz, &mut st.last_vz);
        // Monte-Carlo opt-in (ADR-008): a slot carrying its own device
        // instance swaps the device identity along with its state —
        // same O(1) pointer-exchange discipline, still allocation-free.
        // Slots without a device run on whatever device the working
        // fields hold (the shared construction hardware).
        if let Some(d) = st.device.as_mut() {
            self.pair_bank
                .swap_device(&mut d.pair_c, &mut d.pair_ktc, &mut d.pair_inj);
            self.z_bank.swap_device(&mut d.z_c, &mut d.z_ktc, &mut d.z_inj);
            std::mem::swap(&mut self.adc, &mut d.adc);
            std::mem::swap(&mut self.agg_sigma_pair, &mut d.agg_sigma_pair);
            std::mem::swap(&mut self.agg_shift_pair, &mut d.agg_shift_pair);
            std::mem::swap(&mut self.agg_sigma_z, &mut d.agg_sigma_z);
            std::mem::swap(&mut self.agg_shift_z, &mut d.agg_shift_z);
        }
    }

    /// Current hidden-state voltage (capacitance-weighted over the h
    /// bank). Reads the maintained `idx_h` scratch list — no allocation.
    pub fn v_h(&self) -> f64 {
        self.pair_bank.weighted_mean(&self.idx_h)
    }

    /// Reset the state of batch slot `slot` **alone** to V_0, leaving
    /// every other slot's parked state untouched — the slot-lease path
    /// of streaming sessions, where one sequence ends while its
    /// neighbors keep running. After this, the slot is indistinguishable
    /// from a freshly [`Column::reset`] one.
    pub fn reset_slot(&mut self, slot: usize, cfg: &CircuitConfig) {
        assert!(
            slot < self.slots.len(),
            "slot {slot} out of range ({} provisioned)",
            self.slots.len()
        );
        if slot == self.bound {
            // the bound slot's real state lives in the working fields
            for v in self.pair_bank.v.iter_mut() {
                *v = cfg.v_0;
            }
            for v in self.z_bank.v.iter_mut() {
                *v = cfg.v_0;
            }
            self.v_line_htilde = cfg.v_0;
            self.v_line_z = cfg.v_0;
            self.v_line_h = cfg.v_0;
            self.last_vh = cfg.v_0;
            self.last_vz = cfg.v_0;
            for s in self.h_sel.iter_mut() {
                *s = false;
            }
            self.rebuild_idx_h();
            self.idx_free.clear();
        } else {
            self.slots[slot].reset(cfg.v_0);
        }
    }

    /// Reset the state caps (and lines) of **every** slot to V_0.
    pub fn reset(&mut self, cfg: &CircuitConfig) {
        for v in self.pair_bank.v.iter_mut() {
            *v = cfg.v_0;
        }
        for v in self.z_bank.v.iter_mut() {
            *v = cfg.v_0;
        }
        self.v_line_htilde = cfg.v_0;
        self.v_line_z = cfg.v_0;
        self.v_line_h = cfg.v_0;
        self.last_vh = cfg.v_0;
        self.last_vz = cfg.v_0;
        for s in self.h_sel.iter_mut() {
            *s = false;
        }
        self.rebuild_idx_h();
        self.idx_free.clear();
        for slot in self.slots.iter_mut() {
            slot.reset(cfg.v_0);
        }
    }

    /// Keep `idx_h` in sync with `h_sel` (it doubles as the index list
    /// `v_h()` reads between steps).
    fn rebuild_idx_h(&mut self) {
        self.idx_h.clear();
        for i in 0..self.h_sel.len() {
            // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all rows)
            self.idx_h.push(2 * i + self.h_sel[i] as usize);
        }
    }

    /// Row-driver voltage: x = 0 clamps to V_0, x = 1 selects the rail;
    /// fractional x (first layer) interpolates — the input DAC.
    #[inline]
    fn drive(cfg: &CircuitConfig, x: f64, w: W2) -> f64 {
        cfg.v_0 + x * (cfg.rail_voltage(w.0) - cfg.v_0)
    }

    /// Execute one time step (phases P1–P4) for input activations `x`
    /// (length N; binary {0,1} or analog [0,1] for the first layer).
    pub fn step(
        &mut self,
        x: &[f64],
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> ColumnStep {
        let (v_htilde, v_z) = self.phase_share(x, cfg, rng, meter);
        self.phase_update(v_htilde, v_z, cfg, rng, meter)
    }

    /// Phases P1–P2 only: sample onto the weight rails and charge-share.
    /// Returns the settled (h̃, z) node voltages — *partial* IMC means
    /// when this column is one row tile of a split layer. The step is
    /// completed by [`Column::phase_update`] (after an optional
    /// [`Column::override_share`] with the inter-tile combined values).
    ///
    /// P1 runs as three fixed-stride lane loops (ADR-007): drive
    /// voltages, free-cap indices (select arithmetic, no branch), and
    /// the branch-free lane samplers of [`CapBank`]. The RNG draw
    /// order — the externally pinned invariant — is untouched: P1 draws
    /// nothing (noise is deferred), P2 draws exactly its two normals.
    // lint: rng-draws(2, column-share)
    pub fn phase_share(
        &mut self,
        x: &[f64],
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> (f64, f64) {
        let n = self.rows();
        debug_assert_eq!(x.len(), n);

        // ---- P1: sample (noise deferred to the share; exact — see
        // caps::sample_deferred) -------------------------------------------
        // lane 1: row-driver voltages, pure fixed-stride arithmetic
        for i in 0..n {
            self.drive_h[i] = Self::drive(cfg, x[i], self.cfg_col.w_h[i]);
            self.drive_z[i] = Self::drive(cfg, x[i], self.cfg_col.w_z[i]);
        }
        // lane 2: free-cap indices — `idx_h` stays valid across the
        // step: the holding caps are untouched until the P4 swap
        // rebuilds the list.
        self.idx_free.clear();
        for i in 0..n {
            // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all rows)
            self.idx_free.push(2 * i + (!self.h_sel[i]) as usize);
        }
        // lanes 3+4: gather-sample the free pair caps, unit-stride the z
        self.pair_bank
            .sample_deferred_lane(&self.idx_free, &self.drive_h, meter);
        self.z_bank.sample_deferred_lane_contig(&self.drive_z, meter);

        // ---- P2: charge share (Eq. 6) ------------------------------------
        let v_htilde = self.pair_bank.share_with(
            &self.idx_free,
            Some((cfg.c_line, self.v_line_htilde)),
            self.agg_sigma_pair,
            self.agg_shift_pair,
            cfg,
            rng,
            meter,
        );
        self.v_line_htilde = v_htilde;
        let v_z = self.z_bank.share_with(
            &self.idx_z,
            Some((cfg.c_line, self.v_line_z)),
            self.agg_sigma_z,
            self.agg_shift_z,
            cfg,
            rng,
            meter,
        );
        self.v_line_z = v_z;
        (v_htilde, v_z)
    }

    /// [`Column::phase_share`] with a per-component delta-sparsity fire
    /// mask (ADR-005): component `i` samples onto the rails only when
    /// `fired[i]`; quiescent components keep their caps at the rail
    /// voltage of the last value they fired with (`x[i]` is the core's
    /// *effective* held input, so the cap voltage is simply rewritten —
    /// no switching, no charge draw, which is exactly the energy the
    /// delta network saves in hardware). The P2 charge share then runs
    /// unchanged over the full cap sets — identical summation order and
    /// identical noise draws — so with every component fired this is
    /// bit-identical to [`Column::phase_share`], meter included.
    ///
    /// The mask is applied by *select*, not branch (ADR-007): every
    /// component's cap voltage is written unconditionally (a quiescent
    /// cap already holds its last-fired rail, so the write is the
    /// identity) while the metered charge/toggle contributions are
    /// zeroed lane-wise for quiescent elements. The P1 loops therefore
    /// share their exact structure with [`Column::phase_share`] —
    /// mandatory, since the all-fired mask must stay bit-identical.
    // lint: rng-draws(2, column-share)
    pub fn phase_share_masked(
        &mut self,
        x: &[f64],
        fired: &[bool],
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> (f64, f64) {
        let n = self.rows();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(fired.len(), n);

        // ---- P1: sample, metering fired components only ------------------
        for i in 0..n {
            self.drive_h[i] = Self::drive(cfg, x[i], self.cfg_col.w_h[i]);
            self.drive_z[i] = Self::drive(cfg, x[i], self.cfg_col.w_z[i]);
        }
        self.idx_free.clear();
        for i in 0..n {
            // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all rows)
            self.idx_free.push(2 * i + (!self.h_sel[i]) as usize);
        }
        self.pair_bank.sample_deferred_lane_masked(
            &self.idx_free,
            &self.drive_h,
            fired,
            meter,
        );
        self.z_bank
            .sample_deferred_lane_contig_masked(&self.drive_z, fired, meter);

        // ---- P2: charge share, exactly as in phase_share -----------------
        let v_htilde = self.pair_bank.share_with(
            &self.idx_free,
            Some((cfg.c_line, self.v_line_htilde)),
            self.agg_sigma_pair,
            self.agg_shift_pair,
            cfg,
            rng,
            meter,
        );
        self.v_line_htilde = v_htilde;
        let v_z = self.z_bank.share_with(
            &self.idx_z,
            Some((cfg.c_line, self.v_line_z)),
            self.agg_sigma_z,
            self.agg_shift_z,
            cfg,
            rng,
            meter,
        );
        self.v_line_z = v_z;
        self.last_vh = v_htilde;
        self.last_vz = v_z;
        (v_htilde, v_z)
    }

    /// Whole-share skip for a fully quiescent input frame (ADR-005):
    /// every component of this core's slice is under the delta
    /// threshold, so the share is not executed at all — the column
    /// replays its cached (h̃, z) result from the last executed share.
    /// The free-cap list is still rebuilt (the h/h̃ roles swapped last
    /// P4) and, in non-ideal configs, the two share noise draws are
    /// still burned so the downstream ADC and comparator draws land on
    /// the same RNG stream positions as an executed share — a skip
    /// perturbs only the share it skipped, never the rest of the step,
    /// which is what keeps sequential/batched/streamed outputs in
    /// lockstep at `delta > 0`. The caps themselves are not written:
    /// the engine's finish phase applies the combined share result via
    /// [`Column::override_share`] before [`Column::phase_update`] runs.
    // lint: rng-draws(2, column-share)
    pub fn skip_share(&mut self, cfg: &CircuitConfig, rng: &mut Rng) -> (f64, f64) {
        let n = self.rows();
        self.idx_free.clear();
        for i in 0..n {
            // lint: allow(alloc, push into a cleared scratch list that already holds capacity for all rows)
            self.idx_free.push(2 * i + (!self.h_sel[i]) as usize);
        }
        if !cfg.ideal {
            // the h̃ and z shares of an executed phase_share draw one
            // normal each — keep the stream aligned
            rng.normal_fast();
            rng.normal_fast();
        }
        (self.last_vh, self.last_vz)
    }

    /// Model the inter-tile column-line short of a row-split layer:
    /// every cap on this column's h̃ and z lines settles at the
    /// externally combined (row-count-weighted mean) voltages. Calling
    /// it with the column's own [`Column::phase_share`] results is a
    /// numeric no-op — the caps already sit at those voltages. The
    /// dissipation of the inter-tile short itself is not metered (it is
    /// bounded by the intra-tile share already accounted).
    pub fn override_share(&mut self, v_htilde: f64, v_z: f64) {
        debug_assert_eq!(self.idx_free.len(), self.rows());
        for &i in &self.idx_free {
            self.pair_bank.v[i] = v_htilde;
        }
        self.v_line_htilde = v_htilde;
        for v in self.z_bank.v.iter_mut() {
            *v = v_z;
        }
        self.v_line_z = v_z;
    }

    /// Phases P3–P4: SAR digitization of `v_z`, capacitor-swap state
    /// update, output comparator. Must follow a [`Column::phase_share`]
    /// in the same time step; `v_htilde`/`v_z` are that share's results
    /// (or the combined values of a row-split layer, already applied to
    /// the banks via [`Column::override_share`]).
    pub fn phase_update(
        &mut self,
        v_htilde: f64,
        v_z: f64,
        cfg: &CircuitConfig,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> ColumnStep {
        let n = self.rows();
        debug_assert_eq!(self.idx_free.len(), n, "phase_update without phase_share");

        // ---- P3: SAR digitization of z (Fig 3) ---------------------------
        // The first `slope_m` z caps stay connected; the rest disconnect
        // (binary-scaled segment switches), tuning C_ADC/C_IMC.
        let c_ext: f64 = self.z_bank.c[..self.cfg_col.slope_m]
            .iter()
            .sum::<f64>()
            + cfg.c_line;
        let z_code = self.adc.convert(
            v_z,
            c_ext,
            self.cfg_col.offset_code,
            cfg,
            rng,
            meter,
        );
        let z = Z6::new(z_code);

        // ---- P4: capacitor-swap state update (Eq. 1) ---------------------
        // lane flip of the first k pair selectors (branch-free), the
        // per-pair bank-select switch toggles hoisted to one meter call
        let k = z.swap_count(n);
        for s in self.h_sel[..k].iter_mut() {
            *s = !*s;
        }
        meter.toggles(cfg, 2 * k as u64); // two bank-select switches/pair
        // rebuild the h index list after the swap
        self.rebuild_idx_h();
        let v_h = self.pair_bank.share(
            &self.idx_h,
            Some((cfg.c_line, self.v_line_h)),
            cfg,
            rng,
            meter,
        );
        self.v_line_h = v_h;

        // ---- output comparator (Eq. 4), re-using the ADC's comparator ----
        let y = self
            .adc
            .comparator
            .decide(v_h, self.cfg_col.v_theta, cfg, rng, meter);

        ColumnStep { z, v_htilde, v_h, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::satsim::adc::OFFSET_NEUTRAL;

    fn mk_col(n: usize, wh: u8, wz: u8, ideal: bool) -> (Column, CircuitConfig, Rng) {
        let cfg = if ideal { CircuitConfig::ideal() } else { CircuitConfig::default() };
        let mut rng = Rng::new(5);
        let col_cfg = ColumnConfig {
            w_h: vec![W2::new(wh); n],
            w_z: vec![W2::new(wz); n],
            slope_m: n / 2,
            offset_code: OFFSET_NEUTRAL,
            v_theta: cfg.v_0,
        };
        let col = Column::new(col_cfg, &cfg, &mut rng);
        (col, cfg, rng)
    }

    #[test]
    fn share_computes_imc_mean() {
        // all weights = code 3 (+1.5Δw), half the inputs active →
        // V_htilde = V_0 + 1.5Δw·(k/n)
        let n = 16;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        let mut meter = EnergyMeter::new();
        let mut x = vec![0.0; n];
        for xi in x.iter_mut().take(8) {
            *xi = 1.0;
        }
        let out = col.step(&x, &cfg, &mut rng, &mut meter);
        let expect = cfg.v_0 + 1.5 * cfg.delta_w * 8.0 / 16.0;
        assert!(
            (out.v_htilde - expect).abs() < 1e-9,
            "v_htilde {} vs {}",
            out.v_htilde,
            expect
        );
    }

    #[test]
    fn state_update_is_convex_mixture() {
        // z saturates high (wz = code 3, all x active, gentle slope) →
        // state moves fully to h̃; z = 0 keeps the state.
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        let mut meter = EnergyMeter::new();
        let x = vec![1.0; n];
        let before = col.v_h();
        let out = col.step(&x, &cfg, &mut rng, &mut meter);
        let z = out.z.value() as f64;
        let expect = z * out.v_htilde + (1.0 - z) * before;
        assert!(
            (out.v_h - expect).abs() < 1e-9,
            "v_h {} expect {} (z={})",
            out.v_h,
            expect,
            z
        );
    }

    #[test]
    fn z_zero_freezes_state() {
        // wz = code 0 (−1.5Δw) with all inputs on and a steep slope drives
        // the ADC to 0 → swap count 0 → h unchanged.
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 0, true);
        col.cfg_col.slope_m = n; // steep
        let mut meter = EnergyMeter::new();
        // preload state away from V_0 to see it held
        let x = vec![1.0; n];
        let s1 = col.step(&x, &cfg, &mut rng, &mut meter);
        assert_eq!(s1.z.0, 0, "gate should be fully closed");
        let before = col.v_h();
        let s2 = col.step(&x, &cfg, &mut rng, &mut meter);
        assert_eq!(s2.v_h, before, "state must be untouched at z=0");
    }

    #[test]
    fn output_comparator_thresholds() {
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        let mut meter = EnergyMeter::new();
        let x = vec![1.0; n];
        let out = col.step(&x, &cfg, &mut rng, &mut meter);
        // v_h rose above V_0 (positive weights), θ = V_0 → fires
        assert!(out.v_h > cfg.v_0);
        assert!(out.y);
        // raise the threshold above reach → silent
        col.cfg_col.v_theta = cfg.v_0 + 10.0;
        let out2 = col.step(&x, &cfg, &mut rng, &mut meter);
        assert!(!out2.y);
    }

    #[test]
    fn inactive_rows_clamp_to_v0() {
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        let mut meter = EnergyMeter::new();
        let out = col.step(&vec![0.0; n], &cfg, &mut rng, &mut meter);
        assert!((out.v_htilde - cfg.v_0).abs() < 1e-9);
        assert!((out.z.value() - 0.5).abs() < 0.02); // hardsig(0) = ½
    }

    #[test]
    fn analog_input_interpolates() {
        let n = 1;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 1, true);
        let mut meter = EnergyMeter::new();
        let out = col.step(&[0.5], &cfg, &mut rng, &mut meter);
        let expect = cfg.v_0 + 0.5 * 1.5 * cfg.delta_w;
        assert!((out.v_htilde - expect).abs() < 1e-9);
    }

    #[test]
    fn phased_step_is_bit_identical_to_monolithic_step() {
        // The engine executes row-split layers via phase_share /
        // override_share / phase_update; with the column's own share
        // results that path must reproduce step() exactly — including
        // the noise stream (same rng draw order).
        let n = 10;
        let (mut a, cfg, mut rng_a) = mk_col(n, 3, 1, false);
        let (mut b, _, mut rng_b) = mk_col(n, 3, 1, false);
        let mut ma = EnergyMeter::new();
        let mut mb = EnergyMeter::new();
        for t in 0..30 {
            let x: Vec<f64> = (0..n).map(|i| ((t + i) % 3 == 0) as u8 as f64).collect();
            let sa = a.step(&x, &cfg, &mut rng_a, &mut ma);
            let (vh, vz) = b.phase_share(&x, &cfg, &mut rng_b, &mut mb);
            b.override_share(vh, vz);
            let sb = b.phase_update(vh, vz, &cfg, &mut rng_b, &mut mb);
            assert_eq!(sa, sb, "diverged at step {t}");
        }
        assert_eq!(ma, mb);
    }

    #[test]
    fn override_share_drives_the_state_update() {
        // Overriding the shared h̃ line with an external voltage must
        // make the capacitor-swap update mix toward *that* voltage —
        // the combine semantics of row-split layers.
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        let mut meter = EnergyMeter::new();
        let x = vec![1.0; n];
        let before = col.v_h();
        let (_vh, vz) = col.phase_share(&x, &cfg, &mut rng, &mut meter);
        let v_comb = cfg.v_0 + 0.123; // externally combined h̃
        col.override_share(v_comb, vz);
        let out = col.phase_update(v_comb, vz, &cfg, &mut rng, &mut meter);
        let k = out.z.swap_count(n) as f64 / n as f64;
        let expect = k * v_comb + (1.0 - k) * before;
        assert!(
            (out.v_h - expect).abs() < 1e-9,
            "v_h {} expect {expect} (k={k})",
            out.v_h
        );
    }

    #[test]
    fn slots_hold_independent_state_and_swap_cleanly() {
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        col.set_slots(2, &cfg);
        assert_eq!(col.n_slots(), 2);
        let mut meter = EnergyMeter::new();
        // slot 0 sees active inputs and moves; slot 1 sees silence
        col.bind_slot(0);
        let s0 = col.step(&vec![1.0; n], &cfg, &mut rng, &mut meter);
        col.bind_slot(1);
        let s1 = col.step(&vec![0.0; n], &cfg, &mut rng, &mut meter);
        assert!(s0.v_h > cfg.v_0, "driven slot must move off V_0");
        assert!(
            (s1.v_h - cfg.v_0).abs() < 1e-9,
            "silent slot must stay at V_0, got {}",
            s1.v_h
        );
        // rebinding restores each slot's state (v_h() re-averages the
        // bank, so allow f64 summation rounding)
        col.bind_slot(0);
        assert!((col.v_h() - s0.v_h).abs() < 1e-12);
        col.bind_slot(1);
        assert!((col.v_h() - s1.v_h).abs() < 1e-12);
    }

    #[test]
    fn slot_zero_of_multi_slot_column_matches_single_slot_run() {
        // Interleaving another slot's steps must not perturb slot 0 —
        // the state swap has to be exact, phases included. Same rng
        // stream drives both columns' slot-0 steps; the multi-slot
        // column's slot-1 steps draw from a separate stream, as the
        // core's per-slot streams do.
        let n = 10;
        let (mut a, cfg, mut rng_a) = mk_col(n, 3, 1, false);
        let (mut b, _, mut rng_b) = mk_col(n, 3, 1, false);
        b.set_slots(3, &cfg);
        let mut rng_b1 = Rng::new(777);
        let (mut ma, mut mb) = (EnergyMeter::new(), EnergyMeter::new());
        for t in 0..20 {
            let x: Vec<f64> =
                (0..n).map(|i| ((t + i) % 3 == 0) as u8 as f64).collect();
            let y: Vec<f64> = (0..n).map(|i| ((t + i) % 2) as f64).collect();
            let sa = a.step(&x, &cfg, &mut rng_a, &mut ma);
            b.bind_slot(1);
            b.step(&y, &cfg, &mut rng_b1, &mut mb);
            b.bind_slot(0);
            let sb = b.step(&x, &cfg, &mut rng_b, &mut mb);
            assert_eq!(sa, sb, "slot 0 diverged at step {t}");
        }
    }

    #[test]
    fn reset_slot_touches_only_its_slot() {
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        col.set_slots(3, &cfg);
        let mut meter = EnergyMeter::new();
        let x = vec![1.0; n];
        // drive all three slots off V_0
        for s in 0..3 {
            col.bind_slot(s);
            col.step(&x, &cfg, &mut rng, &mut meter);
        }
        let v2 = {
            col.bind_slot(2);
            col.v_h()
        };
        // reset a parked slot (0) and the bound slot (2)
        col.reset_slot(0, &cfg);
        assert!(v2 > cfg.v_0);
        col.reset_slot(2, &cfg);
        col.bind_slot(0);
        assert!((col.v_h() - cfg.v_0).abs() < 1e-12, "slot 0 not reset");
        col.bind_slot(2);
        assert!((col.v_h() - cfg.v_0).abs() < 1e-12, "slot 2 not reset");
        // slot 1 survived both resets
        col.bind_slot(1);
        assert!(col.v_h() > cfg.v_0, "slot 1 must keep its state");
    }

    #[test]
    fn set_slots_resets_every_slot() {
        let n = 6;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 3, true);
        col.set_slots(2, &cfg);
        let mut meter = EnergyMeter::new();
        col.bind_slot(1);
        col.step(&vec![1.0; n], &cfg, &mut rng, &mut meter);
        assert!(col.v_h() > cfg.v_0);
        // re-provisioning (same count) is a batch boundary: all slots
        // return to V_0 and slot 0 is bound again
        col.set_slots(2, &cfg);
        for s in 0..2 {
            col.bind_slot(s);
            assert!((col.v_h() - cfg.v_0).abs() < 1e-12, "slot {s} not reset");
        }
    }

    #[test]
    fn masked_share_with_all_fired_is_bit_identical() {
        // With every component firing, the delta-masked share must be
        // indistinguishable from the unmasked one — values, rng stream
        // and energy meter alike (the delta=0 ≡ delta→0⁺ anchor).
        let n = 10;
        let (mut a, cfg, mut rng_a) = mk_col(n, 3, 1, false);
        let (mut b, _, mut rng_b) = mk_col(n, 3, 1, false);
        let (mut ma, mut mb) = (EnergyMeter::new(), EnergyMeter::new());
        let fired = vec![true; n];
        for t in 0..20 {
            let x: Vec<f64> =
                (0..n).map(|i| ((t + i) % 3 == 0) as u8 as f64).collect();
            let (vha, vza) = a.phase_share(&x, &cfg, &mut rng_a, &mut ma);
            a.override_share(vha, vza);
            let sa = a.phase_update(vha, vza, &cfg, &mut rng_a, &mut ma);
            let (vhb, vzb) =
                b.phase_share_masked(&x, &fired, &cfg, &mut rng_b, &mut mb);
            b.override_share(vhb, vzb);
            let sb = b.phase_update(vhb, vzb, &cfg, &mut rng_b, &mut mb);
            assert_eq!((vha, vza), (vhb, vzb), "share diverged at step {t}");
            assert_eq!(sa, sb, "step diverged at step {t}");
        }
        assert_eq!(ma, mb);
    }

    #[test]
    fn skip_share_replays_cache_and_burns_share_draws() {
        let n = 8;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 1, false);
        let mut meter = EnergyMeter::new();
        let x = vec![1.0; n];
        // one executed masked share validates the cache
        let fired = vec![true; n];
        let (vh0, vz0) =
            col.phase_share_masked(&x, &fired, &cfg, &mut rng, &mut meter);
        col.override_share(vh0, vz0);
        col.phase_update(vh0, vz0, &cfg, &mut rng, &mut meter);
        let mut twin = col.clone();
        let mut rng_twin = rng.clone();
        let mut meter_twin = meter.clone();
        // quiescent frame: executed (mask all-false) vs skipped share
        let quiet = vec![false; n];
        let _ = col.phase_share_masked(&x, &quiet, &cfg, &mut rng, &mut meter);
        let (vh, vz) = twin.skip_share(&cfg, &mut rng_twin);
        // the skip replays the last executed share's settled values
        assert_eq!((vh, vz), (vh0, vz0));
        // and burns exactly the two draws an executed share consumes,
        // so the downstream P3/P4 draws stay stream-aligned
        assert_eq!(
            rng.normal_fast().to_bits(),
            rng_twin.normal_fast().to_bits(),
            "rng streams misaligned after skip_share"
        );
        // the quiescent masked share metered only the share itself (no
        // P1 sampling events); the skip metered nothing at all
        assert!(meter.switch_toggles > meter_twin.switch_toggles);
        // idx_free was rebuilt, so the finish phases run normally
        twin.override_share(vh, vz);
        twin.phase_update(vh, vz, &cfg, &mut rng_twin, &mut meter_twin);
    }

    #[test]
    fn skip_share_draws_nothing_when_ideal() {
        let n = 6;
        let (mut col, cfg, mut rng) = mk_col(n, 3, 1, true);
        let mut meter = EnergyMeter::new();
        let fired = vec![true; n];
        let x = vec![1.0; n];
        let (vh, vz) =
            col.phase_share_masked(&x, &fired, &cfg, &mut rng, &mut meter);
        col.override_share(vh, vz);
        col.phase_update(vh, vz, &cfg, &mut rng, &mut meter);
        let mut probe = rng.clone();
        col.skip_share(&cfg, &mut rng);
        // the ideal path has no share noise, so nothing may be burned
        assert_eq!(rng.normal_fast().to_bits(), probe.normal_fast().to_bits());
    }

    #[test]
    fn installed_slot_device_matches_fresh_column_fabrication() {
        // the ADR-008 anchor at the column level: a slot device
        // fabricated from an rng stream is bit-identical to the device
        // a fresh column constructed from the same stream would carry
        let n = 8;
        let (mut col, cfg, _) = mk_col(n, 3, 3, false);
        let construction_c = col.pair_bank.c.clone();
        col.set_slots(2, &cfg);
        let mut dev_rng = Rng::new(0xD0D0);
        col.install_slot_device(1, &cfg, &mut dev_rng);
        assert!(col.has_slot_devices());
        let mut fresh_rng = Rng::new(0xD0D0);
        let fresh = Column::new(col.cfg_col.clone(), &cfg, &mut fresh_rng);
        col.bind_slot(1);
        assert_eq!(col.pair_bank.c, fresh.pair_bank.c);
        assert_eq!(col.z_bank.c, fresh.z_bank.c);
        assert_ne!(
            col.pair_bank.c, construction_c,
            "slot 1's device must be its own fabrication"
        );
        // binding a non-opted slot restores the construction hardware
        col.bind_slot(0);
        assert_eq!(col.pair_bank.c, construction_c);
        // and a batch boundary dissolves the opt-in entirely
        col.bind_slot(1);
        col.set_slots(2, &cfg);
        assert!(!col.has_slot_devices());
        assert_eq!(col.pair_bank.c, construction_c);
    }

    #[test]
    fn distinct_slot_devices_hold_distinct_mismatch_draws() {
        let n = 10;
        let (mut col, cfg, _) = mk_col(n, 3, 3, false);
        col.set_slots(3, &cfg);
        let mut r1 = Rng::new(101);
        let mut r2 = Rng::new(202);
        col.install_slot_device(1, &cfg, &mut r1);
        col.install_slot_device(2, &cfg, &mut r2);
        col.bind_slot(1);
        let c1 = col.pair_bank.c.clone();
        let a1 = col.agg_sigma_pair;
        col.bind_slot(2);
        assert_ne!(col.pair_bank.c, c1, "distinct seeds must give distinct devices");
        assert_ne!(col.agg_sigma_pair, a1);
        // rebinding restores slot 1's exact device
        col.bind_slot(1);
        assert_eq!(col.pair_bank.c, c1);
        assert_eq!(col.agg_sigma_pair, a1);
    }

    #[test]
    fn bound_slot_install_keeps_construction_as_placeholder() {
        // installing on the *bound* slot must still restore the
        // construction hardware when another slot binds afterwards
        let n = 6;
        let (mut col, cfg, _) = mk_col(n, 3, 3, false);
        let construction_c = col.pair_bank.c.clone();
        col.set_slots(2, &cfg);
        let mut dev_rng = Rng::new(7);
        col.install_slot_device(0, &cfg, &mut dev_rng); // slot 0 is bound
        assert_ne!(col.pair_bank.c, construction_c);
        let dev0_c = col.pair_bank.c.clone();
        col.bind_slot(1);
        assert_eq!(col.pair_bank.c, construction_c, "slot 1 shares hardware");
        col.bind_slot(0);
        assert_eq!(col.pair_bank.c, dev0_c, "slot 0 keeps its instance");
        col.dissolve_devices();
        assert_eq!(col.pair_bank.c, construction_c);
        assert!(!col.has_slot_devices());
    }

    #[test]
    fn trace_matches_golden_recurrence_ideal() {
        // Multi-step ideal simulation must track the logical recurrence
        // h_t = z·h̃ + (1−z)·h exactly (f64 rounding apart).
        let n = 12;
        let (mut col, cfg, mut rng) = mk_col(n, 0, 0, true);
        // mixed weights
        for i in 0..n {
            col.cfg_col.w_h[i] = W2::new((i % 4) as u8);
            col.cfg_col.w_z[i] = W2::new(((i + 2) % 4) as u8);
        }
        let mut meter = EnergyMeter::new();
        let mut h_log = 0.0f64; // logical h in volts-above-V_0
        let mut step_rng = Rng::new(99);
        for _ in 0..50 {
            let x: Vec<f64> = (0..n).map(|_| (step_rng.coin(0.4)) as u8 as f64).collect();
            let out = col.step(&x, &cfg, &mut rng, &mut meter);
            let z = out.z.value();
            // NB swap granularity: k/n vs z (6-bit value) differ by ≤ 1/(2n);
            let k = out.z.swap_count(n) as f64 / n as f64;
            h_log = k * (out.v_htilde - cfg.v_0) + (1.0 - k) * h_log;
            assert!(
                ((out.v_h - cfg.v_0) - h_log).abs() < 1e-9,
                "diverged: sim {} vs log {} (z={z})",
                out.v_h - cfg.v_0,
                h_log
            );
        }
    }
}
