//! The routing fabric's wire format.
//!
//! Binary output activations are communicated as *transition* events
//! ("on" and "off", paper §2): a unit whose output did not change emits
//! nothing. With the trained networks' sparse, slowly-varying activity
//! this is what makes the fabric cheap — the router benches report the
//! measured transition rate.

/// One routed event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Time step the transition belongs to.
    pub t: u32,
    /// Source layer id (network-level; mapping resolves cores).
    pub layer: u16,
    /// Source unit (column) within the layer.
    pub unit: u16,
    /// true = "on" transition (0→1), false = "off" (1→0).
    pub on: bool,
}

/// Encode the transitions between two binary frames as events.
pub fn delta_encode(
    t: u32,
    layer: u16,
    prev: &[bool],
    curr: &[bool],
    out: &mut Vec<Event>,
) {
    debug_assert_eq!(prev.len(), curr.len());
    for (unit, (&p, &c)) in prev.iter().zip(curr.iter()).enumerate() {
        if p != c {
            // lint: allow(alloc, push into the caller's event buffer; the fabric pre-reserves worst-case capacity)
            out.push(Event { t, layer, unit: unit as u16, on: c });
        }
    }
}

/// Apply events onto a frame (the receiving core's row-driver state).
pub fn delta_apply(events: &[Event], frame: &mut [bool]) {
    for e in events {
        frame[e.unit as usize] = e.on;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_transitions() {
        let prev = vec![false, true, false, true];
        let curr = vec![true, true, false, false];
        let mut evs = Vec::new();
        delta_encode(3, 1, &prev, &curr, &mut evs);
        assert_eq!(evs.len(), 2);
        let mut frame = prev.clone();
        delta_apply(&evs, &mut frame);
        assert_eq!(frame, curr);
    }

    #[test]
    fn no_change_no_events() {
        let f = vec![true, false, true];
        let mut evs = Vec::new();
        delta_encode(0, 0, &f, &f, &mut evs);
        assert!(evs.is_empty());
    }
}
