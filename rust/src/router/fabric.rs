//! Event delivery between layers: per-port frame reconstruction from
//! transition events, with traffic statistics (the router's cost model —
//! the paper's efficiency argument leans on the sparsity of 1-bit
//! activations, so the fabric measures it).

use crate::router::event::{delta_apply, delta_encode, Event};

/// The reconstructed binary input frame a destination layer sees.
#[derive(Debug, Clone)]
pub struct PortState {
    pub frame: Vec<bool>,
}

impl PortState {
    /// An all-false frame of `width`.
    pub fn new(width: usize) -> PortState {
        PortState { frame: vec![false; width] }
    }

    /// Copy the frame into `out` as 0.0/1.0.
    pub fn as_f64(&self, out: &mut [f64]) {
        for (o, &b) in out.iter_mut().zip(self.frame.iter()) {
            *o = b as u8 as f64;
        }
    }

    /// Copy the frame into `out` as 0.0/1.0.
    pub fn as_f32(&self, out: &mut [f32]) {
        for (o, &b) in out.iter_mut().zip(self.frame.iter()) {
            *o = b as u8 as f32;
        }
    }
}

/// Inter-layer fabric for one pipeline: layer l's outputs feed layer l+1.
#[derive(Debug)]
pub struct Fabric {
    /// Destination port per hidden connection (layer l → l+1 has
    /// ports[l] of width dims[l+1]).
    pub ports: Vec<PortState>,
    /// Previous output frame per source layer (for transition coding).
    prev: Vec<Vec<bool>>,
    /// Scratch event buffer.
    events: Vec<Event>,
    /// Statistics.
    pub events_routed: u64,
    /// Frames delivered since construction.
    pub frames_routed: u64,
}

impl Fabric {
    /// `widths[l]` = output width of layer l (events from the readout
    /// layer are not routed — its analog states go to the classifier).
    pub fn new(widths: &[usize]) -> Fabric {
        // a frame can emit at most `width` transition events — reserving
        // the widest port up front keeps `route` allocation-free
        let max_width = widths.iter().copied().max().unwrap_or(0);
        Fabric {
            ports: widths.iter().map(|&w| PortState::new(w)).collect(),
            prev: widths.iter().map(|&w| vec![false; w]).collect(),
            events: Vec::with_capacity(max_width),
            events_routed: 0,
            frames_routed: 0,
        }
    }

    /// Return every port to the all-false start state.
    pub fn reset(&mut self) {
        for p in self.ports.iter_mut() {
            p.frame.fill(false);
        }
        for f in self.prev.iter_mut() {
            f.fill(false);
        }
    }

    /// Route layer `l`'s binary outputs at step `t` to its consumer.
    /// Returns the number of transition events emitted.
    pub fn route(&mut self, l: usize, t: u32, outputs: &[bool]) -> usize {
        self.events.clear();
        delta_encode(t, l as u16, &self.prev[l], outputs, &mut self.events);
        self.prev[l].copy_from_slice(outputs);
        delta_apply(&self.events, &mut self.ports[l].frame);
        self.events_routed += self.events.len() as u64;
        self.frames_routed += 1;
        self.events.len()
    }

    /// Mean transition events per routed frame (sparsity metric).
    pub fn mean_events_per_frame(&self) -> f64 {
        if self.frames_routed == 0 {
            0.0
        } else {
            self.events_routed as f64 / self.frames_routed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_and_reconstructs() {
        let mut f = Fabric::new(&[4]);
        let n1 = f.route(0, 0, &[true, false, true, false]);
        assert_eq!(n1, 2);
        assert_eq!(f.ports[0].frame, vec![true, false, true, false]);
        // unchanged frame → zero events
        let n2 = f.route(0, 1, &[true, false, true, false]);
        assert_eq!(n2, 0);
        let n3 = f.route(0, 2, &[false, false, true, true]);
        assert_eq!(n3, 2);
        assert_eq!(f.ports[0].frame, vec![false, false, true, true]);
        assert!((f.mean_events_per_frame() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_everything() {
        let mut f = Fabric::new(&[3]);
        f.route(0, 0, &[true, true, true]);
        f.reset();
        assert_eq!(f.ports[0].frame, vec![false; 3]);
        // after reset, the same frame re-emits all transitions
        let n = f.route(0, 1, &[true, true, true]);
        assert_eq!(n, 3);
    }
}
