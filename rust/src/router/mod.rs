//! Event-based routing fabric (paper §3: cores "are connected through an
//! event-based routing fabric"; binary activations travel as sparse
//! on/off *transition* events between cores).
//!
//! * [`event`] — the wire format: (source core, column, polarity, t)
//! * [`fabric`] — delivery: per-destination event queues, row-state
//!   reconstruction, transition coding/decoding
//!
//! Placing network layers onto physical cores — including splitting
//! layers wider or taller than a core — is the job of the mapping
//! planner, [`crate::mapping::Plan`].

pub mod event;
pub mod fabric;

pub use event::Event;
pub use fabric::{Fabric, PortState};
