//! Event-based routing fabric (paper §3: cores "are connected through an
//! event-based routing fabric"; binary activations travel as sparse
//! on/off *transition* events between cores).
//!
//! * [`event`] — the wire format: (source core, column, polarity, t)
//! * [`fabric`] — delivery: per-destination event queues, row-state
//!   reconstruction, transition coding/decoding
//! * [`mapping`] — placing network layers onto physical cores, splitting
//!   layers wider than a core and fanning events out to all consumers

pub mod event;
pub mod fabric;
pub mod mapping;

pub use event::Event;
pub use fabric::{Fabric, PortState};
pub use mapping::{LayerPlacement, Mapping};
