//! Layer → core placement.
//!
//! A GRU block with n_in inputs and n_out units occupies
//! ⌈n_in/rows⌉ × ⌈n_out/cols⌉ physical cores (paper §3: blocks "can be
//! mapped to one or multiple cores"). Splitting the *input* dimension
//! needs care: each core slice computes a partial charge share over its
//! own rows, and the partial means are combined with weights proportional
//! to each slice's row count (in hardware: the column lines of vertically
//! stacked slices short together, which is exactly the
//! capacitance-weighted mean the math needs).

use crate::config::CoreGeometry;

/// One physical core's slice of a layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreSlice {
    pub core_id: usize,
    /// Row range [r0, r1) of the layer's input dim on this core.
    pub rows: (usize, usize),
    /// Column range [c0, c1) of the layer's units on this core.
    pub cols: (usize, usize),
}

/// Placement of one layer.
#[derive(Debug, Clone)]
pub struct LayerPlacement {
    pub layer: usize,
    pub n_in: usize,
    pub n_out: usize,
    pub slices: Vec<CoreSlice>,
}

impl LayerPlacement {
    /// Number of row slices (partial-sum groups per unit).
    pub fn row_groups(&self) -> usize {
        self.slices
            .iter()
            .map(|s| s.rows)
            .collect::<std::collections::BTreeSet<_>>()
            .len()
    }
}

/// Full-network placement.
#[derive(Debug, Clone)]
pub struct Mapping {
    pub geometry: CoreGeometry,
    pub layers: Vec<LayerPlacement>,
    pub n_cores: usize,
}

impl Mapping {
    /// Greedy dense placement: every layer gets its own core grid
    /// (no core sharing between layers — matches the paper's
    /// one-block-per-core sketch and keeps the phases independent).
    pub fn place(dims: &[usize], geometry: CoreGeometry) -> Mapping {
        let mut layers = Vec::new();
        let mut next_core = 0usize;
        for l in 0..dims.len() - 1 {
            let (n_in, n_out) = (dims[l], dims[l + 1]);
            let row_tiles = n_in.div_ceil(geometry.rows);
            let col_tiles = n_out.div_ceil(geometry.cols);
            let mut slices = Vec::new();
            for rt in 0..row_tiles {
                for ct in 0..col_tiles {
                    let r0 = rt * geometry.rows;
                    let c0 = ct * geometry.cols;
                    slices.push(CoreSlice {
                        core_id: next_core,
                        rows: (r0, (r0 + geometry.rows).min(n_in)),
                        cols: (c0, (c0 + geometry.cols).min(n_out)),
                    });
                    next_core += 1;
                }
            }
            layers.push(LayerPlacement { layer: l, n_in, n_out, slices });
        }
        Mapping { geometry, layers, n_cores: next_core }
    }

    /// Total synapse sites occupied (diagnostic / utilization metric).
    pub fn occupancy(&self) -> (usize, usize) {
        let used: usize = self
            .layers
            .iter()
            .flat_map(|l| l.slices.iter())
            .map(|s| (s.rows.1 - s.rows.0) * (s.cols.1 - s.cols.0))
            .sum();
        let total = self.n_cores * self.geometry.rows * self.geometry.cols;
        (used, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_network_uses_expected_cores() {
        // 1-64-64-64-64-10 on 64×64 cores: every layer fits one core
        // (the paper's §4.2 counts the 4 hidden blocks ≈ 4 cores; the
        // 64→10 readout occupies a fifth, partially used).
        let m = Mapping::place(&[1, 64, 64, 64, 64, 10], CoreGeometry::default());
        assert_eq!(m.n_cores, 5);
        for l in &m.layers {
            assert_eq!(l.slices.len(), 1);
        }
        let (used, total) = m.occupancy();
        assert!(used <= total);
        assert_eq!(used, 64 + 64 * 64 * 3 + 64 * 10);
    }

    #[test]
    fn wide_layer_splits() {
        let m = Mapping::place(&[128, 96], CoreGeometry { rows: 64, cols: 64 });
        let l = &m.layers[0];
        assert_eq!(l.slices.len(), 4); // 2 row tiles × 2 col tiles
        assert_eq!(l.row_groups(), 2);
        // row/col ranges tile the full matrix exactly
        let mut area = 0;
        for s in &l.slices {
            area += (s.rows.1 - s.rows.0) * (s.cols.1 - s.cols.0);
        }
        assert_eq!(area, 128 * 96);
    }

    #[test]
    fn tiny_layer_partially_fills() {
        let m = Mapping::place(&[1, 10], CoreGeometry { rows: 64, cols: 64 });
        let s = &m.layers[0].slices[0];
        assert_eq!(s.rows, (0, 1));
        assert_eq!(s.cols, (0, 10));
    }
}
