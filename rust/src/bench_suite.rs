//! The recorded performance baseline (`BENCH_baseline.json`): a
//! machine-readable benchmark of the satsim serving path, runnable via
//! `minimalist bench` (CI) or `cargo bench --bench throughput` (which
//! appends this suite after its human-readable tables).
//!
//! Four kinds of numbers:
//! * **engine** — raw `MixedSignalEngine::step` throughput (steps/s) on
//!   the paper network, for an unsplit and a row-split mapping, plus an
//!   *emulated pre-optimization baseline*: the same engine with the
//!   per-step `CircuitConfig` clones and scratch-vector allocations the
//!   hot path performed before it was made allocation-free, re-imposed
//!   on top. The ratio is the measured cost of the removed churn.
//! * **batch_sweep** — lockstep `step_batch` throughput in
//!   sequence-steps/s at B ∈ {1, 4, 16, 64}: the measurement of the
//!   batched engine (per-core weight/placement state amortized across
//!   concurrent streams).
//! * **serving** — end-to-end sequences/s and latency percentiles
//!   through the sharded coordinator, swept over worker counts (golden
//!   backend) and core geometries (satsim backend, forcing splits).
//! * **streaming_sweep** (schema 3) — sessions/s and per-frame push
//!   latency percentiles through the streaming-session path at N
//!   concurrent resident sessions on one mixed-signal worker: the
//!   lockstep amortization measured end to end, frames arriving
//!   incrementally.
//! * **http_sweep** (schema 4) — the same closed-loop streaming load
//!   measured twice over the golden backend: once directly against the
//!   in-process [`crate::coordinator::StreamClient`], once over the
//!   wire through the HTTP/1.1 front end via the load generator. The
//!   delta between the two rows is the measured cost of the wire:
//!   HTTP parse, JSON encode/decode, and the connection threads.
//! * **delta_sweep** (schema 5) — the delta-sparsity trade (ADR-005):
//!   lockstep batch throughput, measured skip ratio, and label
//!   agreement against the exact `delta = 0` engine as the threshold
//!   grows, on a glyph workload.
//! * **parallel_sweep** (schema 6) — the threaded plan traversal
//!   (ADR-007): lockstep sequence-steps/s on a row-split mapping as
//!   slot count and intra-engine thread count grow, with the speedup
//!   of each thread count against the 1-thread (serial) row at the
//!   same slot count. The traversal is bit-identical at every thread
//!   count (`tests/parallel_parity.rs`), so this axis measures pure
//!   scheduling overhead vs fan-out win.
//! * **mc_sweep** (schema 7) — the Monte-Carlo device-variation path
//!   (ADR-008): per mismatch level, the accuracy/flip-rate/energy
//!   reductions of a [`crate::montecarlo::DeviceSweep`] over a
//!   per-slot-fabricated device population, plus the lockstep
//!   throughput of stepping that population (`instances_per_s`). Only
//!   the throughput cells are gated by [`check_against`] — accuracy on
//!   a noisy device population is statistics, not performance, and
//!   must never flap the regression gate.
//!
//! The JSON schema is versioned (`schema`); CI regenerates the file per
//! commit, gates on regressions against the committed baseline
//! ([`check_against`], `minimalist bench --check`), and uploads it as
//! an artifact so the perf trajectory is recorded, not hand-curated.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{CircuitConfig, CoreGeometry, MappingConfig};
use crate::coordinator::loadgen::{self, LoadGenOpts};
use crate::coordinator::{
    BatchPolicy, GoldenBackend, HttpConfig, HttpServer, LatencyRecorder,
    MixedSignalBackend, MixedSignalEngine, Server, StreamServer,
};
use crate::dataset::glyphs;
use crate::mapping::Plan;
use crate::nn::synthetic_network;
use crate::nn::weights::NetworkWeights;
use crate::util::bench::{bench, black_box};
use crate::util::json::Json;

/// Suite knobs: `quick` shrinks budgets and request counts to smoke-test
/// scale (CI); the default sizes measure long enough to be quotable.
#[derive(Debug, Clone, Copy, Default)]
pub struct BenchOpts {
    /// Smoke-test scale: shrink every budget to CI size.
    pub quick: bool,
}

impl BenchOpts {
    fn budget(&self) -> Duration {
        if self.quick {
            Duration::from_millis(200)
        } else {
            Duration::from_secs(2)
        }
    }
}

/// Raw engine-step throughput for one mapping, optimized vs emulated
/// pre-PR3 churn.
fn engine_entry(
    label: &str,
    dims: &[usize],
    geometry: CoreGeometry,
    opts: &BenchOpts,
) -> Json {
    let d_in = dims[0];
    let x: Vec<f32> = (0..d_in).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();

    let mut engine = MixedSignalEngine::new(
        synthetic_network(dims, 42),
        CircuitConfig::default(),
        geometry,
    )
    .expect("bench network must map");
    let row_split_layers =
        engine.plan.layers.iter().filter(|l| l.is_row_split()).count();
    let n_cores = engine.n_cores();
    engine.reset();
    let mut t = 0u32;
    let optimized = bench(label, opts.budget(), || {
        engine.step(t, &x, None);
        t = t.wrapping_add(1);
    });

    // Emulated baseline: re-impose the per-step heap churn the old hot
    // path performed, on top of the optimized step. Per layer: the
    // CircuitConfig clone (a flat copy — no heap, included for
    // fidelity), the events/h-states output vectors, and the replicated
    // input frame (allocation + fill, standing in for the data copy).
    // Per core: the partials vector and the CoreStep observable buffer.
    // The ratio isolates what removing exactly this churn bought; the
    // physics itself dominates the step, so expect a modest margin on
    // big geometries and a growing one as cores shrink.
    let out_widths: Vec<usize> = dims[1..].to_vec();
    let rows = geometry.rows;
    let cols = geometry.cols;
    let circuit = CircuitConfig::default();
    engine.reset();
    let mut t = 0u32;
    let churn = bench(label, opts.budget(), || {
        for &n_out in &out_widths {
            black_box(circuit.clone());
            black_box(Vec::<bool>::with_capacity(n_out));
            black_box(Vec::<f32>::with_capacity(n_out));
            black_box(vec![0.0f64; rows]);
        }
        for _ in 0..n_cores {
            black_box(Vec::<(f64, f64)>::with_capacity(cols));
            black_box(Vec::<(f64, f64)>::with_capacity(cols));
        }
        engine.step(t, &x, None);
        t = t.wrapping_add(1);
    });

    let steps_per_s = optimized.throughput(1.0);
    let churn_steps_per_s = churn.throughput(1.0);
    Json::obj(vec![
        ("label", label.into()),
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("cores", n_cores.into()),
        ("row_split_layers", row_split_layers.into()),
        ("steps_per_s", steps_per_s.into()),
        ("step_us_p50", (optimized.median_ns / 1e3).into()),
        ("steps_per_s_alloc_churn_emulated", churn_steps_per_s.into()),
        (
            "speedup_vs_alloc_churn",
            (steps_per_s / churn_steps_per_s.max(1e-12)).into(),
        ),
    ])
}

/// Lockstep batch sweep on the paper network: sequence-steps/s of
/// `MixedSignalEngine::step_batch` as the slot count grows. B = 1 is
/// the sequential cost; the ratio column is the amortization the
/// batched engine buys.
fn batch_sweep(dims: &[usize], geometry: CoreGeometry, opts: &BenchOpts) -> Json {
    let d_in = dims[0];
    let mut engine = MixedSignalEngine::new(
        synthetic_network(dims, 42),
        CircuitConfig::default(),
        geometry,
    )
    .expect("bench network must map");
    let mut rows: Vec<Json> = Vec::new();
    let mut base = 0.0f64;
    for &b in &[1usize, 4, 16, 64] {
        engine.reset_batch(b);
        let xs: Vec<f32> =
            (0..b * d_in).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();
        let mut t = 0u32;
        let r = bench(&format!("batch-{b}"), opts.budget(), || {
            engine.step_batch(t, &xs);
            t = t.wrapping_add(1);
        });
        // one step_batch call advances b sequences by one step each
        let seq_steps_per_s = r.throughput(b as f64);
        if b == 1 {
            base = seq_steps_per_s;
        }
        rows.push(Json::obj(vec![
            ("batch", b.into()),
            ("seq_steps_per_s", seq_steps_per_s.into()),
            ("step_us_p50", (r.median_ns / 1e3).into()),
            ("speedup_vs_b1", (seq_steps_per_s / base.max(1e-12)).into()),
        ]));
    }
    Json::obj(vec![
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

/// Delta-sparsity sweep (schema 5): throughput, skip ratio, and label
/// agreement of the lockstep batch path as the delta threshold grows
/// (ADR-005), on a glyph workload whose flat image regions are what the
/// fast path exists to skip. The `delta = 0` row is the exact engine —
/// its labels are the agreement reference and its rate the speedup
/// denominator; CI asserts the nonzero-threshold rows actually skip.
fn delta_sweep(opts: &BenchOpts) -> Json {
    let dims = [1usize, 32, 10];
    let geometry = CoreGeometry { rows: 32, cols: 32 };
    let nw = synthetic_network(&dims, 7);
    let b = 8usize;
    let img = if opts.quick { 8 } else { 16 };
    let t_len = img * img;
    let samples = glyphs::make_split(b, img, 3);
    let seqs: Vec<&[f32]> = samples.iter().map(|s| s.pixels.as_slice()).collect();
    // frame-major copies for the step_batch timing loop: frames[t] holds
    // pixel t of every sequence, so the bench closure allocates nothing
    let frames: Vec<Vec<f32>> = (0..t_len)
        .map(|t| samples.iter().map(|s| s.pixels[t]).collect())
        .collect();
    let thresholds: &[f64] = if opts.quick {
        &[0.0, 0.05, 0.2]
    } else {
        &[0.0, 0.02, 0.05, 0.1, 0.2]
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut base_rate = 0.0f64;
    let mut base_labels: Vec<usize> = Vec::new();
    for &delta in thresholds {
        let mut engine = MixedSignalEngine::new(
            nw.clone(),
            CircuitConfig { delta, ..CircuitConfig::default() },
            geometry,
        )
        .expect("sweep network must map");
        // accuracy side: labels of the full workload, and the skip
        // counters it accumulated
        let labels = engine.classify_batch(&seqs);
        let stats = engine.delta_stats();
        if delta == 0.0 {
            base_labels = labels.clone();
        }
        let agreement = labels
            .iter()
            .zip(base_labels.iter())
            .filter(|(a, c)| a == c)
            .count() as f64
            / labels.len().max(1) as f64;
        // speed side: lockstep step_batch over the same frames
        engine.reset_batch(b);
        let mut t = 0u32;
        let r = bench(&format!("delta-{delta}"), opts.budget(), || {
            engine.step_batch(t, &frames[t as usize % t_len]);
            t = t.wrapping_add(1);
        });
        let seq_steps_per_s = r.throughput(b as f64);
        if delta == 0.0 {
            base_rate = seq_steps_per_s;
        }
        rows.push(Json::obj(vec![
            ("delta", delta.into()),
            ("seq_steps_per_s", seq_steps_per_s.into()),
            ("step_us_p50", (r.median_ns / 1e3).into()),
            (
                "speedup_vs_delta0",
                (seq_steps_per_s / base_rate.max(1e-12)).into(),
            ),
            ("skip_ratio", stats.skip_ratio().into()),
            ("label_agreement", agreement.into()),
        ]));
    }
    Json::obj(vec![
        ("backend", "satsim".into()),
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("batch", b.into()),
        ("img", img.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Threaded-traversal sweep (schema 6): lockstep batch throughput of
/// one engine as the intra-engine thread count grows (ADR-007), on a
/// mapping whose layers row- and column-split into enough independent
/// tiles to fan out. Every row is the same bit-exact computation —
/// `tests/parallel_parity.rs` pins that — so `speedup_vs_1thread` is a
/// pure measurement of the scoped pool's scheduling cost against its
/// fan-out win, per slot count. CI gates each (slots, threads) cell
/// against the committed baseline like any other throughput row.
fn parallel_sweep(opts: &BenchOpts) -> Json {
    let dims = [40usize, 48, 10];
    let geometry = CoreGeometry { rows: 32, cols: 32 };
    let d_in = dims[0];
    let mut engine = MixedSignalEngine::new(
        synthetic_network(&dims, 11),
        CircuitConfig::default(),
        geometry,
    )
    .expect("sweep network must map");
    let row_split_layers =
        engine.plan.layers.iter().filter(|l| l.is_row_split()).count();
    assert!(row_split_layers > 0, "sweep mapping must row-split");
    let n_cores = engine.n_cores();
    let slot_counts: &[usize] = if opts.quick { &[4] } else { &[4, 16] };
    let mut rows: Vec<Json> = Vec::new();
    for &b in slot_counts {
        let xs: Vec<f32> =
            (0..b * d_in).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();
        let mut base = 0.0f64;
        for &threads in &[1usize, 2, 4] {
            engine.set_engine_threads(threads);
            engine.reset_batch(b);
            let mut t = 0u32;
            let r = bench(
                &format!("parallel-b{b}-t{threads}"),
                opts.budget(),
                || {
                    engine.step_batch(t, &xs);
                    t = t.wrapping_add(1);
                },
            );
            let seq_steps_per_s = r.throughput(b as f64);
            if threads == 1 {
                base = seq_steps_per_s;
            }
            rows.push(Json::obj(vec![
                ("slots", b.into()),
                ("threads", threads.into()),
                ("seq_steps_per_s", seq_steps_per_s.into()),
                ("step_us_p50", (r.median_ns / 1e3).into()),
                (
                    "speedup_vs_1thread",
                    (seq_steps_per_s / base.max(1e-12)).into(),
                ),
            ]));
        }
    }
    engine.set_engine_threads(1);
    Json::obj(vec![
        ("backend", "satsim".into()),
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("cores", n_cores.into()),
        ("row_split_layers", row_split_layers.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Monte-Carlo device sweep (schema 7): the accuracy × energy
/// reductions of a [`crate::montecarlo::DeviceSweep`] next to the
/// lockstep throughput of advancing the fabricated device population
/// (ADR-008). One row per mismatch level; `instances_per_s` is full
/// inferences (sequences of `img²` steps) completed per second across
/// the whole population, the gated cell. The reduction side is
/// deterministic in the master seed; the throughput side is measured.
fn mc_sweep(opts: &BenchOpts) -> Json {
    use crate::montecarlo::DeviceSweep;
    let dims = [1usize, 16, 10];
    let geometry = CoreGeometry { rows: 16, cols: 16 };
    let nw = synthetic_network(&dims, 7);
    let instances = 16usize; // bench scale; `minimalist mc` sweeps ≥ 64
    let img = 8usize;
    let t_len = img * img;
    let sweep = DeviceSweep {
        instances,
        samples: if opts.quick { 2 } else { 8 },
        img,
        mismatch_levels: if opts.quick {
            vec![0.0, 0.05]
        } else {
            vec![0.0, 0.01, 0.05]
        },
        geometry,
        ..DeviceSweep::default()
    };
    let report = sweep.run(&nw).expect("mc sweep network must map");
    let mut rows: Vec<Json> = Vec::new();
    for l in &report.levels {
        let circuit = CircuitConfig {
            sigma_c: l.sigma_c,
            seed: sweep.master_seed,
            ..CircuitConfig::default()
        };
        let mut engine = MixedSignalEngine::new(nw.clone(), circuit, geometry)
            .expect("mc sweep network must map");
        engine.provision_devices(sweep.master_seed, instances);
        let xs: Vec<f32> =
            (0..instances).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();
        let mut t = 0u32;
        let r = bench(&format!("mc-sigma-{}", l.sigma_c), opts.budget(), || {
            engine.step_batch(t, &xs);
            t = t.wrapping_add(1);
        });
        let inst_steps_per_s = r.throughput(instances as f64);
        rows.push(Json::obj(vec![
            ("sigma_c", l.sigma_c.into()),
            ("instances_per_s", (inst_steps_per_s / t_len as f64).into()),
            ("inst_steps_per_s", inst_steps_per_s.into()),
            ("step_us_p50", (r.median_ns / 1e3).into()),
            ("acc_mean", l.acc_mean.into()),
            ("acc_min", l.acc_min.into()),
            ("acc_p5", l.acc_p5.into()),
            ("flip_rate", l.flip_rate.into()),
            ("energy_per_step_j", l.energy_per_step_j.into()),
            ("energy_per_inference_j", l.energy_per_inference_j.into()),
        ]));
    }
    Json::obj(vec![
        ("backend", "satsim".into()),
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("instances", instances.into()),
        ("img", img.into()),
        ("samples", sweep.samples.into()),
        ("master_seed", (sweep.master_seed as f64).into()),
        ("ideal_accuracy", report.ideal_accuracy.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Drive `n_req` glyph sequences through a server; returns
/// (seq/s, p50, p95, p99, errors).
fn drive(
    server: Server,
    samples: &[glyphs::Sample],
) -> (f64, Duration, Duration, Duration, u64) {
    let client = server.client();
    let t0 = Instant::now();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
        .collect();
    for rx in rxs {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let m = server.shutdown();
    let pcts = m.percentiles(&[50.0, 95.0, 99.0]);
    (
        samples.len() as f64 / wall.as_secs_f64(),
        pcts[0],
        pcts[1],
        pcts[2],
        m.errors,
    )
}

fn sweep_row(
    key: &str,
    val: Json,
    rate: f64,
    p50: Duration,
    p95: Duration,
    p99: Duration,
    errors: u64,
) -> Json {
    Json::obj(vec![
        (key, val),
        ("seq_per_s", rate.into()),
        ("p50_us", (p50.as_micros() as f64).into()),
        ("p95_us", (p95.as_micros() as f64).into()),
        ("p99_us", (p99.as_micros() as f64).into()),
        ("errors", (errors as f64).into()),
    ])
}

/// Worker-count sweep on the golden backend (the sharded-coordinator
/// measurement) — sequences/s must scale with workers.
fn worker_sweep(nw: &NetworkWeights, opts: &BenchOpts) -> Json {
    let (img, n_req) = if opts.quick { (8, 24) } else { (16, 128) };
    let samples = glyphs::make_split(n_req, img, 3);
    let mut rows: Vec<Json> = Vec::new();
    for workers in [1usize, 2, 4] {
        let server = Server::spawn_sharded(
            GoldenBackend::factory(nw.clone()),
            BatchPolicy::new(8, Duration::from_millis(1)),
            workers,
        );
        let (rate, p50, p95, p99, errors) = drive(server, &samples);
        rows.push(sweep_row("workers", workers.into(), rate, p50, p95, p99, errors));
    }
    Json::obj(vec![
        ("backend", "golden".into()),
        ("img", img.into()),
        ("n_req", n_req.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Geometry sweep on the physics backend: smaller cores force column
/// and then row splits of the same network — the serving cost of the
/// extra tiles and the partial-sum combine shows up directly.
fn geometry_sweep(opts: &BenchOpts) -> Json {
    let nw = synthetic_network(&[1, 48, 10], 7);
    let (img, n_req) = if opts.quick { (8, 4) } else { (8, 8) };
    let samples = glyphs::make_split(n_req, img, 3);
    let mut rows: Vec<Json> = Vec::new();
    for (r, c) in [(64usize, 64usize), (32, 32), (16, 16)] {
        let (plan, factory) = MixedSignalBackend::factory(
            nw.clone(),
            CircuitConfig::default(),
            CoreGeometry { rows: r, cols: c },
        )
        .expect("sweep geometry must map");
        let server = Server::spawn_sharded(
            factory,
            BatchPolicy::new(4, Duration::from_millis(1)),
            1,
        );
        let (rate, p50, p95, p99, errors) = drive(server, &samples);
        let mut row = sweep_row(
            "geometry",
            format!("{r}x{c}").into(),
            rate,
            p50,
            p95,
            p99,
            errors,
        );
        row.set("cores", plan.n_cores.into());
        row.set(
            "row_split_layers",
            plan.layers.iter().filter(|l| l.is_row_split()).count().into(),
        );
        rows.push(row);
    }
    Json::obj(vec![
        ("backend", "satsim".into()),
        ("dims", vec![1usize, 48, 10].into()),
        ("img", img.into()),
        ("n_req", n_req.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Streaming-session sweep on the physics backend: one worker holding
/// N resident sessions, frames pushed one per session per round (the
/// worker's tick advances all N through a single lockstep traversal).
/// Reports completed sessions/s, frames/s, and the per-frame push
/// latency percentiles — the serving numbers of `serve --streaming`.
fn streaming_sweep(opts: &BenchOpts) -> Json {
    let dims = [1usize, 32, 10];
    let nw = synthetic_network(&dims, 7);
    let geometry = CoreGeometry { rows: 32, cols: 32 };
    let (t_len, generations) = if opts.quick { (16, 1) } else { (64, 4) };
    let mut rows: Vec<Json> = Vec::new();
    for &n in &[1usize, 4, 16] {
        let plan = Plan::build(&dims, &MappingConfig::with_geometry(geometry))
            .expect("sweep network must map");
        let (_, factory) = MixedSignalBackend::streaming_factory_from_plan(
            nw.clone(),
            CircuitConfig::default(),
            plan,
            n,
            1,
        )
        .expect("sweep network must map");
        let server = StreamServer::spawn(factory, 1, n);
        let client = server.client();
        let t0 = Instant::now();
        let mut completed = 0usize;
        for _ in 0..generations {
            let sessions: Vec<_> = (0..n)
                .map(|_| client.open().expect("capacity sized to the sweep"))
                .collect();
            for t in 0..t_len {
                // push without waiting so all N frames queue before the
                // worker's tick — the lockstep measurement
                let acks: Vec<_> = sessions
                    .iter()
                    .map(|s| s.push_frames_nowait(vec![((t * 5) % 7) as f32 / 6.0]))
                    .collect();
                for rx in acks {
                    let _ = rx.recv();
                }
            }
            for s in sessions {
                s.close().expect("close of a live session");
                completed += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        let pcts = m.percentiles(&[50.0, 95.0, 99.0]);
        rows.push(Json::obj(vec![
            ("sessions", n.into()),
            ("sessions_per_s", (completed as f64 / wall).into()),
            ("frames_per_s", ((completed * t_len) as f64 / wall).into()),
            ("frame_p50_us", (pcts[0].as_micros() as f64).into()),
            ("frame_p95_us", (pcts[1].as_micros() as f64).into()),
            ("frame_p99_us", (pcts[2].as_micros() as f64).into()),
            ("errors", (m.errors as f64).into()),
        ]));
    }
    Json::obj(vec![
        ("backend", "satsim".into()),
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("t_len", t_len.into()),
        ("generations", generations.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Wire-overhead sweep (schema 4): the identical closed-loop streaming
/// load measured over two transports on the golden backend. The
/// `in-process` row drives [`StreamServer`] directly — `connections`
/// driver threads, each completing `sessions_per_conn` sessions in
/// series, pushing `frames` single-value frames in chunks. The `http`
/// row puts the same engine behind the HTTP/1.1 front end on an
/// ephemeral port and drives it with [`loadgen::run`] at the same
/// shape. Comparing `sessions_per_s` / `push_p50_us` across the rows
/// is the per-request price of the wire.
fn http_sweep(nw: &NetworkWeights, opts: &BenchOpts) -> Json {
    let (conns, sessions_per_conn, frames, chunk) = if opts.quick {
        (4usize, 2usize, 16usize, 4usize)
    } else {
        (16, 4, 64, 8)
    };
    // each driver holds one live session at a time, so `conns` slots on
    // one worker means opens never hit Busy in either row
    let capacity = conns;
    let mut rows: Vec<Json> = Vec::new();

    // transport: in-process — the no-wire reference measurement
    {
        let server = StreamServer::spawn(
            GoldenBackend::streaming_factory(nw.clone(), capacity),
            1,
            capacity,
        );
        let client = server.client();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let client = client.clone();
                std::thread::spawn(move || {
                    let mut push = LatencyRecorder::default();
                    for s in 0..sessions_per_conn {
                        let sess = client
                            .open()
                            .expect("capacity sized to the sweep");
                        let mut pushed = 0usize;
                        while pushed < frames {
                            let n = chunk.min(frames - pushed);
                            let vals: Vec<f32> = (0..n)
                                .map(|i| {
                                    (((c + s) * 31 + pushed + i) % 17) as f32
                                        / 16.0
                                })
                                .collect();
                            let t = Instant::now();
                            sess.push_frames(vals)
                                .expect("push on a live session");
                            push.record(t.elapsed());
                            pushed += n;
                        }
                        sess.close().expect("close of a live session");
                    }
                    push
                })
            })
            .collect();
        let mut push = LatencyRecorder::default();
        for h in handles {
            push.merge(&h.join().expect("driver thread must not panic"));
        }
        let wall = t0.elapsed().as_secs_f64();
        server.shutdown();
        let completed = conns * sessions_per_conn;
        let pcts = push.percentiles(&[50.0, 95.0, 99.0]);
        rows.push(Json::obj(vec![
            ("transport", "in-process".into()),
            ("sessions_per_s", (completed as f64 / wall.max(1e-9)).into()),
            (
                "frames_per_s",
                ((completed * frames) as f64 / wall.max(1e-9)).into(),
            ),
            ("push_p50_us", (pcts[0].as_micros() as f64).into()),
            ("push_p95_us", (pcts[1].as_micros() as f64).into()),
            ("push_p99_us", (pcts[2].as_micros() as f64).into()),
            ("errors", 0.0.into()),
        ]));
    }

    // transport: http — the same engine behind the wire front end
    {
        let server = StreamServer::spawn(
            GoldenBackend::streaming_factory(nw.clone(), capacity),
            1,
            capacity,
        );
        let http = HttpServer::bind(
            "127.0.0.1:0",
            None,
            Some(server.client()),
            HttpConfig::default(),
        )
        .expect("ephemeral-port bind");
        let lg = LoadGenOpts {
            connections: conns,
            sessions_per_conn,
            frames,
            frames_per_push: chunk,
            frame_width: 1,
            poll_logits: false,
        };
        let report = loadgen::run(&http.addr().to_string(), &lg);
        http.shutdown();
        server.shutdown();
        let pcts = report.push.percentiles(&[50.0, 95.0, 99.0]);
        rows.push(Json::obj(vec![
            ("transport", "http".into()),
            ("sessions_per_s", report.sessions_per_s().into()),
            ("frames_per_s", report.frames_per_s().into()),
            ("push_p50_us", (pcts[0].as_micros() as f64).into()),
            ("push_p95_us", (pcts[1].as_micros() as f64).into()),
            ("push_p99_us", (pcts[2].as_micros() as f64).into()),
            (
                "errors",
                ((report.protocol_errors + report.transport_errors) as f64)
                    .into(),
            ),
        ]));
    }

    Json::obj(vec![
        ("backend", "golden".into()),
        ("connections", conns.into()),
        ("sessions_per_conn", sessions_per_conn.into()),
        ("frames", frames.into()),
        ("frames_per_push", chunk.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Run the full suite and return the `BENCH_baseline.json` document.
pub fn run(opts: &BenchOpts) -> Json {
    let paper_dims = [1usize, 64, 64, 64, 64, 10];
    let engine = Json::Arr(vec![
        engine_entry(
            "paper-net/64x64/unsplit",
            &paper_dims,
            CoreGeometry { rows: 64, cols: 64 },
            opts,
        ),
        engine_entry(
            "paper-net/32x32/row-split",
            &paper_dims,
            CoreGeometry { rows: 32, cols: 32 },
            opts,
        ),
    ]);
    let sweep = batch_sweep(
        &paper_dims,
        CoreGeometry { rows: 64, cols: 64 },
        opts,
    );
    let nw = synthetic_network(&paper_dims, 42);
    let serving = Json::obj(vec![
        ("worker_sweep", worker_sweep(&nw, opts)),
        ("geometry_sweep", geometry_sweep(opts)),
        ("streaming_sweep", streaming_sweep(opts)),
        ("http_sweep", http_sweep(&nw, opts)),
    ]);
    Json::obj(vec![
        ("bench", "baseline".into()),
        // schema 7: adds mc_sweep (Monte-Carlo device population:
        // accuracy/energy reductions × lockstep instance throughput,
        // ADR-008); schema 6 added parallel_sweep (slot count ×
        // intra-engine thread count, ADR-007), schema 5 delta_sweep
        // (delta-sparsity threshold × throughput/skip-ratio/label-
        // agreement, ADR-005), schema 4 serving.http_sweep, schema 3
        // serving.streaming_sweep
        ("schema", 7usize.into()),
        ("status", "measured".into()),
        ("quick", opts.quick.into()),
        ("engine", engine),
        ("batch_sweep", sweep),
        ("delta_sweep", delta_sweep(opts)),
        ("parallel_sweep", parallel_sweep(opts)),
        ("mc_sweep", mc_sweep(opts)),
        ("serving", serving),
    ])
}

/// Hard-failure threshold of the CI regression gate: a drop of more
/// than 25 % in any compared throughput fails the job.
pub const CHECK_FAIL_FRAC: f64 = 0.25;
/// Advisory threshold: drops past 10 % (but within the hard limit) are
/// annotated, not failed — CI runner variance lives below this.
pub const CHECK_WARN_FRAC: f64 = 0.10;

/// Result of comparing a fresh suite run against a committed baseline.
#[derive(Debug, Default)]
pub struct CheckOutcome {
    /// Entries that regressed past the hard threshold — the gate fails.
    pub hard_regressions: Vec<String>,
    /// Advisory drifts (between the warn and fail thresholds).
    pub warnings: Vec<String>,
    /// Non-comparisons (placeholder baseline, missing entries).
    pub notes: Vec<String>,
}

impl CheckOutcome {
    /// Whether the gate passes: no hard regressions.
    pub fn passed(&self) -> bool {
        self.hard_regressions.is_empty()
    }
}

/// Compare one throughput metric; classify the drop.
fn check_metric(
    out: &mut CheckOutcome,
    what: &str,
    current: f64,
    baseline: f64,
    fail_frac: f64,
    warn_frac: f64,
) {
    if baseline <= 0.0 {
        out.notes.push(format!("{what}: baseline is not positive, skipped"));
        return;
    }
    let drop = 1.0 - current / baseline;
    let pct = 100.0 * drop;
    if drop > fail_frac {
        out.hard_regressions.push(format!(
            "{what}: {current:.0} vs baseline {baseline:.0} ({pct:.1}% drop)"
        ));
    } else if drop > warn_frac {
        out.warnings.push(format!(
            "{what}: {current:.0} vs baseline {baseline:.0} ({pct:.1}% drop)"
        ));
    }
}

/// Compare a fresh suite document against a committed baseline: engine
/// steps/s per matching label, lockstep batch-sweep seq-steps/s per
/// matching batch size, and parallel-sweep seq-steps/s per matching
/// (slots, threads) cell — each axis compared only when both documents
/// carry it (an old-schema baseline without a sweep skips that sweep;
/// only the shared axes compare). Every compared entry runs at
/// `delta = 0` — the schema-5 `delta_sweep` axis is recorded but never
/// gated on, so the regression gate stays armed and meaningful across
/// the schema bump (nonzero-delta rates measure a different, lossy
/// computation). The schema-6 `parallel_sweep` rows *are* gated: a
/// thread-count cell that loses its speedup is a real scheduling
/// regression, not a different computation. The schema-7 `mc_sweep`
/// rows gate **throughput cells only** (`instances_per_s` per mismatch
/// level): the accuracy/flip-rate columns of a noisy device population
/// are statistics and must never flap the gate. A placeholder baseline
/// (`status` ≠ `"measured"`, the committed state until the first CI
/// run lands numbers) produces a note and an empty comparison, so the
/// gate passes vacuously until a measured baseline is committed.
pub fn check_against(
    current: &Json,
    baseline: &Json,
    fail_frac: f64,
    warn_frac: f64,
) -> CheckOutcome {
    let mut out = CheckOutcome::default();
    if baseline.get("status").and_then(Json::as_str) != Some("measured") {
        out.notes.push(
            "baseline is a placeholder (status != \"measured\"); \
             nothing to compare — commit a measured baseline to arm the gate"
                .to_string(),
        );
        return out;
    }
    // Throughput is only comparable at the same budget scale: a
    // full-budget baseline measured on a dev box vs a --quick run on a
    // small CI runner differs by far more than any threshold. Refuse
    // the comparison instead of failing on phantom regressions — the
    // baseline should come from the same job that checks it (CI's
    // bench-gate runs --quick; commit its artifact as the baseline).
    let (cq, bq) = (
        current.get("quick").and_then(Json::as_bool),
        baseline.get("quick").and_then(Json::as_bool),
    );
    if cq != bq {
        out.notes.push(format!(
            "baseline budget scale (quick={bq:?}) differs from the current \
             run (quick={cq:?}); throughput is not comparable across budget \
             scales — regenerate the baseline with the same flags"
        ));
        return out;
    }
    let empty: [Json; 0] = [];
    let base_engine =
        baseline.get("engine").and_then(Json::as_arr).unwrap_or(&empty);
    if base_engine.is_empty() {
        out.notes
            .push("baseline has no engine entries; nothing to compare".into());
    }
    for be in base_engine {
        let Some(label) = be.get("label").and_then(Json::as_str) else {
            continue;
        };
        let cur = current
            .get("engine")
            .and_then(Json::as_arr)
            .unwrap_or(&empty)
            .iter()
            .find(|e| e.get("label").and_then(Json::as_str) == Some(label));
        let Some(cur) = cur else {
            out.notes.push(format!(
                "engine entry '{label}' missing from the current run"
            ));
            continue;
        };
        let (c, b) = (
            cur.get("steps_per_s").and_then(Json::as_f64).unwrap_or(0.0),
            be.get("steps_per_s").and_then(Json::as_f64).unwrap_or(0.0),
        );
        check_metric(
            &mut out,
            &format!("engine '{label}' steps/s"),
            c,
            b,
            fail_frac,
            warn_frac,
        );
    }
    let sweep_rows = |doc: &Json| -> Vec<(u64, f64)> {
        doc.get("batch_sweep")
            .and_then(|s| s.get("rows"))
            .and_then(Json::as_arr)
            .map(|rows| {
                rows.iter()
                    .filter_map(|r| {
                        Some((
                            r.get("batch")?.as_f64()? as u64,
                            r.get("seq_steps_per_s")?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let base_sweep = sweep_rows(baseline);
    let cur_sweep = sweep_rows(current);
    for (batch, b) in base_sweep {
        let Some(&(_, c)) = cur_sweep.iter().find(|(cb, _)| *cb == batch)
        else {
            out.notes.push(format!(
                "batch-sweep B={batch} missing from the current run"
            ));
            continue;
        };
        check_metric(
            &mut out,
            &format!("batch-sweep B={batch} seq-steps/s"),
            c,
            b,
            fail_frac,
            warn_frac,
        );
    }
    let parallel_rows = |doc: &Json| -> Vec<(u64, u64, f64)> {
        doc.get("parallel_sweep")
            .and_then(|s| s.get("rows"))
            .and_then(Json::as_arr)
            .map(|rows| {
                rows.iter()
                    .filter_map(|r| {
                        Some((
                            r.get("slots")?.as_f64()? as u64,
                            r.get("threads")?.as_f64()? as u64,
                            r.get("seq_steps_per_s")?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let cur_parallel = parallel_rows(current);
    for (slots, threads, b) in parallel_rows(baseline) {
        let Some(&(_, _, c)) = cur_parallel
            .iter()
            .find(|(s, t, _)| *s == slots && *t == threads)
        else {
            out.notes.push(format!(
                "parallel-sweep slots={slots} threads={threads} missing \
                 from the current run"
            ));
            continue;
        };
        check_metric(
            &mut out,
            &format!(
                "parallel-sweep slots={slots} threads={threads} seq-steps/s"
            ),
            c,
            b,
            fail_frac,
            warn_frac,
        );
    }
    // mc_sweep: throughput cells only — the accuracy/energy columns are
    // recorded but deliberately never compared (see the doc above)
    let mc_rows = |doc: &Json| -> Vec<(f64, f64)> {
        doc.get("mc_sweep")
            .and_then(|s| s.get("rows"))
            .and_then(Json::as_arr)
            .map(|rows| {
                rows.iter()
                    .filter_map(|r| {
                        Some((
                            r.get("sigma_c")?.as_f64()?,
                            r.get("instances_per_s")?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let cur_mc = mc_rows(current);
    for (sigma, b) in mc_rows(baseline) {
        let Some(&(_, c)) =
            cur_mc.iter().find(|(s, _)| (*s - sigma).abs() < 1e-12)
        else {
            out.notes.push(format!(
                "mc-sweep sigma_c={sigma} missing from the current run"
            ));
            continue;
        };
        check_metric(
            &mut out,
            &format!("mc-sweep sigma_c={sigma} instances/s"),
            c,
            b,
            fail_frac,
            warn_frac,
        );
    }
    out
}

/// Write a suite result where CI (or the operator) asked for it.
pub fn write(path: &str, doc: &Json) -> Result<()> {
    std::fs::write(path, format!("{doc}\n"))?;
    Ok(())
}

/// Print the engine entries of a suite document — shared by the CLI
/// and the throughput bench so the report cannot drift from the
/// schema. Tolerant of missing fields (prints placeholders) so a
/// schema mismatch never panics a reporting path.
pub fn print_engine_summary(doc: &Json) {
    if let Some(entries) = doc.get("engine").and_then(|e| e.as_arr()) {
        for e in entries {
            println!(
                "  engine {:<28} {:>12.0} steps/s  ({:.2}x vs alloc-churn baseline)",
                e.get("label").and_then(Json::as_str).unwrap_or("?"),
                e.get("steps_per_s").and_then(Json::as_f64).unwrap_or(0.0),
                e.get("speedup_vs_alloc_churn")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
            );
        }
    }
    if let Some(rows) = doc
        .get("batch_sweep")
        .and_then(|s| s.get("rows"))
        .and_then(Json::as_arr)
    {
        for r in rows {
            println!(
                "  lockstep B={:<3} {:>12.0} seq-steps/s  ({:.2}x vs B=1)",
                r.get("batch").and_then(Json::as_f64).unwrap_or(0.0),
                r.get("seq_steps_per_s").and_then(Json::as_f64).unwrap_or(0.0),
                r.get("speedup_vs_b1").and_then(Json::as_f64).unwrap_or(0.0),
            );
        }
    }
    if let Some(rows) = doc
        .get("parallel_sweep")
        .and_then(|s| s.get("rows"))
        .and_then(Json::as_arr)
    {
        for r in rows {
            println!(
                "  parallel B={:<3} T={:<2} {:>12.0} seq-steps/s  ({:.2}x vs 1 thread)",
                r.get("slots").and_then(Json::as_f64).unwrap_or(0.0),
                r.get("threads").and_then(Json::as_f64).unwrap_or(0.0),
                r.get("seq_steps_per_s").and_then(Json::as_f64).unwrap_or(0.0),
                r.get("speedup_vs_1thread").and_then(Json::as_f64).unwrap_or(0.0),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature run must produce the full schema with sane numbers —
    /// this is what keeps `minimalist bench` and the CI artifact honest.
    #[test]
    fn quick_suite_produces_schema() {
        let opts = BenchOpts { quick: true };
        let doc = run(&opts);
        assert_eq!(doc.req_str("status").unwrap(), "measured");
        assert_eq!(doc.req_f64("schema").unwrap() as u64, 7);
        let engine = doc.req("engine").unwrap().as_arr().unwrap();
        assert_eq!(engine.len(), 2);
        for e in engine {
            assert!(e.req_f64("steps_per_s").unwrap() > 0.0);
            assert!(e.req_f64("steps_per_s_alloc_churn_emulated").unwrap() > 0.0);
        }
        // the row-split entry really is row-split
        assert!(engine[1].req_f64("row_split_layers").unwrap() > 0.0);
        // the lockstep sweep covers B = 1 through 64 with real numbers
        let sweep = doc.req("batch_sweep").unwrap();
        let rows = sweep.req("rows").unwrap().as_arr().unwrap();
        let batches: Vec<u64> = rows
            .iter()
            .map(|r| r.req_f64("batch").unwrap() as u64)
            .collect();
        assert_eq!(batches, vec![1, 4, 16, 64]);
        for r in rows {
            assert!(r.req_f64("seq_steps_per_s").unwrap() > 0.0);
            assert!(r.req_f64("speedup_vs_b1").unwrap() > 0.0);
        }
        // the delta sweep anchors on an exact delta-0 row (no skips,
        // perfect agreement) and its nonzero thresholds must actually
        // skip work on the glyph workload — the CI assertion that the
        // fast path engages outside its own unit tests
        let ds = doc.req("delta_sweep").unwrap();
        let drows = ds.req("rows").unwrap().as_arr().unwrap();
        assert!(drows.len() >= 3);
        assert_eq!(drows[0].req_f64("delta").unwrap(), 0.0);
        assert_eq!(drows[0].req_f64("skip_ratio").unwrap(), 0.0);
        assert_eq!(drows[0].req_f64("label_agreement").unwrap(), 1.0);
        for r in drows {
            assert!(r.req_f64("seq_steps_per_s").unwrap() > 0.0);
            assert!(r.req_f64("speedup_vs_delta0").unwrap() > 0.0);
            let agreement = r.req_f64("label_agreement").unwrap();
            assert!((0.0..=1.0).contains(&agreement));
            if r.req_f64("delta").unwrap() > 0.0 {
                assert!(
                    r.req_f64("skip_ratio").unwrap() > 0.0,
                    "nonzero threshold must skip some components: {r}"
                );
            }
        }
        // the parallel sweep covers every thread count on a genuinely
        // row-split mapping, with a 1-thread anchor per slot count and
        // real rates everywhere; speedups stay sane (the *magnitude* is
        // runner-dependent — CI gates it against the committed
        // baseline, not against an absolute floor that would flake on
        // a one-core container)
        let ps = doc.req("parallel_sweep").unwrap();
        assert!(ps.req_f64("row_split_layers").unwrap() > 0.0);
        let prows = ps.req("rows").unwrap().as_arr().unwrap();
        assert_eq!(prows.len() % 3, 0, "three thread counts per slot count");
        for chunk in prows.chunks(3) {
            let threads: Vec<u64> = chunk
                .iter()
                .map(|r| r.req_f64("threads").unwrap() as u64)
                .collect();
            assert_eq!(threads, vec![1, 2, 4]);
            let slots = chunk[0].req_f64("slots").unwrap();
            for r in chunk {
                assert_eq!(r.req_f64("slots").unwrap(), slots);
                assert!(r.req_f64("seq_steps_per_s").unwrap() > 0.0);
                assert!(r.req_f64("speedup_vs_1thread").unwrap() > 0.0);
            }
            assert_eq!(chunk[0].req_f64("speedup_vs_1thread").unwrap(), 1.0);
        }
        // the mc sweep carries a device population with real throughput
        // and in-range statistics per mismatch level; the sigma=0 row
        // must flip no labels against the ideal device within mismatch
        // (it still carries default sampling noise, so agreement on
        // accuracy is only required to be a valid fraction)
        let mc = doc.req("mc_sweep").unwrap();
        assert!(mc.req_f64("instances").unwrap() >= 2.0);
        let mrows = mc.req("rows").unwrap().as_arr().unwrap();
        assert_eq!(mrows.len(), 2, "quick mc sweep runs two levels");
        assert_eq!(mrows[0].req_f64("sigma_c").unwrap(), 0.0);
        for r in mrows {
            assert!(r.req_f64("instances_per_s").unwrap() > 0.0);
            assert!(r.req_f64("inst_steps_per_s").unwrap() > 0.0);
            let acc = r.req_f64("acc_mean").unwrap();
            assert!((0.0..=1.0).contains(&acc), "acc_mean {acc}");
            assert!(r.req_f64("acc_min").unwrap() <= acc + 1e-12);
            let flips = r.req_f64("flip_rate").unwrap();
            assert!((0.0..=1.0).contains(&flips), "flip_rate {flips}");
            assert!(r.req_f64("energy_per_inference_j").unwrap() > 0.0);
        }
        let serving = doc.req("serving").unwrap();
        let ws = serving.req("worker_sweep").unwrap();
        assert_eq!(ws.req("rows").unwrap().as_arr().unwrap().len(), 3);
        let gs = serving.req("geometry_sweep").unwrap();
        for row in gs.req("rows").unwrap().as_arr().unwrap() {
            assert!(row.req_f64("seq_per_s").unwrap() > 0.0);
            assert_eq!(row.req_f64("errors").unwrap(), 0.0);
        }
        // the streaming sweep covers N ∈ {1, 4, 16} live sessions with
        // real rates and no serving errors
        let ss = serving.req("streaming_sweep").unwrap();
        let srows = ss.req("rows").unwrap().as_arr().unwrap();
        let counts: Vec<u64> = srows
            .iter()
            .map(|r| r.req_f64("sessions").unwrap() as u64)
            .collect();
        assert_eq!(counts, vec![1, 4, 16]);
        for r in srows {
            assert!(r.req_f64("sessions_per_s").unwrap() > 0.0);
            assert!(r.req_f64("frames_per_s").unwrap() > 0.0);
            assert_eq!(r.req_f64("errors").unwrap(), 0.0);
        }
        // the http sweep carries both transports, with real rates over
        // the wire and no protocol/transport errors
        let hs = serving.req("http_sweep").unwrap();
        let hrows = hs.req("rows").unwrap().as_arr().unwrap();
        let transports: Vec<&str> = hrows
            .iter()
            .map(|r| r.req_str("transport").unwrap())
            .collect();
        assert_eq!(transports, vec!["in-process", "http"]);
        for r in hrows {
            assert!(r.req_f64("sessions_per_s").unwrap() > 0.0);
            assert!(r.req_f64("frames_per_s").unwrap() > 0.0);
            assert_eq!(r.req_f64("errors").unwrap(), 0.0);
        }
        // and the document round-trips through the JSON module
        let text = format!("{doc}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("bench").unwrap(), "baseline");
    }

    fn doc_with(engine_steps: f64, sweep_b4: f64) -> Json {
        Json::obj(vec![
            ("status", "measured".into()),
            (
                "engine",
                Json::Arr(vec![Json::obj(vec![
                    ("label", "paper-net/64x64/unsplit".into()),
                    ("steps_per_s", engine_steps.into()),
                ])]),
            ),
            (
                "batch_sweep",
                Json::obj(vec![(
                    "rows",
                    Json::Arr(vec![Json::obj(vec![
                        ("batch", 4usize.into()),
                        ("seq_steps_per_s", sweep_b4.into()),
                    ])]),
                )]),
            ),
        ])
    }

    #[test]
    fn check_flags_hard_regressions_and_warns_on_drift() {
        let baseline = doc_with(1000.0, 4000.0);
        // within warn threshold: clean pass
        let ok = check_against(&doc_with(950.0, 3900.0), &baseline, 0.25, 0.10);
        assert!(ok.passed());
        assert!(ok.warnings.is_empty(), "{:?}", ok.warnings);
        // 15% engine drop: advisory, not fatal
        let drift =
            check_against(&doc_with(850.0, 3900.0), &baseline, 0.25, 0.10);
        assert!(drift.passed());
        assert_eq!(drift.warnings.len(), 1, "{:?}", drift.warnings);
        // 50% batch-sweep drop: the gate fails
        let bad =
            check_against(&doc_with(950.0, 2000.0), &baseline, 0.25, 0.10);
        assert!(!bad.passed());
        assert_eq!(bad.hard_regressions.len(), 1, "{:?}", bad.hard_regressions);
        assert!(bad.hard_regressions[0].contains("B=4"));
        // improvements never warn
        let better =
            check_against(&doc_with(2000.0, 8000.0), &baseline, 0.25, 0.10);
        assert!(better.passed() && better.warnings.is_empty());
    }

    #[test]
    fn check_compares_parallel_sweep_thread_cells() {
        // the schema-6 thread-axis rows are gated per (slots, threads)
        // cell: a regression in one cell fails, a missing cell notes
        let with_parallel = |rate: f64| -> Json {
            let mut doc = doc_with(1000.0, 4000.0);
            doc.set(
                "parallel_sweep",
                Json::obj(vec![(
                    "rows",
                    Json::Arr(vec![
                        Json::obj(vec![
                            ("slots", 4usize.into()),
                            ("threads", 1usize.into()),
                            ("seq_steps_per_s", 5000.0.into()),
                        ]),
                        Json::obj(vec![
                            ("slots", 4usize.into()),
                            ("threads", 4usize.into()),
                            ("seq_steps_per_s", rate.into()),
                        ]),
                    ]),
                )]),
            );
            doc
        };
        let baseline = with_parallel(12_000.0);
        assert!(check_against(&with_parallel(11_500.0), &baseline, 0.25, 0.10)
            .passed());
        // the 4-thread cell losing its speedup is a hard regression
        let bad = check_against(&with_parallel(5000.0), &baseline, 0.25, 0.10);
        assert!(!bad.passed());
        assert!(
            bad.hard_regressions[0].contains("threads=4"),
            "{:?}",
            bad.hard_regressions
        );
        // a baseline without the axis (schema ≤ 5) skips it cleanly
        let old = doc_with(1000.0, 4000.0);
        assert!(check_against(&with_parallel(1.0), &old, 0.25, 0.10).passed());
        // a cell missing from the current run notes, not panics
        let sparse = check_against(&doc_with(1000.0, 4000.0), &baseline, 0.25, 0.10);
        assert!(sparse.passed());
        assert!(
            sparse.notes.iter().any(|n| n.contains("parallel-sweep")),
            "{:?}",
            sparse.notes
        );
    }

    #[test]
    fn check_gates_mc_sweep_throughput_cells_only() {
        // the schema-7 mc rows gate instances/s per sigma level; the
        // accuracy/energy columns are never compared, so an accuracy
        // collapse alone must not trip the gate
        let with_mc = |rate: f64, acc: f64| -> Json {
            let mut doc = doc_with(1000.0, 4000.0);
            doc.set(
                "mc_sweep",
                Json::obj(vec![(
                    "rows",
                    Json::Arr(vec![Json::obj(vec![
                        ("sigma_c", 0.05.into()),
                        ("instances_per_s", rate.into()),
                        ("acc_mean", acc.into()),
                    ])]),
                )]),
            );
            doc
        };
        let baseline = with_mc(200.0, 0.9);
        // small drift: clean pass
        assert!(check_against(&with_mc(190.0, 0.9), &baseline, 0.25, 0.10)
            .passed());
        // accuracy collapse with steady throughput: still a pass
        let acc_drop = check_against(&with_mc(200.0, 0.1), &baseline, 0.25, 0.10);
        assert!(acc_drop.passed() && acc_drop.warnings.is_empty());
        // a real throughput regression fails on the mc cell
        let bad = check_against(&with_mc(100.0, 0.9), &baseline, 0.25, 0.10);
        assert!(!bad.passed());
        assert!(
            bad.hard_regressions[0].contains("mc-sweep sigma_c=0.05"),
            "{:?}",
            bad.hard_regressions
        );
        // an old-schema baseline without the axis skips it cleanly
        let old = doc_with(1000.0, 4000.0);
        assert!(check_against(&with_mc(1.0, 0.0), &old, 0.25, 0.10).passed());
        // a cell missing from the current run notes, not panics
        let sparse =
            check_against(&doc_with(1000.0, 4000.0), &baseline, 0.25, 0.10);
        assert!(sparse.passed());
        assert!(
            sparse.notes.iter().any(|n| n.contains("mc-sweep")),
            "{:?}",
            sparse.notes
        );
    }

    #[test]
    fn check_passes_vacuously_on_placeholder_baseline() {
        // a committed placeholder baseline must not arm the gate
        let placeholder = Json::obj(vec![
            ("status", "pending-first-ci-run".into()),
            ("engine", Json::Arr(vec![])),
        ]);
        let out = check_against(
            &doc_with(1.0, 1.0),
            &placeholder,
            CHECK_FAIL_FRAC,
            CHECK_WARN_FRAC,
        );
        assert!(out.passed());
        assert_eq!(out.notes.len(), 1);
        assert!(out.warnings.is_empty());
    }

    #[test]
    fn check_refuses_cross_budget_comparisons() {
        // a full-budget dev baseline vs CI's --quick run: numbers are
        // not comparable — the gate must note and pass, not phantom-fail
        let mut baseline = doc_with(100_000.0, 400_000.0);
        baseline.set("quick", false.into());
        let mut current = doc_with(1000.0, 4000.0); // "90% slower"
        current.set("quick", true.into());
        let out = check_against(&current, &baseline, 0.25, 0.10);
        assert!(out.passed());
        assert!(out.hard_regressions.is_empty() && out.warnings.is_empty());
        assert_eq!(out.notes.len(), 1);
        assert!(out.notes[0].contains("budget scale"), "{:?}", out.notes);
        // same scale on both sides still compares (and catches the drop)
        baseline.set("quick", true.into());
        assert!(!check_against(&current, &baseline, 0.25, 0.10).passed());
    }

    #[test]
    fn check_tolerates_schema_1_baselines_without_sweep() {
        // a measured BENCH_pr3.json has engine entries but no
        // batch_sweep: the engine entries compare, the sweep is skipped
        let mut baseline = doc_with(1000.0, 0.0);
        baseline.set("batch_sweep", Json::Null);
        let out =
            check_against(&doc_with(500.0, 9999.0), &baseline, 0.25, 0.10);
        assert!(!out.passed(), "engine regression must still be caught");
        assert!(out
            .hard_regressions
            .iter()
            .all(|r| r.contains("engine")));
    }
}
