//! The recorded performance baseline (`BENCH_pr3.json`): a
//! machine-readable benchmark of the satsim serving path, runnable via
//! `minimalist bench` (CI) or `cargo bench --bench throughput` (which
//! appends this suite after its human-readable tables).
//!
//! Two kinds of numbers:
//! * **engine** — raw `MixedSignalEngine::step` throughput (steps/s) on
//!   the paper network, for an unsplit and a row-split mapping, plus an
//!   *emulated pre-optimization baseline*: the same engine with the
//!   per-step `CircuitConfig` clones and scratch-vector allocations the
//!   hot path performed before it was made allocation-free, re-imposed
//!   on top. The ratio is the measured cost of the removed churn.
//! * **serving** — end-to-end sequences/s and latency percentiles
//!   through the sharded coordinator, swept over worker counts (golden
//!   backend) and core geometries (satsim backend, forcing splits).
//!
//! The JSON schema is versioned (`schema`); CI uploads the file as an
//! artifact so the perf trajectory is recorded per commit, not by hand.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{CircuitConfig, CoreGeometry};
use crate::coordinator::{
    BatchPolicy, GoldenBackend, MixedSignalBackend, MixedSignalEngine, Server,
};
use crate::dataset::glyphs;
use crate::nn::synthetic_network;
use crate::nn::weights::NetworkWeights;
use crate::util::bench::{bench, black_box};
use crate::util::json::Json;

/// Suite knobs: `quick` shrinks budgets and request counts to smoke-test
/// scale (CI); the default sizes measure long enough to be quotable.
#[derive(Debug, Clone, Copy, Default)]
pub struct BenchOpts {
    pub quick: bool,
}

impl BenchOpts {
    fn budget(&self) -> Duration {
        if self.quick {
            Duration::from_millis(200)
        } else {
            Duration::from_secs(2)
        }
    }
}

/// Raw engine-step throughput for one mapping, optimized vs emulated
/// pre-PR3 churn.
fn engine_entry(
    label: &str,
    dims: &[usize],
    geometry: CoreGeometry,
    opts: &BenchOpts,
) -> Json {
    let d_in = dims[0];
    let x: Vec<f32> = (0..d_in).map(|i| ((i * 5) % 7) as f32 / 6.0).collect();

    let mut engine = MixedSignalEngine::new(
        synthetic_network(dims, 42),
        CircuitConfig::default(),
        geometry,
    )
    .expect("bench network must map");
    let row_split_layers =
        engine.plan.layers.iter().filter(|l| l.is_row_split()).count();
    let n_cores = engine.n_cores();
    engine.reset();
    let mut t = 0u32;
    let optimized = bench(label, opts.budget(), || {
        engine.step(t, &x, None);
        t = t.wrapping_add(1);
    });

    // Emulated baseline: re-impose the per-step heap churn the old hot
    // path performed, on top of the optimized step. Per layer: the
    // CircuitConfig clone (a flat copy — no heap, included for
    // fidelity), the events/h-states output vectors, and the replicated
    // input frame (allocation + fill, standing in for the data copy).
    // Per core: the partials vector and the CoreStep observable buffer.
    // The ratio isolates what removing exactly this churn bought; the
    // physics itself dominates the step, so expect a modest margin on
    // big geometries and a growing one as cores shrink.
    let out_widths: Vec<usize> = dims[1..].to_vec();
    let rows = geometry.rows;
    let cols = geometry.cols;
    let circuit = CircuitConfig::default();
    engine.reset();
    let mut t = 0u32;
    let churn = bench(label, opts.budget(), || {
        for &n_out in &out_widths {
            black_box(circuit.clone());
            black_box(Vec::<bool>::with_capacity(n_out));
            black_box(Vec::<f32>::with_capacity(n_out));
            black_box(vec![0.0f64; rows]);
        }
        for _ in 0..n_cores {
            black_box(Vec::<(f64, f64)>::with_capacity(cols));
            black_box(Vec::<(f64, f64)>::with_capacity(cols));
        }
        engine.step(t, &x, None);
        t = t.wrapping_add(1);
    });

    let steps_per_s = optimized.throughput(1.0);
    let churn_steps_per_s = churn.throughput(1.0);
    Json::obj(vec![
        ("label", label.into()),
        ("dims", dims.to_vec().into()),
        (
            "geometry",
            format!("{}x{}", geometry.rows, geometry.cols).into(),
        ),
        ("cores", n_cores.into()),
        ("row_split_layers", row_split_layers.into()),
        ("steps_per_s", steps_per_s.into()),
        ("step_us_p50", (optimized.median_ns / 1e3).into()),
        ("steps_per_s_alloc_churn_emulated", churn_steps_per_s.into()),
        (
            "speedup_vs_alloc_churn",
            (steps_per_s / churn_steps_per_s.max(1e-12)).into(),
        ),
    ])
}

/// Drive `n_req` glyph sequences through a server; returns
/// (seq/s, p50, p95, p99, errors).
fn drive(
    server: Server,
    samples: &[glyphs::Sample],
) -> (f64, Duration, Duration, Duration, u64) {
    let client = server.client();
    let t0 = Instant::now();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| client.submit(i as u64, s.pixels.clone()))
        .collect();
    for rx in rxs {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let m = server.shutdown();
    let pcts = m.percentiles(&[50.0, 95.0, 99.0]);
    (
        samples.len() as f64 / wall.as_secs_f64(),
        pcts[0],
        pcts[1],
        pcts[2],
        m.errors,
    )
}

fn sweep_row(
    key: &str,
    val: Json,
    rate: f64,
    p50: Duration,
    p95: Duration,
    p99: Duration,
    errors: u64,
) -> Json {
    Json::obj(vec![
        (key, val),
        ("seq_per_s", rate.into()),
        ("p50_us", (p50.as_micros() as f64).into()),
        ("p95_us", (p95.as_micros() as f64).into()),
        ("p99_us", (p99.as_micros() as f64).into()),
        ("errors", (errors as f64).into()),
    ])
}

/// Worker-count sweep on the golden backend (the sharded-coordinator
/// measurement) — sequences/s must scale with workers.
fn worker_sweep(nw: &NetworkWeights, opts: &BenchOpts) -> Json {
    let (img, n_req) = if opts.quick { (8, 24) } else { (16, 128) };
    let samples = glyphs::make_split(n_req, img, 3);
    let mut rows: Vec<Json> = Vec::new();
    for workers in [1usize, 2, 4] {
        let server = Server::spawn_sharded(
            GoldenBackend::factory(nw.clone()),
            BatchPolicy::new(8, Duration::from_millis(1)),
            workers,
        );
        let (rate, p50, p95, p99, errors) = drive(server, &samples);
        rows.push(sweep_row("workers", workers.into(), rate, p50, p95, p99, errors));
    }
    Json::obj(vec![
        ("backend", "golden".into()),
        ("img", img.into()),
        ("n_req", n_req.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Geometry sweep on the physics backend: smaller cores force column
/// and then row splits of the same network — the serving cost of the
/// extra tiles and the partial-sum combine shows up directly.
fn geometry_sweep(opts: &BenchOpts) -> Json {
    let nw = synthetic_network(&[1, 48, 10], 7);
    let (img, n_req) = if opts.quick { (8, 4) } else { (8, 8) };
    let samples = glyphs::make_split(n_req, img, 3);
    let mut rows: Vec<Json> = Vec::new();
    for (r, c) in [(64usize, 64usize), (32, 32), (16, 16)] {
        let (plan, factory) = MixedSignalBackend::factory(
            nw.clone(),
            CircuitConfig::default(),
            CoreGeometry { rows: r, cols: c },
        )
        .expect("sweep geometry must map");
        let server = Server::spawn_sharded(
            factory,
            BatchPolicy::new(4, Duration::from_millis(1)),
            1,
        );
        let (rate, p50, p95, p99, errors) = drive(server, &samples);
        let mut row = sweep_row(
            "geometry",
            format!("{r}x{c}").into(),
            rate,
            p50,
            p95,
            p99,
            errors,
        );
        row.set("cores", plan.n_cores.into());
        row.set(
            "row_split_layers",
            plan.layers.iter().filter(|l| l.is_row_split()).count().into(),
        );
        rows.push(row);
    }
    Json::obj(vec![
        ("backend", "satsim".into()),
        ("dims", vec![1usize, 48, 10].into()),
        ("img", img.into()),
        ("n_req", n_req.into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Run the full suite and return the `BENCH_pr3.json` document.
pub fn run(opts: &BenchOpts) -> Json {
    let paper_dims = [1usize, 64, 64, 64, 64, 10];
    let engine = Json::Arr(vec![
        engine_entry(
            "paper-net/64x64/unsplit",
            &paper_dims,
            CoreGeometry { rows: 64, cols: 64 },
            opts,
        ),
        engine_entry(
            "paper-net/32x32/row-split",
            &paper_dims,
            CoreGeometry { rows: 32, cols: 32 },
            opts,
        ),
    ]);
    let nw = synthetic_network(&paper_dims, 42);
    let serving = Json::obj(vec![
        ("worker_sweep", worker_sweep(&nw, opts)),
        ("geometry_sweep", geometry_sweep(opts)),
    ]);
    Json::obj(vec![
        ("bench", "pr3".into()),
        ("schema", 1usize.into()),
        ("status", "measured".into()),
        ("quick", opts.quick.into()),
        ("engine", engine),
        ("serving", serving),
    ])
}

/// Write a suite result where CI (or the operator) asked for it.
pub fn write(path: &str, doc: &Json) -> Result<()> {
    std::fs::write(path, format!("{doc}\n"))?;
    Ok(())
}

/// Print the engine entries of a suite document — shared by the CLI
/// and the throughput bench so the report cannot drift from the
/// schema. Tolerant of missing fields (prints placeholders) so a
/// schema mismatch never panics a reporting path.
pub fn print_engine_summary(doc: &Json) {
    let Some(entries) = doc.get("engine").and_then(|e| e.as_arr()) else {
        return;
    };
    for e in entries {
        println!(
            "  engine {:<28} {:>12.0} steps/s  ({:.2}x vs alloc-churn baseline)",
            e.get("label").and_then(Json::as_str).unwrap_or("?"),
            e.get("steps_per_s").and_then(Json::as_f64).unwrap_or(0.0),
            e.get("speedup_vs_alloc_churn")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature run must produce the full schema with sane numbers —
    /// this is what keeps `minimalist bench` and the CI artifact honest.
    #[test]
    fn quick_suite_produces_schema() {
        let opts = BenchOpts { quick: true };
        let doc = run(&opts);
        assert_eq!(doc.req_str("status").unwrap(), "measured");
        assert_eq!(doc.req_f64("schema").unwrap() as u64, 1);
        let engine = doc.req("engine").unwrap().as_arr().unwrap();
        assert_eq!(engine.len(), 2);
        for e in engine {
            assert!(e.req_f64("steps_per_s").unwrap() > 0.0);
            assert!(e.req_f64("steps_per_s_alloc_churn_emulated").unwrap() > 0.0);
        }
        // the row-split entry really is row-split
        assert!(engine[1].req_f64("row_split_layers").unwrap() > 0.0);
        let serving = doc.req("serving").unwrap();
        let ws = serving.req("worker_sweep").unwrap();
        assert_eq!(ws.req("rows").unwrap().as_arr().unwrap().len(), 3);
        let gs = serving.req("geometry_sweep").unwrap();
        for row in gs.req("rows").unwrap().as_arr().unwrap() {
            assert!(row.req_f64("seq_per_s").unwrap() > 0.0);
            assert_eq!(row.req_f64("errors").unwrap(), 0.0);
        }
        // and the document round-trips through the JSON module
        let text = format!("{doc}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("bench").unwrap(), "pr3");
    }
}
