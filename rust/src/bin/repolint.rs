//! `repolint` — run the repo's static invariant checks (ADR-006).
//!
//! Walks the repository tree (Rust sources under `rust/` and
//! `examples/`, Markdown under `docs/` plus `README.md`), runs every
//! lint pass, prints violations as `file:line: [rule] message
//! (see doc)`, and exits non-zero if any fired. CI runs this as the
//! blocking `lint` job.
//!
//! Usage: `cargo run --release --bin repolint [-- --root <repo-root>]`
//!
//! Without `--root` the repo root is discovered from the crate's own
//! manifest directory (the parent of `rust/`), falling back to an
//! upward walk from the current directory looking for `rust/src` and
//! `docs` side by side.

use std::env;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use minimalist::lint::LintTree;

fn main() -> ExitCode {
    let args: Vec<String> = env::args().collect();
    let root = match parse_root(&args) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("repolint: {msg}");
            return ExitCode::from(2);
        }
    };
    let tree = match LintTree::load(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("repolint: failed to read tree at {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let violations = tree.run_all();
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!(
            "repolint: clean ({} files scanned under {})",
            tree.len(),
            root.display()
        );
        ExitCode::SUCCESS
    } else {
        println!("repolint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

/// Resolve the repo root from `--root`, the compile-time manifest
/// location, or an upward walk.
fn parse_root(args: &[String]) -> Result<PathBuf, String> {
    let mut it = args.iter().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                let p = it.next().ok_or("--root needs a path")?;
                let p = PathBuf::from(p);
                if looks_like_root(&p) {
                    return Ok(p);
                }
                return Err(format!(
                    "{} does not look like the repo root (no rust/src)",
                    p.display()
                ));
            }
            "--help" | "-h" => {
                return Err("usage: repolint [--root <repo-root>]".to_string());
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    // The manifest dir is `<root>/rust` at build time; it still
    // resolves when the binary runs from a target/ subdirectory.
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    if let Some(parent) = manifest.parent() {
        if looks_like_root(parent) {
            return Ok(parent.to_path_buf());
        }
    }
    let mut cur = env::current_dir().map_err(|e| e.to_string())?;
    loop {
        if looks_like_root(&cur) {
            return Ok(cur);
        }
        if !cur.pop() {
            return Err("could not locate the repo root (pass --root)".to_string());
        }
    }
}

/// A repo root has `rust/src` (and normally `docs/`) under it.
fn looks_like_root(p: &Path) -> bool {
    p.join("rust/src").is_dir()
}
