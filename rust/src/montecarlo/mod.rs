//! Monte-Carlo device-variation sweeps over the batched engine
//! (ADR-008).
//!
//! The paper's central hardware claim — that switched-capacitor GRU
//! cores survive fabrication non-idealities — needs accuracy-vs-mismatch
//! statistics over *many device instances*, not one seed. The lockstep
//! batch substrate already advances B independent sequences through one
//! plan traversal per step; this module reuses it to advance B
//! independent **devices**: [`MixedSignalEngine::provision_devices`]
//! gives every batch slot its own fabrication (capacitor mismatch, ADC
//! DAC weights, comparator offset) drawn from a per-instance seed, so
//! one `classify_batch` call evaluates the whole device population on a
//! sample.
//!
//! ## The seeding split
//!
//! Slot `i`'s device is fabricated from
//! [`instance_seed`]`(master_seed, i)` — the `(i+1)`-th successive
//! [`splitmix64`] output of the master seed. The split is documented and
//! frozen because it is the reproducibility contract of every sweep
//! artifact: a `SweepReport` is a pure function of
//! `(weights, DeviceSweep)`, bit-identical across engine thread counts
//! (tests/mc_determinism.rs) and across machines. Each slot is
//! bit-identical to a whole fresh engine built with
//! `circuit.seed = instance_seed(master, i)` — the anchor invariant the
//! satsim/engine layers pin inline — so any single device of a sweep can
//! be re-instantiated alone for debugging.
//!
//! This deliberately *opts out* of the ADR-001 slot-clone convention
//! (every slot the same device, batched ≡ sequential bit-exactly); the
//! two conventions coexist because provisioning is explicit and
//! reversible (`dissolve_devices`). See docs/adr/008.
//!
//! ## Reductions
//!
//! Per mismatch level ([`LevelReport`]): mean/min/5th-percentile
//! accuracy across instances, label-flip rate against the ideal
//! (noise-free) device, and activity-dependent energy from the cores'
//! [`crate::energy::EnergyMeter`]s. Surfaced as `minimalist mc`, the
//! schema-7 `mc_sweep` bench axis, and `examples/mc_report.rs`.

use anyhow::Result;

use crate::config::{CircuitConfig, CoreGeometry};
use crate::coordinator::MixedSignalEngine;
use crate::dataset::glyphs;
use crate::nn::weights::NetworkWeights;
use crate::util::json::Json;
use crate::util::rng::splitmix64;

/// Per-instance fabrication seed: the `(instance + 1)`-th successive
/// [`splitmix64`] output of `master` in closed form (splitmix64 strides
/// its state by the golden-ratio increment, so the stream can be jumped
/// to without iterating). Well-mixed and decorrelated across both
/// `master` and `instance`; `instance_seed(m, 0) != m`, so slot 0 of a
/// sweep is *not* the construction device (ADR-008).
pub fn instance_seed(master: u64, instance: usize) -> u64 {
    let mut state = master
        .wrapping_add((instance as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    splitmix64(&mut state)
}

/// Configuration of one Monte-Carlo device sweep — the reproducibility
/// key of its [`SweepReport`] (together with the network weights).
#[derive(Debug, Clone)]
pub struct DeviceSweep {
    /// Device instances per mismatch level (= lockstep batch width).
    pub instances: usize,
    /// Capacitor-mismatch levels to sweep: each becomes
    /// `CircuitConfig::sigma_c` of a fresh engine (the remaining noise
    /// knobs stay at their defaults).
    pub mismatch_levels: Vec<f64>,
    /// Delta-sparsity threshold (ADR-005) applied to every engine,
    /// including the ideal reference — 0 disables.
    pub delta: f64,
    /// Intra-engine traversal lanes (ADR-007). Purely a throughput
    /// knob: the report is bit-identical at every value.
    pub engine_threads: usize,
    /// Glyph samples evaluated per level.
    pub samples: usize,
    /// Glyph image side (sequence length = `img²`).
    pub img: usize,
    /// Master seed: the root of every per-instance fabrication seed and
    /// of the workload split.
    pub master_seed: u64,
    /// Core geometry the network is planned onto.
    pub geometry: CoreGeometry,
}

impl Default for DeviceSweep {
    fn default() -> DeviceSweep {
        DeviceSweep {
            instances: 64,
            mismatch_levels: vec![0.0, 0.005, 0.01, 0.02, 0.05],
            delta: 0.0,
            engine_threads: 1,
            samples: 16,
            img: 16,
            master_seed: 0x5EED,
            geometry: CoreGeometry::default(),
        }
    }
}

impl DeviceSweep {
    /// CI smoke scale: still ≥ 64 instances (the population is the
    /// point), but few samples, a small image, and three levels.
    pub fn quick() -> DeviceSweep {
        DeviceSweep {
            samples: 4,
            img: 8,
            mismatch_levels: vec![0.0, 0.02, 0.05],
            ..DeviceSweep::default()
        }
    }

    /// Run the sweep: per mismatch level, fabricate `instances` devices
    /// onto the batch slots and classify every workload sample on all
    /// of them in lockstep. Deterministic in `(weights, self)` — no
    /// wall-clock, no global state.
    pub fn run(&self, weights: &NetworkWeights) -> Result<SweepReport> {
        anyhow::ensure!(self.instances > 0, "sweep needs ≥ 1 device instance");
        anyhow::ensure!(
            !self.mismatch_levels.is_empty(),
            "sweep needs ≥ 1 mismatch level"
        );
        anyhow::ensure!(self.samples > 0, "sweep needs ≥ 1 workload sample");
        let samples =
            glyphs::make_split(self.samples, self.img, self.master_seed ^ 0x5A11AD);

        // the ideal (noise-free) device: the flip-rate reference. One
        // sequential engine — mismatch level and instance count do not
        // apply to a device with zero variation.
        let ideal_cfg =
            CircuitConfig { delta: self.delta, ..CircuitConfig::ideal() };
        let mut ideal =
            MixedSignalEngine::new(weights.clone(), ideal_cfg, self.geometry)?;
        let ideal_labels: Vec<usize> =
            samples.iter().map(|s| ideal.classify(&s.pixels)).collect();
        let ideal_correct = ideal_labels
            .iter()
            .zip(samples.iter())
            .filter(|(&l, s)| l == s.label)
            .count();

        let mut levels = Vec::with_capacity(self.mismatch_levels.len());
        for &sigma_c in &self.mismatch_levels {
            let circuit = CircuitConfig {
                sigma_c,
                delta: self.delta,
                seed: self.master_seed,
                ..CircuitConfig::default()
            };
            let mut engine =
                MixedSignalEngine::new(weights.clone(), circuit, self.geometry)?;
            engine.set_engine_threads(self.engine_threads);
            engine.provision_devices(self.master_seed, self.instances);
            let mut correct = vec![0usize; self.instances];
            let mut flips = 0u64;
            for (si, s) in samples.iter().enumerate() {
                let refs: Vec<&[f32]> = (0..self.instances)
                    .map(|_| s.pixels.as_slice())
                    .collect();
                let labels = engine.classify_batch(&refs);
                for (i, &l) in labels.iter().enumerate() {
                    correct[i] += (l == s.label) as usize;
                    flips += (l != ideal_labels[si]) as u64;
                }
            }
            let meter = engine.energy();
            let mut acc: Vec<f64> = correct
                .iter()
                .map(|&c| c as f64 / self.samples as f64)
                .collect();
            acc.sort_by(|a, b| a.partial_cmp(b).expect("accuracies are finite"));
            let inferences = (self.samples * self.instances) as f64;
            levels.push(LevelReport {
                sigma_c,
                acc_mean: acc.iter().sum::<f64>() / acc.len() as f64,
                acc_min: acc[0],
                acc_p5: percentile_low(&acc, 0.05),
                flip_rate: flips as f64 / inferences,
                energy_total_j: meter.total_j(),
                energy_per_step_j: meter.per_step_j(),
                energy_per_inference_j: meter.total_j() / inferences,
                cap_events: meter.cap_events,
                adc_conversions: meter.adc_conversions,
                per_instance_acc: acc,
            });
        }
        Ok(SweepReport {
            master_seed: self.master_seed,
            instances: self.instances,
            samples: self.samples,
            img: self.img,
            delta: self.delta,
            engine_threads: self.engine_threads,
            ideal_accuracy: ideal_correct as f64 / self.samples as f64,
            levels,
        })
    }
}

/// Lower-index percentile of an ascending-sorted slice: the value at
/// `floor(p·(n−1))`. Deterministic (no interpolation), pessimistic for
/// small populations — p5 of fewer than 20 instances is the minimum.
fn percentile_low(sorted: &[f64], p: f64) -> f64 {
    let idx = (p * (sorted.len() - 1) as f64).floor() as usize;
    sorted[idx]
}

/// One mismatch level's reduction over the device population.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelReport {
    /// Capacitor-mismatch sigma this level fabricated with.
    pub sigma_c: f64,
    /// Mean accuracy across instances.
    pub acc_mean: f64,
    /// Worst instance's accuracy.
    pub acc_min: f64,
    /// 5th-percentile accuracy (lower-index convention).
    pub acc_p5: f64,
    /// Fraction of (sample, instance) labels that differ from the ideal
    /// device's label on the same sample.
    pub flip_rate: f64,
    /// Total simulated energy across the level's whole workload (J).
    pub energy_total_j: f64,
    /// Mean energy per accounted meter step (J).
    pub energy_per_step_j: f64,
    /// Total energy divided by `samples × instances` inferences (J).
    pub energy_per_inference_j: f64,
    /// Capacitor (dis)charge events accounted.
    pub cap_events: u64,
    /// Full SAR conversions accounted.
    pub adc_conversions: u64,
    /// Per-instance accuracies, ascending (the full distribution, for
    /// tests and plotting).
    pub per_instance_acc: Vec<f64>,
}

/// The reduced result of one [`DeviceSweep::run`] — deterministic in
/// `(weights, sweep config)`, so two runs with the same master seed are
/// comparable field-for-field (tests/mc_determinism.rs asserts
/// equality, not closeness).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepReport {
    /// Master seed the sweep derived everything from.
    pub master_seed: u64,
    /// Device instances per level.
    pub instances: usize,
    /// Workload samples per level.
    pub samples: usize,
    /// Glyph image side.
    pub img: usize,
    /// Delta-sparsity threshold applied throughout.
    pub delta: f64,
    /// Traversal lanes the sweep ran with (does not affect results).
    pub engine_threads: usize,
    /// Accuracy of the ideal (noise-free) device on the same workload.
    pub ideal_accuracy: f64,
    /// One reduction per mismatch level, in sweep order.
    pub levels: Vec<LevelReport>,
}

impl SweepReport {
    /// Machine-readable form (the `minimalist mc --out` document and
    /// the bench-suite axis rows). Deliberately timestamp-free so the
    /// document is bit-stable for a fixed master seed.
    pub fn to_json(&self) -> Json {
        let levels: Vec<Json> = self
            .levels
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("sigma_c", l.sigma_c.into()),
                    ("acc_mean", l.acc_mean.into()),
                    ("acc_min", l.acc_min.into()),
                    ("acc_p5", l.acc_p5.into()),
                    ("flip_rate", l.flip_rate.into()),
                    ("energy_total_j", l.energy_total_j.into()),
                    ("energy_per_step_j", l.energy_per_step_j.into()),
                    (
                        "energy_per_inference_j",
                        l.energy_per_inference_j.into(),
                    ),
                    ("cap_events", (l.cap_events as f64).into()),
                    ("adc_conversions", (l.adc_conversions as f64).into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("report", "mc_sweep".into()),
            ("master_seed", (self.master_seed as f64).into()),
            ("instances", self.instances.into()),
            ("samples", self.samples.into()),
            ("img", self.img.into()),
            ("delta", self.delta.into()),
            ("engine_threads", self.engine_threads.into()),
            ("ideal_accuracy", self.ideal_accuracy.into()),
            ("levels", Json::Arr(levels)),
        ])
    }

    /// Human-readable table (the `minimalist mc` stdout report).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "mc sweep: {} instance(s) × {} sample(s), img {}, master seed \
             {:#x}, delta {}, ideal accuracy {:.3}\n\
             sigma_c   acc_mean  acc_min   acc_p5  flip_rate  pJ/step  pJ/inference\n",
            self.instances,
            self.samples,
            self.img,
            self.master_seed,
            self.delta,
            self.ideal_accuracy,
        );
        for l in &self.levels {
            s.push_str(&format!(
                "{:7.4}   {:7.3}  {:7.3}  {:7.3}  {:8.3}  {:8.2}  {:11.2}\n",
                l.sigma_c,
                l.acc_mean,
                l.acc_min,
                l.acc_p5,
                l.flip_rate,
                l.energy_per_step_j * 1e12,
                l.energy_per_inference_j * 1e12,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::weights::synthetic_network;

    #[test]
    fn instance_seeds_are_deterministic_and_distinct() {
        let a: Vec<u64> = (0..256).map(|i| instance_seed(7, i)).collect();
        let b: Vec<u64> = (0..256).map(|i| instance_seed(7, i)).collect();
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 256, "instance seeds must not collide");
        // the split really is the successive-splitmix64 stream
        let mut state = 7u64;
        for (i, &s) in a.iter().enumerate().take(8) {
            assert_eq!(s, splitmix64(&mut state), "instance {i}");
        }
        // distinct masters give distinct streams
        assert_ne!(instance_seed(7, 0), instance_seed(8, 0));
        // and slot 0 is not the construction device
        assert_ne!(instance_seed(7, 0), 7);
    }

    #[test]
    fn percentile_low_conventions() {
        let xs = [0.1, 0.2, 0.3, 0.4, 0.5];
        assert_eq!(percentile_low(&xs, 0.0), 0.1);
        assert_eq!(percentile_low(&xs, 0.05), 0.1);
        assert_eq!(percentile_low(&xs, 0.5), 0.3);
        assert_eq!(percentile_low(&xs, 1.0), 0.5);
        assert_eq!(percentile_low(&[0.7], 0.05), 0.7);
    }

    #[test]
    fn tiny_sweep_produces_sane_report() {
        let nw = synthetic_network(&[1, 12, 10], 11);
        let sweep = DeviceSweep {
            instances: 4,
            samples: 2,
            img: 8,
            mismatch_levels: vec![0.0, 0.05],
            geometry: CoreGeometry { rows: 16, cols: 16 },
            ..DeviceSweep::default()
        };
        let r = sweep.run(&nw).unwrap();
        assert_eq!(r.levels.len(), 2);
        assert!((0.0..=1.0).contains(&r.ideal_accuracy));
        for l in &r.levels {
            assert!(l.acc_mean.is_finite());
            assert!((0.0..=1.0).contains(&l.acc_mean));
            assert!(l.acc_min <= l.acc_p5 && l.acc_p5 <= l.acc_mean + 1e-12);
            assert!((0.0..=1.0).contains(&l.flip_rate));
            assert!(l.energy_total_j > 0.0, "meters must have accumulated");
            assert!(l.energy_per_inference_j > 0.0);
            assert_eq!(l.per_instance_acc.len(), 4);
        }
        // the JSON document round-trips and is timestamp-free stable
        let text = format!("{}", r.to_json());
        assert_eq!(format!("{}", r.to_json()), text);
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("report").unwrap(), "mc_sweep");
        assert_eq!(back.req_f64("instances").unwrap() as usize, 4);
    }

    #[test]
    fn sweep_is_thread_invariant() {
        // the whole point of reusing the lockstep substrate: the report
        // is a pure function of (weights, config) — engine_threads is
        // not part of the result
        let nw = synthetic_network(&[1, 12, 10], 11);
        let base = DeviceSweep {
            instances: 4,
            samples: 2,
            img: 8,
            mismatch_levels: vec![0.0, 0.05],
            geometry: CoreGeometry { rows: 16, cols: 16 },
            ..DeviceSweep::default()
        };
        let r1 = base.run(&nw).unwrap();
        let r2 = DeviceSweep { engine_threads: 2, ..base.clone() }
            .run(&nw)
            .unwrap();
        assert_eq!(r1.levels, r2.levels, "threads must not change the sweep");
        assert_eq!(r1.ideal_accuracy, r2.ideal_accuracy);
    }

    #[test]
    fn sweep_rejects_degenerate_configs() {
        let nw = synthetic_network(&[1, 12, 10], 11);
        let base = DeviceSweep {
            instances: 4,
            samples: 2,
            img: 8,
            geometry: CoreGeometry { rows: 16, cols: 16 },
            ..DeviceSweep::default()
        };
        assert!(DeviceSweep { instances: 0, ..base.clone() }.run(&nw).is_err());
        assert!(DeviceSweep { samples: 0, ..base.clone() }.run(&nw).is_err());
        assert!(
            DeviceSweep { mismatch_levels: vec![], ..base }.run(&nw).is_err()
        );
    }
}
