//! `alloc-discipline`: no allocation-capable calls in steady-state
//! hot-path functions.
//!
//! PR 3 made the per-step satsim path allocation-free and pinned it
//! with a counting-allocator test (`rust/tests/hot_path_alloc.rs`).
//! That test is dynamic — it only sees the paths a particular config
//! exercises. This pass mirrors the invariant statically: every
//! function named in [`HOT_FNS`] is scanned for tokens that can reach
//! the allocator, and each hit must carry a
//! `// lint: allow(alloc, reason)` annotation (same line, or on the
//! comment line directly above). The manifest itself is part of the
//! contract: in strict mode a listed file or function that no longer
//! exists is a violation, so renames cannot silently drop coverage.

use super::scan::allow_sites;
use super::{LintTree, Violation};

/// Rule identifier.
pub const RULE: &str = "alloc-discipline";
/// Governing document.
pub const DOC: &str = "docs/adr/006-repolint-static-invariants.md";

/// The hot-path manifest: file suffix → steady-state functions that
/// must not allocate. Keep in sync with `rust/tests/hot_path_alloc.rs`.
pub const HOT_FNS: &[(&str, &[&str])] = &[
    (
        "satsim/column.rs",
        &[
            "step",
            "phase_share",
            "phase_share_masked",
            "skip_share",
            "override_share",
            "phase_update",
            "bind_slot",
            "swap_slot",
            "v_h",
            "rebuild_idx_h",
            "drive",
        ],
    ),
    (
        "satsim/core.rs",
        &[
            "step",
            "step_slot",
            "step_partial",
            "step_partial_slot",
            "step_partial_slot_delta",
            "delta_counters",
            "step_finish",
            "step_finish_slot",
            "finish_partial_only",
            "finish_partial_only_slot",
            "last_events",
        ],
    ),
    (
        "satsim/caps.rs",
        &[
            "sample",
            "sample_deferred",
            "sample_deferred_lane",
            "sample_deferred_lane_masked",
            "sample_deferred_lane_contig",
            "sample_deferred_lane_contig_masked",
            "lane_sum",
            "aggregate_sample_sigma",
            "aggregate_injection_shift",
            "charge",
            "share",
            "share_with",
            "swap_device",
            "weighted_mean",
        ],
    ),
    ("satsim/adc.rs", &["decide", "convert", "ideal_code"]),
    ("router/event.rs", &["delta_encode", "delta_apply"]),
    ("router/fabric.rs", &["as_f64", "as_f32", "route"]),
    (
        "coordinator/engine.rs",
        &[
            "step",
            "step_batch",
            "step_slots",
            "step_slots_inner",
            "step_slots_threaded",
            "push_outputs",
        ],
    ),
    // the scoped pool's dispatch path runs inside the engine's
    // zero-alloc step (ADR-007); construction (`new`) is cold and may
    // allocate, the per-step entry points may not
    ("util/pool.rs", &["run", "drain"]),
];

/// Tokens that can reach the global allocator. Matched against the
/// code buffer (so string/comment occurrences never fire). `.unwrap`
/// -style exact suffixes are not needed here: every token is either a
/// full path or ends in `(`/`!` so prefixes cannot alias.
const ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "format!",
    "Vec::new",
    "Vec::with_capacity",
    "Box::new",
    "String::new",
    "String::from",
    "Rc::new",
    "Arc::new",
    "HashMap::new",
    "BTreeMap::new",
    "VecDeque::new",
    ".to_vec(",
    ".to_string(",
    ".to_owned(",
    ".clone(",
    ".collect(",
    ".push(",
    ".push_str(",
    ".insert(",
    ".extend(",
    ".extend_from_slice(",
    ".resize(",
    ".resize_with(",
    ".reserve(",
    ".append(",
];

/// Run the pass over `tree`.
pub fn check(tree: &LintTree) -> Vec<Violation> {
    let mut out = Vec::new();
    for (suffix, fns) in HOT_FNS {
        let Some(file) = tree.by_suffix(suffix) else {
            if tree.strict {
                out.push(Violation {
                    file: (*suffix).to_string(),
                    line: 1,
                    rule: RULE,
                    msg: format!("hot-path manifest file `{suffix}` not found in tree"),
                    doc: DOC,
                });
            }
            continue;
        };
        let allows = allow_sites(file);
        for name in *fns {
            let spans = file.find_fns(name);
            if spans.is_empty() {
                if tree.strict {
                    out.push(Violation {
                        file: file.rel.clone(),
                        line: 1,
                        rule: RULE,
                        msg: format!(
                            "hot-path fn `{name}` listed in the manifest was not found \
                             (renamed? update lint/alloc.rs)"
                        ),
                        doc: DOC,
                    });
                }
                continue;
            }
            for span in spans {
                for i in span.sig_line..=span.close {
                    let line = &file.code[i];
                    for tok in ALLOC_TOKENS {
                        if !line.contains(tok) {
                            continue;
                        }
                        let allowed = allows
                            .iter()
                            .any(|a| a.kind == "alloc" && a.line == i);
                        if !allowed {
                            out.push(Violation {
                                file: file.rel.clone(),
                                line: i + 1,
                                rule: RULE,
                                msg: format!(
                                    "allocation-capable call `{tok}` in hot-path fn \
                                     `{name}` without `lint: allow(alloc, ...)`"
                                ),
                                doc: DOC,
                            });
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unannotated_push_in_hot_fn_fires() {
        let tree = LintTree::from_memory(&[(
            "rust/src/router/event.rs",
            "pub fn delta_encode(out: &mut Vec<u8>) {\n    out.push(1);\n}\n",
        )]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE);
        assert_eq!(v[0].line, 2);
        assert!(v[0].msg.contains(".push("));
    }

    #[test]
    fn annotated_push_is_clean() {
        let tree = LintTree::from_memory(&[(
            "rust/src/router/event.rs",
            "pub fn delta_encode(out: &mut Vec<u8>) {\n    out.push(1); // lint: allow(alloc, caller-owned buffer)\n}\n",
        )]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn alloc_token_in_string_or_comment_does_not_fire() {
        let tree = LintTree::from_memory(&[(
            "rust/src/router/event.rs",
            "pub fn delta_encode() {\n    // we used to out.push(1) here\n    let _s = \"x.clone()\";\n}\npub fn delta_apply() {}\n",
        )]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn non_manifest_fn_may_allocate() {
        let tree = LintTree::from_memory(&[(
            "rust/src/router/event.rs",
            "pub fn cold_setup() -> Vec<u8> {\n    let mut v = Vec::new();\n    v.push(1);\n    v\n}\n",
        )]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn strict_mode_reports_missing_manifest_fn() {
        let mut tree = LintTree::from_memory(&[(
            "rust/src/router/event.rs",
            "pub fn delta_encode_v2() {}\n",
        )]);
        tree.strict = true;
        let v = check(&tree);
        assert!(v.iter().any(|v| v.msg.contains("`delta_encode`")));
    }
}
