//! Exhaustiveness contracts: enum ↔ mapping ↔ docs surfaces that must
//! stay in lock step.
//!
//! The wire spec (docs/http-api.md, ADR-004) promises a 1:1 mapping
//! from `ServeError` variants to HTTP statuses and documents every
//! metric the `/metrics` endpoint emits; the bench suite promises its
//! JSON schema version is explained in prose; the ADR index promises a
//! row per record. All four are cross-file invariants a compiler never
//! sees. The sub-rules here parse the authoritative site of each fact
//! and diff it against its mirrors:
//!
//! * `exhaustive-status` — every `ServeError` variant (declared in
//!   `coordinator/server.rs`) appears in the canonical `status_for`
//!   mapping in `coordinator/http.rs` *and* in `docs/http-api.md`.
//! * `exhaustive-metrics` — every `minimalist_*` family name emitted
//!   by `coordinator/http.rs` / `coordinator/metrics.rs` appears in
//!   `docs/http-api.md`; names assembled by interpolation (a literal
//!   ending in `_`) are rejected outright so extraction stays sound.
//! * `exhaustive-schema` — the `("schema", N)` version stamped by
//!   `bench_suite.rs` is mentioned as `schema N` in README.md or docs.
//! * `exhaustive-adr` — every `docs/adr/NNN-*.md` file has a row in
//!   `docs/adr/README.md`.
//!
//! In non-strict (fixture) trees each sub-rule runs only when its
//! input files are present.

use super::scan::SourceFile;
use super::{LintTree, Violation};

/// Governing document for the serving-surface sub-rules.
pub const DOC_HTTP: &str = "docs/http-api.md";
/// Governing document for the schema/ADR bookkeeping sub-rules.
pub const DOC_ADR: &str = "docs/adr/006-repolint-static-invariants.md";

/// Run all exhaustiveness sub-rules over `tree`.
pub fn check(tree: &LintTree) -> Vec<Violation> {
    let mut out = Vec::new();
    check_status(tree, &mut out);
    check_metrics(tree, &mut out);
    check_schema(tree, &mut out);
    check_adr_index(tree, &mut out);
    out
}

/// `exhaustive-status`: ServeError ↔ status_for ↔ docs.
fn check_status(tree: &LintTree, out: &mut Vec<Violation>) {
    let Some(server) = tree.by_suffix("coordinator/server.rs") else {
        return;
    };
    let variants = enum_variants(server, "ServeError");
    if variants.is_empty() {
        if tree.strict {
            out.push(Violation {
                file: server.rel.clone(),
                line: 1,
                rule: "exhaustive-status",
                msg: "could not locate `enum ServeError` (moved? update lint/exhaustive.rs)"
                    .to_string(),
                doc: DOC_HTTP,
            });
        }
        return;
    }
    // The canonical mapping: `fn status_for` in coordinator/http.rs.
    if let Some(http) = tree.by_suffix("coordinator/http.rs") {
        let spans = http.find_fns("status_for");
        if let Some(span) = spans.first() {
            let body: String = http.code[span.sig_line..=span.close].join("\n");
            for (line, v) in &variants {
                if !body.contains(&format!("ServeError::{v}")) {
                    out.push(Violation {
                        file: server.rel.clone(),
                        line: line + 1,
                        rule: "exhaustive-status",
                        msg: format!(
                            "ServeError::{v} has no arm in the canonical `status_for` \
                             mapping in coordinator/http.rs"
                        ),
                        doc: DOC_HTTP,
                    });
                }
            }
        } else {
            out.push(Violation {
                file: http.rel.clone(),
                line: 1,
                rule: "exhaustive-status",
                msg: "canonical `fn status_for(&ServeError)` not found in \
                      coordinator/http.rs"
                    .to_string(),
                doc: DOC_HTTP,
            });
        }
    } else if tree.strict {
        out.push(Violation {
            file: "rust/src/coordinator/http.rs".to_string(),
            line: 1,
            rule: "exhaustive-status",
            msg: "coordinator/http.rs not found in tree".to_string(),
            doc: DOC_HTTP,
        });
    }
    // The documented mapping: every variant named in the spec.
    if let Some(docs) = tree.by_suffix("docs/http-api.md") {
        for (line, v) in &variants {
            if !docs.contains(v) {
                out.push(Violation {
                    file: server.rel.clone(),
                    line: line + 1,
                    rule: "exhaustive-status",
                    msg: format!("ServeError::{v} is not documented in docs/http-api.md"),
                    doc: DOC_HTTP,
                });
            }
        }
    } else if tree.strict {
        out.push(Violation {
            file: DOC_HTTP.to_string(),
            line: 1,
            rule: "exhaustive-status",
            msg: "docs/http-api.md not found in tree".to_string(),
            doc: DOC_HTTP,
        });
    }
}

/// Parse the variant names of `enum <name>` from non-test code lines.
/// Returns `(0-based line, variant)` pairs.
fn enum_variants(f: &SourceFile, name: &str) -> Vec<(usize, String)> {
    let needle = format!("enum {name}");
    let mut out = Vec::new();
    let Some(start) = f
        .code
        .iter()
        .enumerate()
        .position(|(i, l)| !f.in_test[i] && l.contains(&needle))
    else {
        return out;
    };
    let mut depth: i32 = 0;
    let mut opened = false;
    for i in start..f.code.len() {
        let entered = depth;
        for ch in f.code[i].chars() {
            match ch {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        // A variant is a line that *starts* at depth 1 inside the
        // enum body (skipping the declaration line itself).
        if i > start && entered == 1 && depth >= 1 {
            let t = f.code[i].trim();
            let ident: String = t
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if ident.chars().next().is_some_and(|c| c.is_uppercase()) {
                out.push((i, ident));
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    out
}

/// `exhaustive-metrics`: emitted metric families ↔ docs.
fn check_metrics(tree: &LintTree, out: &mut Vec<Violation>) {
    let docs = tree.by_suffix("docs/http-api.md");
    let mut names: Vec<(String, usize, String)> = Vec::new(); // (file, line, name)
    for suffix in ["coordinator/http.rs", "coordinator/metrics.rs"] {
        let Some(f) = tree.by_suffix(suffix) else { continue };
        for (i, s) in f.strings.iter().enumerate() {
            if f.in_test[i] {
                continue;
            }
            let mut from = 0;
            while let Some(pos) = s[from..].find("minimalist_") {
                let at = from + pos;
                let name: String = s[at..]
                    .chars()
                    .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
                    .collect();
                from = at + name.len().max(1);
                if name.ends_with('_') {
                    out.push(Violation {
                        file: f.rel.clone(),
                        line: i + 1,
                        rule: "exhaustive-metrics",
                        msg: format!(
                            "metric family `{name}…` is assembled by interpolation — \
                             emit full literal names so they can be checked against docs"
                        ),
                        doc: DOC_HTTP,
                    });
                    continue;
                }
                if !names.iter().any(|(_, _, n)| n == &name) {
                    names.push((f.rel.clone(), i, name));
                }
            }
        }
    }
    let Some(docs) = docs else {
        if tree.strict && !names.is_empty() {
            out.push(Violation {
                file: DOC_HTTP.to_string(),
                line: 1,
                rule: "exhaustive-metrics",
                msg: "docs/http-api.md not found in tree".to_string(),
                doc: DOC_HTTP,
            });
        }
        return;
    };
    for (file, line, name) in names {
        if !docs.contains(&name) {
            out.push(Violation {
                file,
                line: line + 1,
                rule: "exhaustive-metrics",
                msg: format!("metric `{name}` is emitted but not documented in docs/http-api.md"),
                doc: DOC_HTTP,
            });
        }
    }
}

/// Rebuild a line as code with string-literal contents restored (but
/// comments still blanked) — for matching mixed patterns like
/// `("schema", 5`.
fn code_with_strings(f: &SourceFile, i: usize) -> String {
    f.code[i]
        .chars()
        .zip(f.strings[i].chars())
        .map(|(c, s)| if s != ' ' { s } else { c })
        .collect()
}

/// `exhaustive-schema`: bench schema version ↔ prose mention.
fn check_schema(tree: &LintTree, out: &mut Vec<Violation>) {
    let Some(bench) = tree.by_suffix("bench_suite.rs") else { return };
    for i in 0..bench.code.len() {
        if bench.in_test[i] {
            continue;
        }
        let l = code_with_strings(bench, i);
        let Some(pos) = l.find("(\"schema\",") else { continue };
        let digits: String = l[pos + "(\"schema\",".len()..]
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if digits.is_empty() {
            continue;
        }
        let mention = format!("schema {digits}");
        let mentioned = tree.files.iter().any(|f| {
            (f.rel == "README.md" || f.rel.starts_with("docs/")) && f.contains(&mention)
        });
        if !mentioned {
            out.push(Violation {
                file: bench.rel.clone(),
                line: i + 1,
                rule: "exhaustive-schema",
                msg: format!(
                    "bench schema bumped to {digits} but no `schema {digits}` mention \
                     in README.md or docs/"
                ),
                doc: DOC_ADR,
            });
        }
    }
}

/// `exhaustive-adr`: every ADR file has an index row.
fn check_adr_index(tree: &LintTree, out: &mut Vec<Violation>) {
    let adrs: Vec<&SourceFile> = tree
        .files
        .iter()
        .filter(|f| {
            f.rel.starts_with("docs/adr/")
                && f.rel.ends_with(".md")
                && f.rel
                    .rsplit('/')
                    .next()
                    .is_some_and(|n| n.chars().next().is_some_and(|c| c.is_ascii_digit()))
        })
        .collect();
    if adrs.is_empty() {
        return;
    }
    let Some(index) = tree.files.iter().find(|f| f.rel == "docs/adr/README.md") else {
        if tree.strict {
            out.push(Violation {
                file: "docs/adr/README.md".to_string(),
                line: 1,
                rule: "exhaustive-adr",
                msg: "ADR files exist but docs/adr/README.md index is missing".to_string(),
                doc: DOC_ADR,
            });
        }
        return;
    };
    for adr in adrs {
        let name = adr.rel.rsplit('/').next().unwrap_or(&adr.rel);
        if !index.contains(name) {
            out.push(Violation {
                file: adr.rel.clone(),
                line: 1,
                rule: "exhaustive-adr",
                msg: format!("ADR `{name}` has no row in the docs/adr/README.md index"),
                doc: DOC_ADR,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SERVER_FIXTURE: &str = "\
/// Why a request failed.
pub enum ServeError {
    /// Slots exhausted.
    Busy,
    /// Server went away.
    Lost,
    /// New in this fixture.
    Gone,
}
";

    #[test]
    fn missing_status_arm_fires() {
        let http = "\
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::Busy => 429,
        ServeError::Lost => 503,
        ServeError::Gone => 410,
    }
}
";
        let docs = "Errors: Busy (429), Lost (503).\n";
        let tree = LintTree::from_memory(&[
            ("rust/src/coordinator/server.rs", SERVER_FIXTURE),
            ("rust/src/coordinator/http.rs", http),
            ("docs/http-api.md", docs),
        ]);
        let v = check(&tree);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert_eq!(v[0].rule, "exhaustive-status");
        assert!(v[0].msg.contains("Gone"));
        assert!(v[0].msg.contains("documented"));
    }

    #[test]
    fn complete_surfaces_are_clean() {
        let http = "\
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::Busy => 429,
        ServeError::Lost => 503,
        ServeError::Gone => 410,
    }
}
";
        let docs = "Errors: Busy (429), Lost (503), Gone (410).\n";
        let tree = LintTree::from_memory(&[
            ("rust/src/coordinator/server.rs", SERVER_FIXTURE),
            ("rust/src/coordinator/http.rs", http),
            ("docs/http-api.md", docs),
        ]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn undocumented_metric_fires() {
        let http = "\
fn render() -> String {
    String::from(\"minimalist_bogus_total 1\\n\")
}
";
        let tree = LintTree::from_memory(&[
            ("rust/src/coordinator/http.rs", http),
            ("docs/http-api.md", "no metrics here\n"),
        ]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "exhaustive-metrics");
        assert!(v[0].msg.contains("minimalist_bogus_total"));
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn interpolated_metric_name_fires() {
        let http = "\
fn render(name: &str) -> String {
    format!(\"minimalist_delta_{name}_total 1\")
}
";
        let tree = LintTree::from_memory(&[
            ("rust/src/coordinator/http.rs", http),
            ("docs/http-api.md", "minimalist_delta_\n"),
        ]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("interpolation"));
    }

    #[test]
    fn schema_without_mention_fires() {
        let bench = "fn report() { let _ = (\"schema\", 9usize); }\n";
        let tree = LintTree::from_memory(&[
            ("rust/src/bench_suite.rs", bench),
            ("README.md", "mentions schema 8 only\n"),
        ]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "exhaustive-schema");
        assert!(v[0].msg.contains('9'));
    }

    #[test]
    fn adr_without_index_row_fires() {
        let tree = LintTree::from_memory(&[
            ("docs/adr/007-new-thing.md", "# ADR 7\n"),
            ("docs/adr/README.md", "| 006 | old | (006-old.md) |\n"),
        ]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "exhaustive-adr");
        assert!(v[0].msg.contains("007-new-thing.md"));
    }
}
