//! Lightweight Rust source scanner for `repolint`.
//!
//! This is deliberately *not* a parser. The rule passes in the sibling
//! modules only need to know, for every line of a source file, which
//! bytes are code, which are comment text, and which are string-literal
//! contents — plus where functions start and end and where the trailing
//! `#[cfg(test)]` module begins. A character-level state machine over
//! the raw text gives us exactly that with zero dependencies (see
//! ADR-006 for why we scan tokens instead of pulling in `syn`).
//!
//! For each input line the scanner produces three parallel buffers of
//! identical length:
//!
//! * `code`    — the line with comment text and string/char-literal
//!   *contents* blanked to spaces (delimiters are kept, so `"x"`
//!   becomes `" "`). All structural matching runs on this buffer.
//! * `comment` — only the comment text, everything else blanked.
//!   Annotation parsing (`// lint: ...`, `// SAFETY:`) runs here.
//! * `strings` — only string-literal contents, everything else
//!   blanked. Metric-name extraction runs here.

/// Scanner state that survives across line boundaries.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Mode {
    /// Plain code.
    Code,
    /// Inside `/* ... */`, tracking nesting depth.
    BlockComment(u32),
    /// Inside a `"..."` string literal.
    Str,
    /// Inside a raw string literal `r##"..."##` with the given number
    /// of `#` marks.
    RawStr(u32),
}

/// One scanned source or text file.
///
/// Markdown and other non-Rust files are stored with `raw` only (the
/// derived buffers simply mirror the raw text so text rules can share
/// the same lookup helpers).
pub struct SourceFile {
    /// Path relative to the repo root, with forward slashes
    /// (e.g. `rust/src/satsim/column.rs`).
    pub rel: String,
    /// Raw lines as read from disk.
    pub raw: Vec<String>,
    /// Per-line code buffer (comments and literal contents blanked).
    pub code: Vec<String>,
    /// Per-line comment-text buffer.
    pub comment: Vec<String>,
    /// Per-line string-literal-contents buffer.
    pub strings: Vec<String>,
    /// `true` for lines inside a `#[cfg(test)]` module.
    pub in_test: Vec<bool>,
}

impl SourceFile {
    /// Scan `text` as Rust source.
    pub fn rust(rel: &str, text: &str) -> SourceFile {
        let raw: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        let mut code = Vec::with_capacity(raw.len());
        let mut comment = Vec::with_capacity(raw.len());
        let mut strings = Vec::with_capacity(raw.len());
        let mut mode = Mode::Code;
        for line in &raw {
            let (c, m, s, next) = scan_line(line, mode);
            code.push(c);
            comment.push(m);
            strings.push(s);
            mode = next;
        }
        let in_test = mark_test_regions(&code);
        SourceFile { rel: rel.to_string(), raw, code, comment, strings, in_test }
    }

    /// Wrap `text` as a plain text (non-Rust) file: every derived
    /// buffer aliases the raw line so the same helpers apply.
    pub fn text(rel: &str, text: &str) -> SourceFile {
        let raw: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        let n = raw.len();
        SourceFile {
            rel: rel.to_string(),
            code: raw.clone(),
            comment: vec![String::new(); n],
            strings: raw.clone(),
            raw,
            in_test: vec![false; n],
        }
    }

    /// Whether this file is Rust source (by extension).
    pub fn is_rust(&self) -> bool {
        self.rel.ends_with(".rs")
    }

    /// Whether any buffer of any line contains `needle` (raw search —
    /// used for text files and docs cross-references).
    pub fn contains(&self, needle: &str) -> bool {
        self.raw.iter().any(|l| l.contains(needle))
    }

    /// Find all functions named `name` (exact token match) defined
    /// outside test regions, returning their spans.
    pub fn find_fns(&self, name: &str) -> Vec<FnSpan> {
        let mut out = Vec::new();
        for (i, line) in self.code.iter().enumerate() {
            if self.in_test[i] {
                continue;
            }
            if let Some(col) = find_fn_token(line, name) {
                let (open, close) = match self.body_span(i, col) {
                    Some(span) => span,
                    None => continue,
                };
                out.push(FnSpan { name: name.to_string(), sig_line: i, open, close });
            }
        }
        out
    }

    /// Given the signature line of a fn, locate the `{`..`}` span of
    /// its body. Returns 0-based line indices `(open, close)`.
    fn body_span(&self, sig_line: usize, sig_col: usize) -> Option<(usize, usize)> {
        let mut depth: i32 = 0;
        let mut open_line = None;
        for i in sig_line..self.code.len() {
            let start = if i == sig_line { sig_col } else { 0 };
            for ch in self.code[i][start.min(self.code[i].len())..].chars() {
                match ch {
                    '{' => {
                        if open_line.is_none() {
                            open_line = Some(i);
                        }
                        depth += 1;
                    }
                    '}' => {
                        depth -= 1;
                        if depth == 0 && open_line.is_some() {
                            return Some((open_line.unwrap(), i));
                        }
                    }
                    // A signature that ends in `;` before any `{` is a
                    // trait method declaration — no body.
                    ';' if open_line.is_none() => return None,
                    _ => {}
                }
            }
        }
        None
    }
}

/// The location of one function definition.
pub struct FnSpan {
    /// Function name as matched.
    pub name: String,
    /// 0-based line of the `fn` keyword.
    pub sig_line: usize,
    /// 0-based line of the opening `{`.
    pub open: usize,
    /// 0-based line of the matching `}`.
    pub close: usize,
}

/// Find `fn <name>(` (or `fn <name><`) as a whole token in a code
/// line; returns the byte offset of the `fn` keyword.
fn find_fn_token(line: &str, name: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find("fn ") {
        let at = from + pos;
        from = at + 3;
        // `fn` must be its own word: start of line or preceded by a
        // non-identifier character.
        if at > 0 {
            let prev = bytes[at - 1] as char;
            if prev.is_alphanumeric() || prev == '_' {
                continue;
            }
        }
        let rest = line[at + 3..].trim_start();
        if let Some(after) = rest.strip_prefix(name) {
            match after.chars().next() {
                Some('(') | Some('<') => return Some(at),
                _ => {}
            }
        }
    }
    None
}

/// Mark lines belonging to `#[cfg(test)]` modules. The repo convention
/// is a single trailing `mod tests`, but this tracks braces so it also
/// handles a mid-file test module. If brace tracking fails (unbalanced
/// input), everything from the attribute to EOF is conservatively
/// marked as test.
fn mark_test_regions(code: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        if !code[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Walk forward to the opening brace of the annotated item.
        let mut depth: i32 = 0;
        let mut opened = false;
        let mut end = code.len() - 1;
        for (j, line) in code.iter().enumerate().skip(i) {
            for ch in line.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                    }
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                end = j;
                break;
            }
        }
        for flag in in_test.iter_mut().take(end + 1).skip(i) {
            *flag = true;
        }
        i = end + 1;
    }
    in_test
}

/// Scan one line, splitting it into code / comment / string buffers
/// and returning the carry-over state for the next line.
fn scan_line(line: &str, start: Mode) -> (String, String, String, Mode) {
    let n = line.len();
    let mut code = String::with_capacity(n);
    let mut comment = String::with_capacity(n);
    let mut strings = String::with_capacity(n);
    let mut mode = start;
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0;
    // Push one char to `which` and a space to the other two buffers.
    macro_rules! emit {
        (code $c:expr) => {{ code.push($c); comment.push(' '); strings.push(' '); }};
        (comment $c:expr) => {{ code.push(' '); comment.push($c); strings.push(' '); }};
        (strings $c:expr) => {{ code.push(' '); comment.push(' '); strings.push($c); }};
    }
    while i < chars.len() {
        let c = chars[i];
        match mode {
            Mode::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    // Line comment: rest of the line is comment text.
                    for &cc in &chars[i..] {
                        emit!(comment cc);
                    }
                    i = chars.len();
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    emit!(comment '/');
                    emit!(comment '*');
                    i += 2;
                    mode = Mode::BlockComment(1);
                } else if c == 'r'
                    && matches!(chars.get(i + 1), Some(&'"') | Some(&'#'))
                    && is_raw_string_start(&chars, i)
                {
                    // Raw string r"..." or r#"..."# (also br"...").
                    let mut hashes = 0;
                    emit!(code 'r');
                    i += 1;
                    while chars.get(i) == Some(&'#') {
                        emit!(code '#');
                        hashes += 1;
                        i += 1;
                    }
                    // The opening quote.
                    emit!(code '"');
                    i += 1;
                    mode = Mode::RawStr(hashes);
                } else if c == '"' {
                    emit!(code '"');
                    i += 1;
                    mode = Mode::Str;
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    if chars.get(i + 1) == Some(&'\\') {
                        // '\n' style: skip to closing quote.
                        emit!(code '\'');
                        i += 2;
                        emit!(strings '\\');
                        while i < chars.len() && chars[i] != '\'' {
                            emit!(strings chars[i]);
                            i += 1;
                        }
                        if i < chars.len() {
                            emit!(code '\'');
                            i += 1;
                        }
                    } else if chars.get(i + 2) == Some(&'\'') {
                        // 'a' style char literal.
                        emit!(code '\'');
                        emit!(strings chars[i + 1]);
                        emit!(code '\'');
                        i += 3;
                    } else {
                        // Lifetime: plain code.
                        emit!(code '\'');
                        i += 1;
                    }
                } else {
                    emit!(code c);
                    i += 1;
                }
            }
            Mode::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    emit!(comment '*');
                    emit!(comment '/');
                    i += 2;
                    mode = if depth > 1 { Mode::BlockComment(depth - 1) } else { Mode::Code };
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    emit!(comment '/');
                    emit!(comment '*');
                    i += 2;
                    mode = Mode::BlockComment(depth + 1);
                } else {
                    emit!(comment c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    emit!(strings '\\');
                    if i + 1 < chars.len() {
                        emit!(strings chars[i + 1]);
                    }
                    i += 2;
                } else if c == '"' {
                    emit!(code '"');
                    i += 1;
                    mode = Mode::Code;
                } else {
                    emit!(strings c);
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && raw_string_closes(&chars, i, hashes) {
                    emit!(code '"');
                    i += 1;
                    for _ in 0..hashes {
                        emit!(code '#');
                        i += 1;
                    }
                    mode = Mode::Code;
                } else {
                    emit!(strings c);
                    i += 1;
                }
            }
        }
    }
    // A normal string or char literal never spans lines in this
    // codebase; block comments and raw strings do.
    let carry = match mode {
        Mode::Str => Mode::Code,
        m => m,
    };
    (code, comment, strings, carry)
}

/// Whether the `r` at `chars[at]` starts a raw string (as opposed to
/// being the tail of an identifier like `var"`, which is not valid
/// Rust anyway, or `r` in `for`).
fn is_raw_string_start(chars: &[char], at: usize) -> bool {
    if at > 0 {
        let prev = chars[at - 1];
        if prev.is_alphanumeric() || prev == '_' {
            return false;
        }
    }
    // `r` must be followed by zero or more `#` then `"`.
    let mut j = at + 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// Whether the `"` at `chars[at]` closes a raw string with `hashes`
/// trailing `#` marks.
fn raw_string_closes(chars: &[char], at: usize, hashes: u32) -> bool {
    for k in 0..hashes as usize {
        if chars.get(at + 1 + k) != Some(&'#') {
            return false;
        }
    }
    true
}

/// A `// lint: allow(kind, reason)` annotation parsed from a comment.
pub struct AllowSite {
    /// 0-based line the allow applies to (the annotated code line).
    pub line: usize,
    /// `alloc` or `panic`.
    pub kind: String,
}

/// Collect `// lint: allow(...)` annotations and resolve which code
/// line each one governs: an annotation sharing a line with code
/// covers that line; a standalone annotation covers the next line that
/// contains code.
pub fn allow_sites(f: &SourceFile) -> Vec<AllowSite> {
    let mut out = Vec::new();
    for i in 0..f.comment.len() {
        let Some(kind) = parse_allow(&f.comment[i]) else { continue };
        let line = if f.code[i].trim().is_empty() {
            // Standalone: attach to the next code-bearing line.
            match (i + 1..f.code.len()).find(|&j| !f.code[j].trim().is_empty()) {
                Some(j) => j,
                None => i,
            }
        } else {
            i
        };
        out.push(AllowSite { line, kind });
    }
    out
}

/// Parse `lint: allow(kind, reason)` out of one comment line. The
/// reason is mandatory — an allow without one does not count.
fn parse_allow(comment: &str) -> Option<String> {
    let at = comment.find("lint: allow(")?;
    let inner = &comment[at + "lint: allow(".len()..];
    let close = inner.find(')')?;
    let body = &inner[..close];
    let mut parts = body.splitn(2, ',');
    let kind = parts.next()?.trim();
    let reason = parts.next()?.trim();
    if kind.is_empty() || reason.is_empty() {
        return None;
    }
    Some(kind.to_string())
}

/// A `// lint: rng-draws(N, group)` annotation.
pub struct RngSite {
    /// 0-based line of the annotation comment.
    pub line: usize,
    /// Declared number of RNG draws.
    pub draws: u32,
    /// Pairing group name.
    pub group: String,
}

/// Collect all `rng-draws` annotations in a file.
pub fn rng_sites(f: &SourceFile) -> Vec<RngSite> {
    let mut out = Vec::new();
    for (i, c) in f.comment.iter().enumerate() {
        let Some(at) = c.find("lint: rng-draws(") else { continue };
        let inner = &c[at + "lint: rng-draws(".len()..];
        let Some(close) = inner.find(')') else { continue };
        let body = &inner[..close];
        let mut parts = body.splitn(2, ',');
        let draws = parts.next().and_then(|n| n.trim().parse::<u32>().ok());
        let group = parts.next().map(|g| g.trim().to_string());
        if let (Some(draws), Some(group)) = (draws, group) {
            if !group.is_empty() {
                out.push(RngSite { line: i, draws, group });
            }
        }
    }
    out
}

/// Find the `rng-draws` annotation attached to the fn whose signature
/// is at `sig_line`: the annotation must sit on the signature line or
/// in the contiguous run of comment/attribute/blank lines directly
/// above it.
pub fn rng_site_for_fn<'a>(f: &SourceFile, sites: &'a [RngSite], sig_line: usize) -> Option<&'a RngSite> {
    let mut top = sig_line;
    while top > 0 {
        let above = top - 1;
        let code = f.code[above].trim();
        let is_attr = code.starts_with("#[");
        let is_blankish = code.is_empty();
        if is_attr || is_blankish {
            top = above;
        } else {
            break;
        }
    }
    sites.iter().find(|s| s.line >= top && s.line <= sig_line)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_separated() {
        let f = SourceFile::rust(
            "t.rs",
            "let x = \"a.push(1)\"; // c.push(2)\nlet y = 1; /* block\n still */ let z = 2;\n",
        );
        assert!(!f.code[0].contains("a.push"));
        assert!(f.strings[0].contains("a.push(1)"));
        assert!(f.comment[0].contains("c.push(2)"));
        assert!(f.comment[1].contains("block"));
        assert!(f.comment[2].contains("still"));
        assert!(f.code[2].contains("let z = 2;"));
    }

    #[test]
    fn raw_strings_are_blanked_from_code() {
        let f = SourceFile::rust("t.rs", "let s = r#\"v.push(9) \"quoted\" \"#; s.len();");
        assert!(!f.code[0].contains("v.push"));
        assert!(f.strings[0].contains("v.push(9)"));
        assert!(f.code[0].contains("s.len();"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = SourceFile::rust("t.rs", "fn get<'a>(&'a self) -> &'a str { \"x\" }");
        assert!(f.code[0].contains("fn get<'a>"));
        assert!(f.strings[0].contains('x'));
    }

    #[test]
    fn char_literal_quote_does_not_open_string() {
        let f = SourceFile::rust("t.rs", "if c == '\"' { v.push(c); }");
        assert!(f.code[0].contains("v.push(c)"));
    }

    #[test]
    fn test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = SourceFile::rust("t.rs", src);
        assert_eq!(f.in_test, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn fn_finder_matches_exact_token() {
        let src = "fn step_slot() {}\nfn step(x: u8) {\n    let y = x;\n}\n";
        let f = SourceFile::rust("t.rs", src);
        let fns = f.find_fns("step");
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].sig_line, 1);
        assert_eq!(fns[0].close, 3);
    }

    #[test]
    fn allow_requires_a_reason() {
        let f = SourceFile::rust(
            "t.rs",
            "v.push(1); // lint: allow(alloc, cold path)\nw.push(2); // lint: allow(alloc)\n",
        );
        let sites = allow_sites(&f);
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].line, 0);
        assert_eq!(sites[0].kind, "alloc");
    }

    #[test]
    fn standalone_allow_attaches_to_next_code_line() {
        let f = SourceFile::rust(
            "t.rs",
            "// lint: allow(panic, startup only)\n\nthread::spawn(x).expect(\"boom\");\n",
        );
        let sites = allow_sites(&f);
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].line, 2);
    }

    #[test]
    fn rng_annotation_binds_through_attributes() {
        let src = "// lint: rng-draws(2, share)\n#[inline]\npub fn phase_share() {}\n";
        let f = SourceFile::rust("t.rs", src);
        let sites = rng_sites(&f);
        assert_eq!(sites.len(), 1);
        let hit = rng_site_for_fn(&f, &sites, 2).expect("annotation should bind");
        assert_eq!(hit.draws, 2);
        assert_eq!(hit.group, "share");
    }
}
