//! `rng-discipline`: fast-path/skip-path pairs must declare equal
//! RNG draw counts.
//!
//! ADR-005's delta fast path is only bit-compatible with the legacy
//! path because a skipped charge-share still *burns* the RNG draws the
//! full share would have consumed — one forgotten burn silently
//! desynchronizes every downstream noise sample, and no compiler can
//! see it. This pass makes the draw budget explicit: every function in
//! [`RNG_GROUPS`] must carry a `// lint: rng-draws(N, group)`
//! annotation directly above its signature, and all members of a group
//! must declare the same `N`. Removing either annotation of a pair, or
//! letting the counts drift apart, is a violation. Annotations on
//! functions the manifest does not know about are flagged too, so the
//! manifest and the source cannot diverge silently.

use super::scan::{rng_site_for_fn, rng_sites};
use super::{LintTree, Violation};

/// Rule identifier.
pub const RULE: &str = "rng-discipline";
/// Governing document.
pub const DOC: &str = "docs/adr/005-delta-sparsity.md";

/// Draw-pairing manifest: group name → the functions (file suffix,
/// fn name) whose annotated draw counts must agree. The counts
/// themselves live in the source annotations, not here — the manifest
/// only says *which* functions form a pairing.
pub const RNG_GROUPS: &[(&str, &[(&str, &str)])] = &[
    (
        "column-share",
        &[
            ("satsim/column.rs", "phase_share"),
            ("satsim/column.rs", "phase_share_masked"),
            ("satsim/column.rs", "skip_share"),
        ],
    ),
    (
        "core-share",
        &[
            ("satsim/core.rs", "step_partial_slot"),
            ("satsim/core.rs", "step_partial_slot_delta"),
        ],
    ),
    // ADR-008: provisioning a per-slot device must replay Column::new's
    // construction draw order exactly (CapBank(2n) → CapBank(n) →
    // SarAdc), or the fabricated instance is not the device a fresh
    // engine with that seed would build.
    (
        "column-device",
        &[
            ("satsim/column.rs", "new"),
            ("satsim/column.rs", "install_slot_device"),
        ],
    ),
];

/// Run the pass over `tree`.
pub fn check(tree: &LintTree) -> Vec<Violation> {
    let mut out = Vec::new();
    // (file rel, annotation line) pairs claimed by a manifest fn —
    // anything left over afterwards is a stray annotation.
    let mut claimed: Vec<(String, usize)> = Vec::new();

    for (group, members) in RNG_GROUPS {
        // Reference draw count: the first annotated member present.
        let mut reference: Option<(u32, String)> = None;
        for (suffix, name) in *members {
            let Some(file) = tree.by_suffix(suffix) else {
                if tree.strict {
                    out.push(Violation {
                        file: (*suffix).to_string(),
                        line: 1,
                        rule: RULE,
                        msg: format!("rng manifest file `{suffix}` not found in tree"),
                        doc: DOC,
                    });
                }
                continue;
            };
            let sites = rng_sites(file);
            let spans = file.find_fns(name);
            let Some(span) = spans.first() else {
                if tree.strict {
                    out.push(Violation {
                        file: file.rel.clone(),
                        line: 1,
                        rule: RULE,
                        msg: format!(
                            "rng manifest fn `{name}` not found \
                             (renamed? update lint/rng.rs)"
                        ),
                        doc: DOC,
                    });
                }
                continue;
            };
            let Some(site) = rng_site_for_fn(file, &sites, span.sig_line) else {
                out.push(Violation {
                    file: file.rel.clone(),
                    line: span.sig_line + 1,
                    rule: RULE,
                    msg: format!(
                        "fn `{name}` is in rng group `{group}` but has no \
                         `lint: rng-draws(N, {group})` annotation"
                    ),
                    doc: DOC,
                });
                continue;
            };
            claimed.push((file.rel.clone(), site.line));
            if site.group != *group {
                out.push(Violation {
                    file: file.rel.clone(),
                    line: site.line + 1,
                    rule: RULE,
                    msg: format!(
                        "fn `{name}` declares rng group `{}` but the manifest \
                         places it in `{group}`",
                        site.group
                    ),
                    doc: DOC,
                });
                continue;
            }
            match &reference {
                None => reference = Some((site.draws, (*name).to_string())),
                Some((ref_draws, ref_name)) => {
                    if site.draws != *ref_draws {
                        out.push(Violation {
                            file: file.rel.clone(),
                            line: site.line + 1,
                            rule: RULE,
                            msg: format!(
                                "fn `{name}` declares {} rng draw(s) but group \
                                 `{group}` peer `{ref_name}` declares {ref_draws} — \
                                 skip paths must burn the draws their full-path \
                                 twins consume",
                                site.draws
                            ),
                            doc: DOC,
                        });
                    }
                }
            }
        }
    }

    // Stray annotations: rng-draws on fns the manifest does not pair.
    for file in tree.files.iter().filter(|f| f.is_rust()) {
        for site in rng_sites(file) {
            if !claimed.iter().any(|(rel, l)| rel == &file.rel && *l == site.line) {
                out.push(Violation {
                    file: file.rel.clone(),
                    line: site.line + 1,
                    rule: RULE,
                    msg: format!(
                        "stray `rng-draws` annotation (group `{}`) on a fn the \
                         manifest does not pair — add it to lint/rng.rs or drop it",
                        site.group
                    ),
                    doc: DOC,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAIRED_OK: &str = "\
// lint: rng-draws(2, column-share)
pub fn phase_share(&mut self) {}
// lint: rng-draws(2, column-share)
pub fn phase_share_masked(&mut self) {}
// lint: rng-draws(2, column-share)
pub fn skip_share(&mut self) {}
";

    #[test]
    fn matching_counts_are_clean() {
        let tree = LintTree::from_memory(&[("rust/src/satsim/column.rs", PAIRED_OK)]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn count_mismatch_fires_once() {
        let src = "\
// lint: rng-draws(2, column-share)
pub fn phase_share(&mut self) {}
// lint: rng-draws(1, column-share)
pub fn skip_share(&mut self) {}
";
        let tree = LintTree::from_memory(&[("rust/src/satsim/column.rs", src)]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE);
        assert_eq!(v[0].line, 3);
        assert!(v[0].msg.contains("skip_share"));
    }

    #[test]
    fn removed_annotation_fires() {
        let src = "\
// lint: rng-draws(2, column-share)
pub fn phase_share(&mut self) {}
pub fn skip_share(&mut self) {}
";
        let tree = LintTree::from_memory(&[("rust/src/satsim/column.rs", src)]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("no `lint: rng-draws"));
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn stray_annotation_fires() {
        let src = "\
// lint: rng-draws(3, mystery)
pub fn helper() {}
";
        let tree = LintTree::from_memory(&[("rust/src/satsim/noise.rs", src)]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("stray"));
    }

    #[test]
    fn wrong_group_name_fires() {
        let src = "\
// lint: rng-draws(2, column-share)
pub fn phase_share(&mut self) {}
// lint: rng-draws(2, other-group)
pub fn skip_share(&mut self) {}
";
        let tree = LintTree::from_memory(&[("rust/src/satsim/column.rs", src)]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("other-group"));
    }
}
