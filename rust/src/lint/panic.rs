//! `panic-hygiene` and `unsafe-safety`: no unannotated panic paths in
//! the serving stack, no undocumented `unsafe`.
//!
//! PR 3 made worker panics a per-batch, contained event
//! (`ServeError::BackendPanicked`) — but that isolation only covers
//! the classify call. A stray `unwrap` in the listener, the response
//! router, or the load generator takes down the whole thread and with
//! it every connection it owns. `panic-hygiene` therefore forbids
//! panic-capable tokens (`.unwrap()`, `.expect(`, `panic!`,
//! `unreachable!`, `todo!`, `unimplemented!`, direct indexing is left
//! to review) in the non-test regions of `coordinator/http.rs`,
//! `coordinator/server.rs`, and `coordinator/loadgen.rs` unless the
//! site carries `// lint: allow(panic, reason)`.
//!
//! `unsafe-safety` applies tree-wide: any line whose *code* contains
//! the `unsafe` keyword must have a `SAFETY:` comment on the same line
//! or within the three lines above — the comment-discipline clippy
//! enforces via `undocumented_unsafe_blocks`, extended to `unsafe fn`
//! and `unsafe impl`, and enforced even where clippy does not run.

use super::scan::allow_sites;
use super::{LintTree, Violation};

/// Rule identifier for the panic pass.
pub const RULE_PANIC: &str = "panic-hygiene";
/// Rule identifier for the unsafe pass.
pub const RULE_UNSAFE: &str = "unsafe-safety";
/// Governing document.
pub const DOC: &str = "docs/adr/006-repolint-static-invariants.md";

/// Files whose non-test code must not panic without an annotation.
pub const SERVING_FILES: &[&str] = &[
    "coordinator/http.rs",
    "coordinator/server.rs",
    "coordinator/loadgen.rs",
];

/// Panic-capable tokens. `.unwrap()` is matched with its closing
/// paren so `unwrap_or`/`unwrap_or_else`/`unwrap_or_default` do not
/// alias; the macros match with `!` so identifiers do not.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Run both passes over `tree`.
pub fn check(tree: &LintTree) -> Vec<Violation> {
    let mut out = Vec::new();
    check_panics(tree, &mut out);
    check_unsafe(tree, &mut out);
    out
}

/// `panic-hygiene` over the serving files.
fn check_panics(tree: &LintTree, out: &mut Vec<Violation>) {
    for suffix in SERVING_FILES {
        let Some(file) = tree.by_suffix(suffix) else {
            if tree.strict {
                out.push(Violation {
                    file: (*suffix).to_string(),
                    line: 1,
                    rule: RULE_PANIC,
                    msg: format!("serving-path manifest file `{suffix}` not found in tree"),
                    doc: DOC,
                });
            }
            continue;
        };
        let allows = allow_sites(file);
        for (i, line) in file.code.iter().enumerate() {
            if file.in_test[i] {
                continue;
            }
            for tok in PANIC_TOKENS {
                if !line.contains(tok) {
                    continue;
                }
                let allowed = allows.iter().any(|a| a.kind == "panic" && a.line == i);
                if !allowed {
                    out.push(Violation {
                        file: file.rel.clone(),
                        line: i + 1,
                        rule: RULE_PANIC,
                        msg: format!(
                            "panic-capable `{tok}` in a request-serving path without \
                             `lint: allow(panic, ...)`"
                        ),
                        doc: DOC,
                    });
                }
            }
        }
    }
}

/// `unsafe-safety` over every Rust file (tests and benches included —
/// an undocumented `unsafe` is no better for living in a test).
fn check_unsafe(tree: &LintTree, out: &mut Vec<Violation>) {
    for file in tree.files.iter().filter(|f| f.is_rust()) {
        for (i, line) in file.code.iter().enumerate() {
            if !has_word(line, "unsafe") {
                continue;
            }
            let documented = (i.saturating_sub(3)..=i)
                .any(|j| file.comment[j].contains("SAFETY:"));
            if !documented {
                out.push(Violation {
                    file: file.rel.clone(),
                    line: i + 1,
                    rule: RULE_UNSAFE,
                    msg: "`unsafe` without a `// SAFETY:` comment on the same line or \
                          the 3 lines above"
                        .to_string(),
                    doc: DOC,
                });
            }
        }
    }
}

/// Whole-word search (identifier boundaries on both sides).
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find(word) {
        let at = from + pos;
        from = at + word.len();
        let ok_before = at == 0 || {
            let c = bytes[at - 1] as char;
            !c.is_alphanumeric() && c != '_'
        };
        let after = at + word.len();
        let ok_after = after >= bytes.len() || {
            let c = bytes[after] as char;
            !c.is_alphanumeric() && c != '_'
        };
        if ok_before && ok_after {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unannotated_expect_in_serving_path_fires() {
        let tree = LintTree::from_memory(&[(
            "rust/src/coordinator/http.rs",
            "fn accept() {\n    thread::spawn(f).join().expect(\"accept thread\");\n}\n",
        )]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE_PANIC);
        assert_eq!(v[0].line, 2);
        assert!(v[0].msg.contains(".expect("));
    }

    #[test]
    fn annotated_expect_is_clean() {
        let tree = LintTree::from_memory(&[(
            "rust/src/coordinator/http.rs",
            "fn accept() {\n    // lint: allow(panic, startup-only spawn)\n    thread::spawn(f).join().expect(\"accept thread\");\n}\n",
        )]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn unwrap_or_else_does_not_alias_unwrap() {
        let tree = LintTree::from_memory(&[(
            "rust/src/coordinator/http.rs",
            "fn lock() {\n    m.lock().unwrap_or_else(|p| p.into_inner());\n}\n",
        )]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn panic_tokens_outside_serving_files_are_fine() {
        let tree = LintTree::from_memory(&[(
            "rust/src/satsim/caps.rs",
            "fn idx(&self, i: usize) -> f64 {\n    self.c.get(i).copied().unwrap()\n}\n",
        )]);
        // `.unwrap()` needs the closing paren; `.unwrap()` here:
        let tree2 = LintTree::from_memory(&[(
            "rust/src/satsim/caps.rs",
            "fn idx(&self) {\n    self.c.first().unwrap();\n}\n",
        )]);
        assert!(check(&tree).is_empty());
        assert!(check(&tree2).is_empty());
    }

    #[test]
    fn undocumented_unsafe_fires_anywhere() {
        let tree = LintTree::from_memory(&[(
            "rust/tests/alloc_guard.rs",
            "unsafe impl GlobalAlloc for Counting {\n}\n",
        )]);
        let v = check(&tree);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE_UNSAFE);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn safety_comment_above_unsafe_is_clean() {
        let tree = LintTree::from_memory(&[(
            "rust/tests/alloc_guard.rs",
            "// SAFETY: delegates verbatim to the system allocator.\nunsafe impl GlobalAlloc for Counting {\n}\n",
        )]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn test_region_panics_are_ignored() {
        let tree = LintTree::from_memory(&[(
            "rust/src/coordinator/http.rs",
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); panic!(\"boom\"); }\n}\n",
        )]);
        assert!(check(&tree).is_empty());
    }
}
