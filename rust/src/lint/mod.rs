//! `repolint`: a dependency-free static invariant checker for this
//! repository (ADR-006).
//!
//! The simulator's correctness story rests on contracts no compiler
//! checks: steady-state hot paths must not allocate (PR 3's
//! counting-allocator test, mirrored statically here), skip paths must
//! burn exactly the RNG draws their full-path twins consume (ADR-005),
//! the wire protocol's error/metric/doc surfaces must stay in lock
//! step (ADR-004), and request-serving threads must not panic. Each
//! contract is a self-contained pass over a [`scan::SourceFile`] tree:
//!
//! | rule id             | guards                                           |
//! |---------------------|--------------------------------------------------|
//! | `alloc-discipline`  | no allocation-capable calls in hot-path fns      |
//! | `rng-discipline`    | fast/skip path pairs declare equal RNG draws     |
//! | `exhaustive-status` | `ServeError` ↔ `status_for` ↔ docs/http-api.md   |
//! | `exhaustive-metrics`| every `minimalist_*` metric is documented        |
//! | `exhaustive-schema` | bench schema bumps are mentioned in docs         |
//! | `exhaustive-adr`    | every ADR file has an index row                  |
//! | `panic-hygiene`     | no unannotated panic paths in the serving stack  |
//! | `unsafe-safety`     | every `unsafe` carries a `// SAFETY:` comment    |
//!
//! Escape hatches are explicit source annotations with mandatory
//! reasons: `// lint: allow(alloc, <reason>)`,
//! `// lint: allow(panic, <reason>)`, and
//! `// lint: rng-draws(<n>, <group>)`. The `repolint` binary walks the
//! real tree; tests drive the same passes over in-memory fixtures via
//! [`LintTree::from_memory`].

pub mod alloc;
pub mod exhaustive;
pub mod panic;
pub mod rng;
pub mod scan;

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

use scan::SourceFile;

/// One rule violation, printed as
/// `file:line: [rule] message (see doc)`.
pub struct Violation {
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule identifier (e.g. `alloc-discipline`).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub msg: String,
    /// The governing document (ADR or spec) to read.
    pub doc: &'static str,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} (see {})",
            self.file, self.line, self.rule, self.msg, self.doc
        )
    }
}

/// A scanned file tree the rule passes run over.
pub struct LintTree {
    /// All scanned files (Rust sources and Markdown docs).
    pub files: Vec<SourceFile>,
    /// Strict mode: manifest files and functions listed by a rule
    /// must exist in the tree (true for the real repo, false for
    /// in-memory fixtures that carry only the files under test).
    pub strict: bool,
}

/// Directories (relative to the repo root) scanned for Rust sources.
const RUST_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Directories / files scanned for Markdown.
const DOC_DIRS: &[&str] = &["docs"];

impl LintTree {
    /// Load the real tree rooted at `root` (the repo root, i.e. the
    /// directory containing `rust/` and `docs/`).
    pub fn load(root: &Path) -> io::Result<LintTree> {
        let mut files = Vec::new();
        for dir in RUST_DIRS {
            let d = root.join(dir);
            if d.is_dir() {
                walk(&d, root, "rs", &mut files)?;
            }
        }
        for dir in DOC_DIRS {
            let d = root.join(dir);
            if d.is_dir() {
                walk(&d, root, "md", &mut files)?;
            }
        }
        let readme = root.join("README.md");
        if readme.is_file() {
            let text = fs::read_to_string(&readme)?;
            files.push(SourceFile::text("README.md", &text));
        }
        files.sort_by(|a, b| a.rel.cmp(&b.rel));
        Ok(LintTree { files, strict: true })
    }

    /// Build a tree from `(relative path, contents)` pairs — the
    /// fixture entry point used by the linter's own tests. Fixture
    /// trees are non-strict: rule manifests skip files and functions
    /// the fixture does not carry.
    pub fn from_memory(entries: &[(&str, &str)]) -> LintTree {
        let files = entries
            .iter()
            .map(|(rel, text)| {
                if rel.ends_with(".rs") {
                    SourceFile::rust(rel, text)
                } else {
                    SourceFile::text(rel, text)
                }
            })
            .collect();
        LintTree { files, strict: false }
    }

    /// Look a file up by repo-relative path suffix (e.g.
    /// `satsim/column.rs` matches `rust/src/satsim/column.rs`).
    pub fn by_suffix(&self, suffix: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| {
            f.rel == suffix
                || (f.rel.ends_with(suffix)
                    && f.rel[..f.rel.len() - suffix.len()].ends_with('/'))
        })
    }

    /// Number of files in the tree.
    pub fn len(&self) -> usize {
        self.files.len()
    }

    /// Whether the tree is empty.
    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Run every rule pass and return the violations sorted by file
    /// and line.
    pub fn run_all(&self) -> Vec<Violation> {
        let mut v = Vec::new();
        v.extend(alloc::check(self));
        v.extend(rng::check(self));
        v.extend(exhaustive::check(self));
        v.extend(panic::check(self));
        v.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
        v
    }
}

/// Recursively collect files with `ext` under `dir` into `out`,
/// storing paths relative to `root`.
fn walk(dir: &Path, root: &Path, ext: &str, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            // `target/` never lives under the scanned dirs, but guard
            // anyway so a stray build dir cannot poison the scan.
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            walk(&path, root, ext, out)?;
        } else if path.extension().is_some_and(|e| e == ext) {
            let text = fs::read_to_string(&path)?;
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(if ext == "rs" {
                SourceFile::rust(&rel, &text)
            } else {
                SourceFile::text(&rel, &text)
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_suffix_requires_a_path_boundary() {
        let t = LintTree::from_memory(&[
            ("rust/src/satsim/column.rs", "fn a() {}\n"),
            ("rust/src/satsim/mycolumn.rs", "fn b() {}\n"),
        ]);
        let hit = t.by_suffix("satsim/column.rs").expect("should resolve");
        assert_eq!(hit.rel, "rust/src/satsim/column.rs");
        assert!(t.by_suffix("tsim/column.rs").is_none());
    }

    #[test]
    fn violation_display_has_file_line_rule_and_doc() {
        let v = Violation {
            file: "rust/src/x.rs".into(),
            line: 7,
            rule: "alloc-discipline",
            msg: "allocation-capable call `.push(`".into(),
            doc: "docs/adr/006-repolint-static-invariants.md",
        };
        let s = v.to_string();
        assert!(s.starts_with("rust/src/x.rs:7: [alloc-discipline]"));
        assert!(s.contains("docs/adr/006"));
    }
}
