//! Layer → core placement plans (see the module docs of [`crate::mapping`]).

use anyhow::{bail, Result};

use crate::config::{CoreGeometry, MappingConfig};
use crate::nn::weights::NetworkWeights;

/// One physical core's slice of a layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TilePlan {
    /// Global core index (position in the engine's core array).
    pub core: usize,
    /// Logical row range [r0, r1) of the layer's input dim on this core.
    pub rows: (usize, usize),
    /// Column range [c0, c1) of the layer's units on this core.
    pub cols: (usize, usize),
    /// The owner tile (row tile 0) holds the gate digitization, the
    /// capacitor-swap state bank, and the output comparator for its
    /// columns; non-owner row tiles only contribute partial charge
    /// shares.
    pub owner: bool,
}

impl TilePlan {
    /// Rows spanned on this core.
    pub fn n_rows(&self) -> usize {
        self.rows.1 - self.rows.0
    }

    /// Columns spanned on this core.
    pub fn n_cols(&self) -> usize {
        self.cols.1 - self.cols.0
    }
}

/// Placement of one layer onto row_tiles × col_tiles cores.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPlan {
    /// Index of the layer this plan places.
    pub layer: usize,
    /// Logical input width.
    pub n_in: usize,
    /// Logical output width.
    pub n_out: usize,
    /// Row replication factor of a narrow layer (1 for row-split layers;
    /// replication and row splitting are mutually exclusive).
    pub replication: usize,
    /// Core tiles along the input (row) axis.
    pub row_tiles: usize,
    /// Core tiles along the output (column) axis.
    pub col_tiles: usize,
    /// Column-tile major, row tile inner: `tiles[ct * row_tiles + rt]`.
    /// For `row_tiles == 1` this is the plain left-to-right column
    /// chunking of the layer.
    pub tiles: Vec<TilePlan>,
}

impl LayerPlan {
    /// Whether the layer's inputs span multiple row tiles.
    pub fn is_row_split(&self) -> bool {
        self.row_tiles > 1
    }

    /// Tile at (row tile `rt`, column tile `ct`).
    pub fn tile(&self, rt: usize, ct: usize) -> &TilePlan {
        &self.tiles[ct * self.row_tiles + rt]
    }

    /// The owner tile of column group `ct` (row tile 0).
    pub fn owner_tile(&self, ct: usize) -> &TilePlan {
        self.tile(0, ct)
    }

    /// Physical rows occupied on the owner tile (replication included) —
    /// the segment budget available to realize the ADC slope.
    pub fn owner_rows_phys(&self) -> usize {
        self.replication * self.owner_tile(0).n_rows()
    }
}

/// Full-network placement: every layer on its own core grid (no core
/// sharing between layers — matches the paper's one-block-per-core
/// sketch and keeps the clock phases of different layers independent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    /// Per-core physical geometry.
    pub geometry: CoreGeometry,
    /// One placement per network layer.
    pub layers: Vec<LayerPlan>,
    /// Total cores consumed by the plan.
    pub n_cores: usize,
}

impl Plan {
    /// Plan the placement of `dims` (layer widths including input and
    /// readout, e.g. `[1, 64, 64, 64, 64, 10]`) onto cores of
    /// `cfg.geometry`, honoring the planner knobs. Fails on degenerate
    /// geometries, degenerate dims, or a blown core budget — the
    /// returned plan is valid by construction (checked in debug builds
    /// by [`Plan::validate`]).
    pub fn build(dims: &[usize], cfg: &MappingConfig) -> Result<Plan> {
        let g = cfg.geometry;
        if g.rows == 0 || g.cols == 0 {
            bail!("degenerate core geometry {}x{}", g.rows, g.cols);
        }
        if dims.len() < 2 {
            bail!("a network needs at least input and output dims, got {dims:?}");
        }
        if let Some(l) = dims.iter().position(|&d| d == 0) {
            bail!("dims[{l}] is zero");
        }
        let mut layers = Vec::with_capacity(dims.len() - 1);
        let mut next_core = 0usize;
        for l in 0..dims.len() - 1 {
            let (n_in, n_out) = (dims[l], dims[l + 1]);
            let row_tiles = n_in.div_ceil(g.rows);
            let col_tiles = n_out.div_ceil(g.cols);
            let replication = if row_tiles == 1 {
                let fill = (g.rows / n_in).max(1);
                if cfg.max_replication > 0 {
                    fill.min(cfg.max_replication)
                } else {
                    fill
                }
            } else {
                1
            };
            let mut tiles = Vec::with_capacity(row_tiles * col_tiles);
            for ct in 0..col_tiles {
                for rt in 0..row_tiles {
                    let r0 = rt * g.rows;
                    let c0 = ct * g.cols;
                    tiles.push(TilePlan {
                        core: next_core,
                        rows: (r0, (r0 + g.rows).min(n_in)),
                        cols: (c0, (c0 + g.cols).min(n_out)),
                        owner: rt == 0,
                    });
                    next_core += 1;
                }
            }
            layers.push(LayerPlan {
                layer: l,
                n_in,
                n_out,
                replication,
                row_tiles,
                col_tiles,
                tiles,
            });
        }
        if cfg.max_cores > 0 && next_core > cfg.max_cores {
            bail!(
                "plan needs {next_core} cores, budget is {} (geometry {}x{})",
                cfg.max_cores,
                g.rows,
                g.cols
            );
        }
        let plan = Plan { geometry: g, layers, n_cores: next_core };
        debug_assert!(plan.validate().is_ok(), "planner produced an invalid plan");
        Ok(plan)
    }

    /// Structural invariants of a plan: tiles of every layer partition
    /// the [0,n_in)×[0,n_out) weight plane exactly, fit the geometry,
    /// core ids are dense and sequential, and exactly the first row tile
    /// of every column group owns the gate/state column.
    pub fn validate(&self) -> Result<()> {
        let g = self.geometry;
        let mut expect_core = 0usize;
        for lp in &self.layers {
            if lp.tiles.len() != lp.row_tiles * lp.col_tiles {
                bail!("layer {}: tile count mismatch", lp.layer);
            }
            if lp.is_row_split() && lp.replication != 1 {
                bail!("layer {}: row-split layer with replication", lp.layer);
            }
            if lp.replication * lp.n_in.min(g.rows) > g.rows {
                bail!("layer {}: replication overflows the core rows", lp.layer);
            }
            let mut area = 0usize;
            for ct in 0..lp.col_tiles {
                for rt in 0..lp.row_tiles {
                    let t = lp.tile(rt, ct);
                    if t.core != expect_core {
                        bail!("layer {}: non-sequential core id {}", lp.layer, t.core);
                    }
                    expect_core += 1;
                    if t.owner != (rt == 0) {
                        bail!("layer {}: owner flag misplaced", lp.layer);
                    }
                    if t.rows.0 >= t.rows.1 || t.cols.0 >= t.cols.1 {
                        bail!("layer {}: empty tile", lp.layer);
                    }
                    if t.n_rows() > g.rows || t.n_cols() > g.cols {
                        bail!("layer {}: tile exceeds the core geometry", lp.layer);
                    }
                    if t.rows.0 != rt * g.rows || t.cols.0 != ct * g.cols {
                        bail!("layer {}: tile origin off the grid", lp.layer);
                    }
                    if t.rows.1 > lp.n_in || t.cols.1 > lp.n_out {
                        bail!("layer {}: tile exceeds the layer shape", lp.layer);
                    }
                    area += t.n_rows() * t.n_cols();
                }
            }
            if area != lp.n_in * lp.n_out {
                bail!(
                    "layer {}: tiles cover {area} synapse sites, layer has {}",
                    lp.layer,
                    lp.n_in * lp.n_out
                );
            }
        }
        if expect_core != self.n_cores {
            bail!("core count {} != assigned ids {expect_core}", self.n_cores);
        }
        Ok(())
    }

    /// Check the plan against a concrete checkpoint's shapes.
    pub fn check_network(&self, nw: &NetworkWeights) -> Result<()> {
        if self.layers.len() != nw.n_layers() {
            bail!(
                "plan has {} layers, network has {}",
                self.layers.len(),
                nw.n_layers()
            );
        }
        for (lp, lw) in self.layers.iter().zip(nw.layers.iter()) {
            if lp.n_in != lw.n_in || lp.n_out != lw.n_out {
                bail!(
                    "layer {}: plan is {}x{}, network is {}x{}",
                    lp.layer,
                    lp.n_in,
                    lp.n_out,
                    lw.n_in,
                    lw.n_out
                );
            }
        }
        Ok(())
    }

    /// Cores belonging to layer `l`: the half-open range [start, end) in
    /// the engine's core array (column-tile major, row tile inner).
    pub fn core_range(&self, l: usize) -> (usize, usize) {
        let lp = &self.layers[l];
        let start = lp.tiles[0].core;
        (start, start + lp.tiles.len())
    }

    /// Synapse sites occupied vs provisioned (utilization metric).
    /// Replicated rows count as occupied — they hold real charge.
    pub fn occupancy(&self) -> (usize, usize) {
        self.occupancy_at(1)
    }

    /// Occupancy with `slots` lockstep batch slots provisioned per core
    /// (clamped to ≥ 1): the batched engine multiplies every column's
    /// held state by the slot count, so both occupied and provisioned
    /// state-slot counts scale by `slots` — the numbers the engine
    /// actually executes when serving batches of that size.
    pub fn occupancy_at(&self, slots: usize) -> (usize, usize) {
        let slots = slots.max(1);
        let used: usize = self
            .layers
            .iter()
            .flat_map(|lp| {
                lp.tiles
                    .iter()
                    .map(move |t| lp.replication * t.n_rows() * t.n_cols())
            })
            .sum();
        let total = self.n_cores * self.geometry.rows * self.geometry.cols;
        (used * slots, total * slots)
    }

    /// Human-readable rendering for the CLI (`minimalist plan`).
    pub fn describe(&self) -> String {
        self.describe_at(1)
    }

    /// [`Plan::describe`] for an engine provisioned with `slots`
    /// lockstep batch slots per core: reports, per layer, the slot
    /// capacity `tiles × slots` — the analog state slots the batched
    /// engine holds for that layer, i.e. `slots` concurrent sequences,
    /// each occupying one slot on every tile of the layer.
    pub fn describe_at(&self, slots: usize) -> String {
        use std::fmt::Write as _;
        let slots = slots.max(1);
        let (used, total) = self.occupancy_at(slots);
        let mut s = String::new();
        let _ = writeln!(
            s,
            "mapping plan: {} layer(s) -> {} core(s) of {}x{}, \
             {} lockstep slot(s)/core, occupancy {:.1}%",
            self.layers.len(),
            self.n_cores,
            self.geometry.rows,
            self.geometry.cols,
            slots,
            100.0 * used as f64 / total.max(1) as f64
        );
        for lp in &self.layers {
            let _ = writeln!(
                s,
                "  layer {}: {}->{}  {} row-tile(s) x {} col-tile(s), \
                 replication {}, slot capacity {} x {} = {} \
                 ({} concurrent seq)",
                lp.layer,
                lp.n_in,
                lp.n_out,
                lp.row_tiles,
                lp.col_tiles,
                lp.replication,
                lp.tiles.len(),
                slots,
                lp.tiles.len() * slots,
                slots
            );
            for t in &lp.tiles {
                let _ = writeln!(
                    s,
                    "    core {:3}  rows [{},{})  cols [{},{}){}",
                    t.core,
                    t.rows.0,
                    t.rows.1,
                    t.cols.0,
                    t.cols.1,
                    if t.owner { "  owner" } else { "" }
                );
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn build(dims: &[usize], rows: usize, cols: usize) -> Plan {
        Plan::build(
            dims,
            &MappingConfig::with_geometry(CoreGeometry { rows, cols }),
        )
        .unwrap()
    }

    #[test]
    fn paper_network_uses_expected_cores() {
        // 1-64-64-64-64-10 on 64x64 cores: every layer fits one core
        // (the paper's §4.2 counts the 4 hidden blocks ~ 4 cores; the
        // 64->10 readout occupies a fifth, partially used).
        let p = build(&[1, 64, 64, 64, 64, 10], 64, 64);
        assert_eq!(p.n_cores, 5);
        for lp in &p.layers {
            assert_eq!(lp.tiles.len(), 1);
            assert!(!lp.is_row_split());
        }
        // the 1-wide input layer replicates to fill the 64 rows
        assert_eq!(p.layers[0].replication, 64);
        assert_eq!(p.layers[1].replication, 1);
        let (used, total) = p.occupancy();
        assert!(used <= total);
        // replicated rows occupy the full input column
        assert_eq!(used, 64 * 64 + 64 * 64 * 3 + 64 * 10);
    }

    #[test]
    fn wide_layer_splits_both_ways() {
        let p = build(&[128, 96], 64, 64);
        let lp = &p.layers[0];
        assert_eq!(lp.tiles.len(), 4); // 2 row tiles x 2 col tiles
        assert_eq!((lp.row_tiles, lp.col_tiles), (2, 2));
        assert_eq!(lp.replication, 1);
        // row/col ranges tile the full weight plane exactly
        let area: usize = lp.tiles.iter().map(|t| t.n_rows() * t.n_cols()).sum();
        assert_eq!(area, 128 * 96);
        // exactly one owner per column group, at row tile 0
        for ct in 0..lp.col_tiles {
            assert!(lp.owner_tile(ct).owner);
            assert!(!lp.tile(1, ct).owner);
            assert_eq!(lp.owner_tile(ct).rows, (0, 64));
        }
    }

    #[test]
    fn uneven_row_split_keeps_remainder_tile() {
        let p = build(&[100, 8], 64, 64);
        let lp = &p.layers[0];
        assert_eq!((lp.row_tiles, lp.col_tiles), (2, 1));
        assert_eq!(lp.tile(0, 0).rows, (0, 64));
        assert_eq!(lp.tile(1, 0).rows, (64, 100));
        assert_eq!(lp.owner_rows_phys(), 64);
        assert_eq!(p.core_range(0), (0, 2));
    }

    #[test]
    fn tiny_layer_replicates_and_partially_fills() {
        let p = build(&[1, 10], 64, 64);
        let lp = &p.layers[0];
        let t = &lp.tiles[0];
        assert_eq!(t.rows, (0, 1));
        assert_eq!(t.cols, (0, 10));
        assert_eq!(lp.replication, 64);
        assert_eq!(lp.owner_rows_phys(), 64);
    }

    #[test]
    fn replication_knob_caps_fill() {
        let cfg = MappingConfig {
            geometry: CoreGeometry { rows: 64, cols: 64 },
            max_replication: 4,
            max_cores: 0,
        };
        let p = Plan::build(&[1, 10], &cfg).unwrap();
        assert_eq!(p.layers[0].replication, 4);
    }

    #[test]
    fn core_budget_enforced() {
        let cfg = MappingConfig {
            geometry: CoreGeometry { rows: 16, cols: 16 },
            max_replication: 0,
            max_cores: 2,
        };
        // 64x64 layer on 16x16 cores needs 16 tiles > budget 2
        assert!(Plan::build(&[64, 64], &cfg).is_err());
    }

    #[test]
    fn degenerate_inputs_rejected() {
        let g = MappingConfig::with_geometry(CoreGeometry { rows: 0, cols: 64 });
        assert!(Plan::build(&[4, 4], &g).is_err());
        let ok = MappingConfig::default();
        assert!(Plan::build(&[4], &ok).is_err());
        assert!(Plan::build(&[4, 0, 4], &ok).is_err());
    }

    #[test]
    fn random_plans_are_valid() {
        check::property("planner invariants", 200, |rng| {
            let rows = 1 + rng.below(96) as usize;
            let cols = 1 + rng.below(96) as usize;
            let n_layers = 1 + rng.below(4) as usize;
            let dims: Vec<usize> =
                (0..=n_layers).map(|_| 1 + rng.below(200) as usize).collect();
            let cfg = MappingConfig::with_geometry(CoreGeometry { rows, cols });
            let p = Plan::build(&dims, &cfg).map_err(|e| e.to_string())?;
            p.validate().map_err(|e| e.to_string())?;
            // core ranges are dense and ordered
            let mut next = 0usize;
            for l in 0..p.layers.len() {
                let (a, b) = p.core_range(l);
                if a != next || b < a {
                    return Err(format!("bad core range ({a},{b})"));
                }
                next = b;
            }
            if next != p.n_cores {
                return Err("core ranges do not cover the plan".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn describe_mentions_every_core() {
        let p = build(&[100, 40], 64, 32);
        let text = p.describe();
        for t in &p.layers[0].tiles {
            assert!(text.contains(&format!("core {:3}", t.core)), "{text}");
        }
        assert!(text.contains("owner"));
    }

    #[test]
    fn slot_capacity_reporting_scales_with_slots() {
        let p = build(&[100, 40], 64, 32);
        // 2 row tiles x 2 col tiles = 4 tiles on layer 0
        assert_eq!(p.layers[0].tiles.len(), 4);
        let (u1, t1) = p.occupancy_at(1);
        assert_eq!((u1, t1), p.occupancy());
        let (used8, total8) = p.occupancy_at(8);
        assert_eq!((used8, total8), (u1 * 8, t1 * 8));
        // slots = 0 clamps to 1 (a core always holds at least one slot)
        assert_eq!(p.occupancy_at(0), (u1, t1));
        let text = p.describe_at(8);
        assert!(text.contains("8 lockstep slot(s)/core"), "{text}");
        assert!(
            text.contains("slot capacity 4 x 8 = 32 (8 concurrent seq)"),
            "{text}"
        );
        // describe() stays the slots = 1 rendering
        assert!(p.describe().contains("1 lockstep slot(s)/core"));
    }
}
