//! The mapping planner: placing network layers onto a grid of
//! fixed-geometry switched-capacitor cores (paper §3: "depending on
//! their dimensionality, these GRU blocks can be mapped to one or
//! multiple cores, which are connected through an event-based routing
//! fabric").
//!
//! A [`Plan`] is a validated, inspectable placement of every layer onto
//! row-tiles × column-tiles of a [`crate::config::CoreGeometry`]:
//!
//! * **column split** — a layer with more units than core columns
//!   occupies several tiles side by side; each tile owns its units
//!   end to end (gate, state, comparator).
//! * **row split** — a layer with more inputs than core rows is split
//!   vertically. Each row tile computes a *partial* IMC charge share
//!   over its slice of the input; the partial means are combined as the
//!   row-count-weighted average `(n₁·v₁ + n₂·v₂)/(n₁+n₂)` — in hardware
//!   the column lines of vertically stacked tiles short together, which
//!   is exactly this capacitance-weighted mean. The gate digitization
//!   and the capacitor-swap state update live in the designated *owner*
//!   tile (row tile 0).
//! * **row replication** — the opposite special case: a layer with
//!   n_in ≪ rows is mapped with every logical input repeated `r` times
//!   across the physical rows, restoring the fine swap granularity a
//!   full column provides (this is how the 1-wide input layer of the
//!   paper's 1-64-… network occupies a full core column).
//!
//! The planner is pure bookkeeping — [`crate::quant::codesign`] turns
//! the plan into per-column circuit configurations, and
//! [`crate::coordinator::engine::MixedSignalEngine`] executes it.

pub mod plan;

pub use plan::{LayerPlan, Plan, TilePlan};
