//! `minimalist` CLI — leader entrypoint for the MINIMALIST system.
//!
//! Subcommands:
//!   info                      system + config summary
//!   serve                     batched serving loop over synthMNIST load
//!   serve --streaming         streaming sessions over frame-paced load
//!   serve --http              wire front end (docs/http-api.md):
//!                             one-shot + streaming over HTTP/1.1
//!   loadgen                   closed-loop load against serve --http
//!                             (--quick for CI smoke scale)
//!   plan                      print the layer→core mapping plan
//!   bench                     recorded perf baseline → BENCH_baseline.json
//!                             (--check gates on regressions vs --baseline)
//!   adc                       ADC transfer characterization (Fig 3C)
//!   trace                     software vs mixed-signal traces (Fig 4)
//!   energy                    energy report (§4.2)
//!   mc                        Monte-Carlo device-variation sweep over
//!                             the batched engine (ADR-008; --quick for
//!                             CI smoke scale, --out for the JSON report)
//!   eval                      accuracy of a checkpoint on the test split
//!
//! Run `minimalist <cmd> --help-args` for per-command options.

use anyhow::Result;

use minimalist::config::{
    CircuitConfig, CoreGeometry, MappingConfig, NetworkConfig, ServeConfig,
};
use minimalist::coordinator::{
    BatchPolicy, GoldenBackend, HttpConfig, HttpServer, LatencyRecorder,
    MixedSignalBackend, MixedSignalEngine, ServeError, Server, StreamServer,
    StreamSession,
};
use minimalist::dataset::glyphs;
use minimalist::energy;
use minimalist::mapping::Plan;
use minimalist::nn::{synthetic_network, GoldenNetwork, NetworkWeights};
use minimalist::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("info") => cmd_info(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("plan") => cmd_plan(&args),
        Some("bench") => cmd_bench(&args),
        Some("energy") => cmd_energy(&args),
        Some("mc") => cmd_mc(&args),
        Some("eval") => cmd_eval(&args),
        _ => {
            eprintln!(
                "usage: minimalist <info|serve|loadgen|plan|bench|energy|mc|eval> \
                 [--options]\n\
                 (Fig 3C / Fig 4 generators live in examples/: \
                 adc_characterization, trace_compare)"
            );
            Ok(())
        }
    }
}

/// Planner knobs from `--rows`/`--cols` (default: the paper's 64×64)
/// plus `--max-replication`/`--max-cores` — shared by `plan` and
/// `serve` so the printed plan is exactly the one served.
fn mapping_from_args(args: &Args) -> Result<MappingConfig> {
    let g = CoreGeometry::default();
    Ok(MappingConfig {
        geometry: CoreGeometry {
            rows: args.get_usize("rows", g.rows)?,
            cols: args.get_usize("cols", g.cols)?,
        },
        max_replication: args.get_usize("max-replication", 0)?,
        max_cores: args.get_usize("max-cores", 0)?,
    })
}

/// Circuit knobs shared by the satsim serving/energy commands. `--delta`
/// sets the delta-sparsity threshold (ADR-005): components whose input
/// has drifted by at most this much since they last fired skip their
/// charge-share sampling work. 0 (the default) disables the machinery
/// and serves the exact legacy path.
fn circuit_from_args(args: &Args) -> Result<CircuitConfig> {
    let delta = args.get_f64("delta", 0.0)?.max(0.0);
    Ok(CircuitConfig { delta, ..CircuitConfig::default() })
}

fn load_or_synthetic(args: &Args) -> Result<NetworkWeights> {
    match args.opt("weights") {
        Some(path) => NetworkWeights::load(path),
        None => {
            eprintln!("note: no --weights given, using a synthetic network");
            Ok(synthetic_network(
                &NetworkConfig::paper().dims,
                args.get_u64("seed", 7)?,
            ))
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let circuit = CircuitConfig::default();
    println!("MINIMALIST — switched-capacitor minGRU system");
    println!("  circuit: {circuit:#?}");
    let bound = energy::paper_network_bound(&circuit);
    println!(
        "  worst-case energy bound, 4×(64×64) cores: {:.1} pJ/step \
         (paper §4.2: 169 pJ)",
        bound * 1e12
    );
    if let Some(w) = args.opt("weights") {
        let nw = NetworkWeights::load(w)?;
        println!(
            "  checkpoint: dims {:?}, variant {}, {} layers",
            nw.dims,
            nw.variant,
            nw.n_layers()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let weights = load_or_synthetic(args)?;
    let n_req = args.get_usize("requests", 64)?;
    let img = args.get_usize("img-size", 16)?;
    let backend = args.get_or("backend", "golden").to_string();
    let defaults = ServeConfig::default();
    let serve = ServeConfig {
        workers: args.get_usize("workers", defaults.workers)?.max(1),
        max_batch: args.get_usize("max-batch", defaults.max_batch)?,
        max_wait_ms: args.get_u64("max-wait-ms", defaults.max_wait_ms)?,
        sessions: args.get_usize("sessions", defaults.sessions)?.max(1),
        http_port: args.get_u64("port", defaults.http_port as u64)? as u16,
        http_max_body_bytes: args.get_usize(
            "max-body-bytes",
            defaults.http_max_body_bytes,
        )?,
        http_keepalive_ms: args
            .get_u64("keepalive-ms", defaults.http_keepalive_ms)?,
        engine_threads: args
            .get_usize("engine-threads", defaults.engine_threads)?
            .max(1),
    };
    if args.flag("http") {
        return cmd_serve_http(args, weights, &serve, &backend);
    }
    if args.flag("streaming") {
        return cmd_serve_streaming(args, weights, &serve, &backend, n_req, img);
    }
    let policy = BatchPolicy::from(&serve);
    let server = match backend.as_str() {
        "golden" => Server::spawn_sharded(
            GoldenBackend::factory(weights),
            policy,
            serve.workers,
        ),
        "satsim" => {
            let mapping = mapping_from_args(args)?;
            let planned = Plan::build(&weights.dims, &mapping)?;
            let (plan, factory) = MixedSignalBackend::factory_from_plan(
                weights,
                circuit_from_args(args)?,
                planned,
                serve.engine_threads,
            )?;
            let (used, total) = plan.occupancy_at(serve.max_batch);
            println!(
                "mapping: {} core(s) of {}x{}, {} lockstep slot(s)/core at \
                 max batch, occupancy {:.1}% \
                 (`minimalist plan --slots N` prints the full placement)",
                plan.n_cores,
                plan.geometry.rows,
                plan.geometry.cols,
                serve.max_batch,
                100.0 * used as f64 / total.max(1) as f64
            );
            // uniform-length batches feed the engine's lockstep path as
            // one group — the fast configuration for this backend
            Server::spawn_sharded(factory, policy.bucketed(), serve.workers)
        }
        other => anyhow::bail!("unknown backend '{other}' (golden|satsim)"),
    };
    println!(
        "serving with {} worker(s), batch≤{}, wait≤{} ms",
        server.n_workers(),
        serve.max_batch,
        serve.max_wait_ms
    );
    let client = server.client();
    let samples = glyphs::make_split(n_req, img, args.get_u64("seed", 1)?);
    let mut correct = 0usize;
    let mut failed = 0usize;
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| (s.label, client.submit(i as u64, s.pixels.clone())))
        .collect();
    for (label, rx) in rxs {
        // a failed request must not kill the driver before the metrics
        // print — that is the whole point of Result-carrying responses
        match rx.recv() {
            Ok(resp) => match resp.result {
                Ok(l) => correct += (l == label) as usize,
                Err(e) => {
                    failed += 1;
                    eprintln!("request {} failed: {e}", resp.id);
                }
            },
            Err(_) => failed += 1,
        }
    }
    let metrics = server.shutdown();
    println!("backend={backend} {}", metrics.summary());
    print_error_breakdown(&metrics);
    println!(
        "accuracy {}/{} = {:.3} ({} failed)",
        correct,
        n_req,
        correct as f64 / n_req as f64,
        failed
    );
    Ok(())
}

/// Break the merged error counter out per [`ServeError`] variant, so
/// e.g. streaming `Busy` rejections are distinguishable from lost
/// requests and poisoned batches in the end-of-run report.
fn print_error_breakdown(metrics: &LatencyRecorder) {
    if metrics.errors > 0 {
        println!(
            "errors   : {} total — lost={} busy={} panicked={}",
            metrics.errors,
            metrics.errors_lost,
            metrics.errors_busy,
            metrics.errors_panicked
        );
    }
}

/// `minimalist serve --streaming`: frame-paced synthetic load through
/// the streaming-session path. The driver keeps up to `--concurrent`
/// sessions open (default: the slot capacity, `workers × --sessions`;
/// set it higher to watch `Busy` rejections), pushes
/// `--frames-per-push` pixels per round to every live session — the
/// worker advances them together in lockstep — polls one session's
/// running logits mid-sequence, and closes each finished session for
/// its label.
fn cmd_serve_streaming(
    args: &Args,
    weights: minimalist::nn::NetworkWeights,
    serve: &ServeConfig,
    backend: &str,
    n_req: usize,
    img: usize,
) -> Result<()> {
    let capacity = serve.workers * serve.sessions;
    let concurrent = args.get_usize("concurrent", capacity)?.max(1);
    let chunk = args.get_usize("frames-per-push", 32)?.max(1);
    let server = match backend {
        "golden" => StreamServer::spawn(
            GoldenBackend::streaming_factory(weights, serve.sessions),
            serve.workers,
            serve.sessions,
        ),
        "satsim" => {
            let mapping = mapping_from_args(args)?;
            let planned = Plan::build(&weights.dims, &mapping)?;
            let (plan, factory) = MixedSignalBackend::streaming_factory_from_plan(
                weights,
                circuit_from_args(args)?,
                planned,
                serve.sessions,
                serve.engine_threads,
            )?;
            let (used, total) = plan.occupancy_at(serve.sessions);
            println!(
                "mapping: {} core(s) of {}x{}, {} resident session slot(s) \
                 per worker, occupancy {:.1}%",
                plan.n_cores,
                plan.geometry.rows,
                plan.geometry.cols,
                serve.sessions,
                100.0 * used as f64 / total.max(1) as f64
            );
            StreamServer::spawn(factory, serve.workers, serve.sessions)
        }
        other => anyhow::bail!("unknown backend '{other}' (golden|satsim)"),
    };
    println!(
        "streaming with {} worker(s) × {} slot(s) = capacity {capacity}, \
         {concurrent} concurrent session(s), {chunk} frame(s)/push",
        server.n_workers(),
        serve.sessions,
    );
    let client = server.client();
    let samples = glyphs::make_split(n_req, img, args.get_u64("seed", 1)?);
    // (label, session, pixels, cursor) per live session
    let mut active: Vec<(usize, StreamSession, Vec<f32>, usize)> = Vec::new();
    let mut it = samples.into_iter();
    let (mut correct, mut failed, mut busy_rejected) = (0usize, 0usize, 0usize);
    let mut polled = false;
    loop {
        // top up the live-session window; a Busy rejection ends the
        // top-up for this round (the sample counts as rejected load)
        while active.len() < concurrent {
            let Some(s) = it.next() else { break };
            match client.open() {
                Ok(sess) => active.push((s.label, sess, s.pixels, 0)),
                Err(e) => {
                    failed += 1;
                    busy_rejected += (e == ServeError::Busy) as usize;
                    break;
                }
            }
        }
        if active.is_empty() {
            break;
        }
        // one frame-paced round: a chunk to every live session, pushed
        // without waiting so the worker ticks them in lockstep
        let acks: Vec<_> = active
            .iter_mut()
            .map(|(_, sess, px, cur)| {
                let end = (*cur + chunk).min(px.len());
                let payload = px[*cur..end].to_vec();
                *cur = end;
                sess.push_frames_nowait(payload)
            })
            .collect();
        for rx in acks {
            let _ = rx.recv();
        }
        // demonstrate the mid-sequence poll once, on a half-done session
        if !polled {
            if let Some((_, sess, px, cur)) =
                active.iter().find(|(_, _, px, cur)| *cur * 2 >= px.len())
            {
                if *cur < px.len() {
                    if let Ok(logits) = sess.logits() {
                        println!(
                            "running logits after {}/{} frames: argmax={}",
                            cur,
                            px.len(),
                            minimalist::nn::argmax(&logits)
                        );
                        polled = true;
                    }
                }
            }
        }
        // close finished sessions (slots return to the pool, so the
        // next round's top-up reuses them)
        let mut i = 0;
        while i < active.len() {
            if active[i].3 >= active[i].2.len() {
                let (label, sess, _, _) = active.swap_remove(i);
                match sess.close() {
                    Ok(l) => correct += (l == label) as usize,
                    Err(e) => {
                        failed += 1;
                        eprintln!("session close failed: {e}");
                    }
                }
            } else {
                i += 1;
            }
        }
    }
    let metrics = server.shutdown();
    println!("backend={backend} streaming {}", metrics.summary());
    print_error_breakdown(&metrics);
    println!(
        "accuracy {}/{} = {:.3} ({} failed, {} busy-rejected)",
        correct,
        n_req,
        correct as f64 / n_req as f64,
        failed,
        busy_rejected
    );
    Ok(())
}

/// `minimalist serve --http`: both serving modes behind the wire front
/// end (protocol in docs/http-api.md), serving until `--for-ms`
/// elapses (0, the default, serves until killed). `--port 0` (default)
/// binds an ephemeral port; `--port-file p` writes the bound port for
/// scripted callers — how the CI smoke job finds the server.
fn cmd_serve_http(
    args: &Args,
    weights: NetworkWeights,
    serve: &ServeConfig,
    backend: &str,
) -> Result<()> {
    let policy = BatchPolicy::from(serve);
    let (server, stream) = match backend {
        "golden" => (
            Server::spawn_sharded(
                GoldenBackend::factory(weights.clone()),
                policy,
                serve.workers,
            ),
            StreamServer::spawn(
                GoldenBackend::streaming_factory(weights, serve.sessions),
                serve.workers,
                serve.sessions,
            ),
        ),
        "satsim" => {
            let mapping = mapping_from_args(args)?;
            let planned = Plan::build(&weights.dims, &mapping)?;
            let circuit = circuit_from_args(args)?;
            let (_, one_shot) = MixedSignalBackend::factory_from_plan(
                weights.clone(),
                circuit.clone(),
                planned.clone(),
                serve.engine_threads,
            )?;
            let (_, streaming) =
                MixedSignalBackend::streaming_factory_from_plan(
                    weights,
                    circuit,
                    planned,
                    serve.sessions,
                    serve.engine_threads,
                )?;
            (
                Server::spawn_sharded(
                    one_shot,
                    policy.bucketed(),
                    serve.workers,
                ),
                StreamServer::spawn(streaming, serve.workers, serve.sessions),
            )
        }
        other => anyhow::bail!("unknown backend '{other}' (golden|satsim)"),
    };
    let http = HttpServer::bind(
        &format!("{}:{}", args.get_or("bind", "127.0.0.1"), serve.http_port),
        Some(server.client()),
        Some(stream.client()),
        HttpConfig::from(serve),
    )?;
    let addr = http.addr();
    println!(
        "http front end on {addr}: backend={backend}, {} one-shot \
         worker(s), {}×{} session slot(s)",
        server.n_workers(),
        stream.n_workers(),
        serve.sessions
    );
    if let Some(path) = args.opt("port-file") {
        std::fs::write(path, format!("{}\n", addr.port()))?;
    }
    let for_ms = args.get_u64("for-ms", 0)?;
    if for_ms == 0 {
        println!("serving until killed (--for-ms N bounds the run)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_millis(for_ms));
    // drain order matters: front end first, so in-flight requests
    // complete against live engines instead of surfacing as 503s
    println!("http {}", http.shutdown().summary());
    println!("one-shot {}", server.shutdown().summary());
    println!("streaming {}", stream.shutdown().summary());
    Ok(())
}

/// `minimalist loadgen --target host:port`: closed-loop wire load
/// against a running `serve --http`. Exits non-zero when zero sessions
/// complete or any protocol error is observed — the CI smoke gate's
/// assertion. `--out p` writes the schema-4 JSON report.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use minimalist::coordinator::loadgen::{self, LoadGenOpts};
    let base = if args.flag("quick") {
        LoadGenOpts::quick()
    } else {
        LoadGenOpts::default()
    };
    let opts = LoadGenOpts {
        connections: args.get_usize("connections", base.connections)?.max(1),
        sessions_per_conn: args
            .get_usize("sessions-per-conn", base.sessions_per_conn)?
            .max(1),
        frames: args.get_usize("frames", base.frames)?.max(1),
        frames_per_push: args
            .get_usize("frames-per-push", base.frames_per_push)?
            .max(1),
        frame_width: args.get_usize("frame-width", base.frame_width)?.max(1),
        poll_logits: !args.flag("no-poll"),
    };
    let target = args.get_or("target", "127.0.0.1:8080").to_string();
    println!(
        "loadgen → {target}: {} connection(s) × {} session(s), {} frame(s) \
         in chunks of {}",
        opts.connections,
        opts.sessions_per_conn,
        opts.frames,
        opts.frames_per_push
    );
    let report = loadgen::run(&target, &opts);
    println!("{}", report.summary());
    if let Some(out) = args.opt("out") {
        std::fs::write(out, format!("{}\n", report.to_json(&target, &opts)))?;
        println!("wrote {out}");
    }
    anyhow::ensure!(
        report.sessions_completed > 0,
        "no sessions completed against {target}"
    );
    anyhow::ensure!(
        report.protocol_errors == 0,
        "{} protocol error(s) observed",
        report.protocol_errors
    );
    Ok(())
}

/// Print the layer→core placement for a network and geometry:
///   minimalist plan [--dims 100,32,10] [--rows 64] [--cols 64]
///                   [--max-replication N] [--max-cores N] [--weights p]
///                   [--slots B]
/// Without --dims, the checkpoint's (or the paper network's) dims plan.
/// `--slots` reports the per-layer slot capacity (tiles × slots) the
/// batched engine provisions when serving batches of that size.
fn cmd_plan(args: &Args) -> Result<()> {
    let dims: Vec<usize> = match args.opt("dims") {
        Some(s) => s
            .split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--dims expects integers, got '{d}'"))
            })
            .collect::<Result<_>>()?,
        None => match args.opt("weights") {
            Some(p) => NetworkWeights::load(p)?.dims,
            None => NetworkConfig::paper().dims,
        },
    };
    let plan = Plan::build(&dims, &mapping_from_args(args)?)?;
    print!("{}", plan.describe_at(args.get_usize("slots", 1)?));
    Ok(())
}

/// Run the recorded perf suite and write the machine-readable baseline:
///   minimalist bench [--quick] [--out BENCH_baseline.json]
///                    [--check] [--baseline BENCH_baseline.json]
/// `--quick` shrinks budgets/request counts to CI smoke-test scale.
/// `--check` compares the fresh run against the committed baseline and
/// exits non-zero on a hard (>25%) throughput regression; smaller
/// drifts print `::warning::` annotations (surfaced by GitHub Actions).
fn cmd_bench(args: &Args) -> Result<()> {
    use minimalist::bench_suite;
    let opts = bench_suite::BenchOpts { quick: args.flag("quick") };
    let out = args.get_or("out", "BENCH_baseline.json");
    eprintln!(
        "running bench suite ({}) ...",
        if opts.quick { "quick" } else { "full" }
    );
    let doc = bench_suite::run(&opts);
    bench_suite::print_engine_summary(&doc);
    bench_suite::write(out, &doc)?;
    println!("wrote {out}");
    if args.flag("check") {
        let baseline_path = args.get_or("baseline", "BENCH_baseline.json");
        let text = std::fs::read_to_string(baseline_path).map_err(|e| {
            anyhow::anyhow!("reading baseline {baseline_path}: {e}")
        })?;
        let baseline = minimalist::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {baseline_path}: {e}"))?;
        let outcome = bench_suite::check_against(
            &doc,
            &baseline,
            bench_suite::CHECK_FAIL_FRAC,
            bench_suite::CHECK_WARN_FRAC,
        );
        for n in &outcome.notes {
            println!("bench-check: {n}");
        }
        for w in &outcome.warnings {
            // GitHub Actions renders these as advisory annotations
            println!("::warning::bench-check drift: {w}");
        }
        for r in &outcome.hard_regressions {
            println!("::error::bench-check regression: {r}");
        }
        if !outcome.passed() {
            anyhow::bail!(
                "bench regression gate failed: {} metric(s) dropped more \
                 than {:.0}% vs {baseline_path}",
                outcome.hard_regressions.len(),
                100.0 * bench_suite::CHECK_FAIL_FRAC
            );
        }
        println!("bench-check: OK vs {baseline_path}");
    }
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    let circuit = circuit_from_args(args)?;
    let bound = energy::worst_case_step_bound(&circuit, 64, 64);
    println!(
        "worst-case bound per 64×64 core: {:.2} pJ/step; 4 cores: {:.1} pJ \
         (paper: 169 pJ)",
        bound * 1e12,
        4.0 * bound * 1e12
    );
    // simulated, activity-dependent energy
    let weights = load_or_synthetic(args)?;
    let mut engine = MixedSignalEngine::new(
        weights,
        circuit,
        CoreGeometry::default(),
    )?;
    let t = args.get_usize("steps", 64)?;
    let n_inf = args.get_usize("inferences", 4)?.max(1);
    let seq: Vec<f32> = (0..t).map(|i| ((i * 7) % 11) as f32 / 10.0).collect();
    for _ in 0..n_inf {
        engine.classify(&seq);
    }
    // meters are lifetime-cumulative, so the per-inference figure is
    // the live total amortized over the inferences actually run
    let m = engine.energy();
    println!(
        "simulated over {} steps, {} cores: {:.2} pJ/step, \
         {:.2} pJ/inference over {} inference(s) of {} steps \
         ({} cap events, {} switch toggles, {} conversions)",
        m.steps,
        engine.n_cores(),
        m.per_step_j() * 1e12,
        m.total_j() / n_inf as f64 * 1e12,
        n_inf,
        t,
        m.cap_events,
        m.switch_toggles,
        m.adc_conversions
    );
    let d = engine.delta_stats();
    if d.components_fired + d.components_skipped > 0 {
        println!(
            "delta sparsity: fired={} skipped={} skip_ratio={:.3} \
             (shares {} done / {} skipped)",
            d.components_fired,
            d.components_skipped,
            d.skip_ratio(),
            d.shares_done,
            d.shares_skipped
        );
    }
    Ok(())
}

/// `minimalist mc`: Monte-Carlo device-variation sweep (ADR-008).
///   minimalist mc [--quick] [--instances N] [--mismatch-levels 0,0.01,..]
///                 [--delta D] [--engine-threads T] [--samples N]
///                 [--img-size S] [--seed MASTER] [--rows R] [--cols C]
///                 [--weights p] [--out report.json]
/// Every batch slot is fabricated as its own device instance from the
/// master seed; the report reduces to per-mismatch-level accuracy
/// (mean/min/p5), label-flip rate vs the ideal device, and simulated
/// energy. Exits non-zero on an empty sweep or NaN accuracy — the CI
/// `mc-smoke` assertion.
fn cmd_mc(args: &Args) -> Result<()> {
    use minimalist::montecarlo::DeviceSweep;
    let quick = args.flag("quick");
    let base = if quick { DeviceSweep::quick() } else { DeviceSweep::default() };
    let levels: Vec<f64> = match args.opt("mismatch-levels") {
        Some(s) => s
            .split(',')
            .map(|v| {
                v.trim().parse::<f64>().map_err(|_| {
                    anyhow::anyhow!("--mismatch-levels expects floats, got '{v}'")
                })
            })
            .collect::<Result<_>>()?,
        None => base.mismatch_levels.clone(),
    };
    // the quick sweep runs a small network on small cores so the CI
    // smoke job still covers ≥ 64 device instances in seconds
    let default_geo = if quick {
        CoreGeometry { rows: 16, cols: 16 }
    } else {
        base.geometry
    };
    let sweep = DeviceSweep {
        instances: args.get_usize("instances", base.instances)?.max(1),
        mismatch_levels: levels,
        delta: args.get_f64("delta", base.delta)?.max(0.0),
        engine_threads: args
            .get_usize("engine-threads", base.engine_threads)?
            .max(1),
        samples: args.get_usize("samples", base.samples)?.max(1),
        img: args.get_usize("img-size", base.img)?.max(2),
        master_seed: args.get_u64("seed", base.master_seed)?,
        geometry: CoreGeometry {
            rows: args.get_usize("rows", default_geo.rows)?,
            cols: args.get_usize("cols", default_geo.cols)?,
        },
    };
    let weights = match args.opt("weights") {
        Some(p) => NetworkWeights::load(p)?,
        None if quick => synthetic_network(&[1, 16, 10], 7),
        None => {
            eprintln!("note: no --weights given, using a synthetic network");
            synthetic_network(&NetworkConfig::paper().dims, 7)
        }
    };
    let report = sweep.run(&weights)?;
    print!("{}", report.summary());
    if let Some(out) = args.opt("out") {
        std::fs::write(out, format!("{}\n", report.to_json()))?;
        println!("wrote {out}");
    }
    anyhow::ensure!(
        !report.levels.is_empty(),
        "empty sweep: no mismatch level produced a report"
    );
    anyhow::ensure!(
        report.levels.iter().all(|l| {
            l.acc_mean.is_finite() && l.acc_min.is_finite() && l.acc_p5.is_finite()
        }),
        "sweep produced NaN accuracy"
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let weights = load_or_synthetic(args)?;
    let split = minimalist::dataset::load_test_split(
        args.get_or("data", "artifacts/synthmnist_test.mtf"),
    )?;
    let mut net = GoldenNetwork::new(weights);
    let mut correct = 0;
    for (x, &y) in split.x.iter().zip(split.y.iter()) {
        correct += (net.classify(x) == y) as usize;
    }
    println!(
        "golden accuracy: {}/{} = {:.4}",
        correct,
        split.y.len(),
        correct as f64 / split.y.len() as f64
    );
    Ok(())
}
