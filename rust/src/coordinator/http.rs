//! The wire-level front end: `std::net` HTTP/1.1 serving over the
//! in-process coordinator (docs/adr/004, wire contract in
//! docs/http-api.md).
//!
//! One [`HttpServer`] owns a `TcpListener` plus handles to the two
//! serving engines — a [`Client`] for one-shot classification and a
//! [`StreamClient`] for streaming sessions — and bridges bytes to
//! them:
//!
//! * an **accept thread** takes connections off the listener and hands
//!   each to its own **connection thread** (blocking reads with a
//!   timeout, so idle keep-alive connections poll the drain flag
//!   instead of pinning the process);
//! * the connection thread parses requests with the bounded
//!   [`crate::util::http`] parser, routes on `(method, path)`, and
//!   answers JSON; protocol violations get the status the parser
//!   assigned and close the connection — a malformed peer can never
//!   take the listener down (routing is additionally panic-contained,
//!   answering 500);
//! * streaming sessions are resident server-side state: `POST
//!   /v1/session` leases a slot via [`StreamClient::open`] and parks
//!   the [`StreamSession`] handle in a registry keyed by the
//!   server-assigned id, which later `frames`/`logits`/`DELETE`
//!   requests — on any connection — look up by path. Admission is
//!   reject-not-queue, straight from docs/adr/003:
//!   [`ServeError::Busy`] maps to 429, [`ServeError::Lost`] and
//!   [`ServeError::BackendPanicked`] to 503 ([`serve_status`]).
//!
//! Shutdown is a graceful drain ([`HttpServer::shutdown`]): set the
//! drain flag, nudge the accept thread awake, let every connection
//! thread finish the request it is on (responses during drain say
//! `Connection: close`), join them all, then return the merged
//! [`HttpMetrics`]. The engines behind the front end are intentionally
//! *not* owned here — drain the front end first, then shut the engines
//! down, and in-flight requests complete instead of surfacing as 503s.

use std::collections::{BTreeMap, HashMap};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::coordinator::metrics::LatencyRecorder;
use crate::coordinator::server::{
    Client, ServeError, StreamClient, StreamSession,
};
use crate::nn::argmax;
use crate::util::http::{
    read_request, write_response, HttpRequest, Limits, ReadError,
};
use crate::util::json::Json;

const JSON: &str = "application/json";
const TEXT: &str = "text/plain; charset=utf-8";

/// Front-end knobs: parser limits plus the keep-alive read timeout
/// (which doubles as the drain poll tick — an idle connection notices
/// `shutdown` within one tick).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Protocol limits (head/header/body bounds).
    pub limits: Limits,
    /// Idle-poll tick for keep-alive connections.
    pub keepalive: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            limits: Limits::default(),
            keepalive: Duration::from_millis(2000),
        }
    }
}

impl From<&ServeConfig> for HttpConfig {
    fn from(c: &ServeConfig) -> HttpConfig {
        HttpConfig {
            limits: Limits {
                max_body_bytes: c.http_max_body_bytes.max(1024),
                ..Limits::default()
            },
            keepalive: Duration::from_millis(c.http_keepalive_ms.max(10)),
        }
    }
}

/// Counters and latency distribution of the front end itself (the
/// engines keep their own [`LatencyRecorder`]s; these are the
/// over-the-wire numbers). Snapshotted by [`HttpServer::shutdown`],
/// rendered live by `GET /metrics`.
#[derive(Debug, Clone, Default)]
pub struct HttpMetrics {
    /// Wire latency of every 2xx request (parse → response flushed is
    /// excluded; this is the routed-work window).
    pub recorder: LatencyRecorder,
    /// Connections accepted.
    pub connections: u64,
    /// Requests refused by the parser (400/411/413/431/501/505).
    pub protocol_errors: u64,
    /// Responses written, by status code.
    pub by_status: BTreeMap<u16, u64>,
}

impl HttpMetrics {
    /// Responses written, all statuses.
    pub fn requests(&self) -> u64 {
        self.by_status.values().sum()
    }

    /// The `GET /metrics` text exposition (Prometheus-style lines):
    /// front-end counters, request-latency quantiles, and the
    /// per-variant [`ServeError`] counts the recorder broke out.
    pub fn render(&self, live_sessions: usize) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "minimalist_http_connections_total {}\n",
            self.connections
        ));
        s.push_str(&format!(
            "minimalist_http_requests_total {}\n",
            self.requests()
        ));
        s.push_str(&format!(
            "minimalist_http_protocol_errors_total {}\n",
            self.protocol_errors
        ));
        s.push_str(&format!(
            "minimalist_http_sessions_live {live_sessions}\n"
        ));
        for (st, n) in &self.by_status {
            s.push_str(&format!(
                "minimalist_http_responses_total{{status=\"{st}\"}} {n}\n"
            ));
        }
        let pcts = self.recorder.percentiles(&[50.0, 95.0, 99.0]);
        for (q, d) in [("0.5", pcts[0]), ("0.95", pcts[1]), ("0.99", pcts[2])] {
            s.push_str(&format!(
                "minimalist_http_request_latency_us{{quantile=\"{q}\"}} {}\n",
                d.as_micros()
            ));
        }
        s.push_str(&format!(
            "minimalist_http_request_latency_us_count {}\n",
            self.recorder.items
        ));
        for (kind, n) in [
            ("busy", self.recorder.errors_busy),
            ("lost", self.recorder.errors_lost),
            ("panicked", self.recorder.errors_panicked),
        ] {
            s.push_str(&format!(
                "minimalist_serve_errors_total{{kind=\"{kind}\"}} {n}\n"
            ));
        }
        // delta-sparsity skip accounting (ADR-005) — folded into the
        // recorder from the engine workers; zeros unless a delta
        // backend ran behind this front end. Family names are spelled
        // out in full so repolint's `exhaustive-metrics` rule can
        // check each one against docs/http-api.md.
        for (family, n) in [
            (
                "minimalist_delta_components_fired_total",
                self.recorder.delta.components_fired,
            ),
            (
                "minimalist_delta_components_skipped_total",
                self.recorder.delta.components_skipped,
            ),
            (
                "minimalist_delta_shares_skipped_total",
                self.recorder.delta.shares_skipped,
            ),
        ] {
            s.push_str(&format!("{family} {n}\n"));
        }
        // §4.2 energy accounting — the engine workers' live meters,
        // folded into the recorder at worker exit and summed across
        // workers (steps are disjoint per worker, so they add). Zeros
        // unless a mixed-signal backend ran behind this front end.
        // Spelled out in full for repolint's `exhaustive-metrics` rule.
        let e = &self.recorder.energy;
        for (family, n) in [
            ("minimalist_energy_cap_events_total", e.cap_events),
            ("minimalist_energy_switch_toggles_total", e.switch_toggles),
            ("minimalist_energy_adc_conversions_total", e.adc_conversions),
            ("minimalist_energy_steps_total", e.steps),
        ] {
            s.push_str(&format!("{family} {n}\n"));
        }
        s.push_str(&format!(
            "minimalist_energy_joules_total {:e}\n",
            e.total_j()
        ));
        s.push_str(&format!(
            "minimalist_energy_joules_per_step {:e}\n",
            e.per_step_j()
        ));
        s
    }

    /// One-line end-of-run report for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "connections={} requests={} protocol_errors={} {}",
            self.connections,
            self.requests(),
            self.protocol_errors,
            self.recorder.summary()
        )
    }
}

/// The canonical [`ServeError`]→HTTP-status mapping — the single
/// site the wire spec (docs/http-api.md), the request router, the
/// conformance tests, and repolint's `exhaustive-status` rule all
/// agree on: reject-not-queue `Busy` is the client's backpressure
/// signal (429, retry after closing something); `Lost`/
/// `BackendPanicked` mean the serving side is gone or poisoned (503).
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::Busy => 429,
        ServeError::Lost => 503,
        ServeError::BackendPanicked(_) => 503,
    }
}

/// Status code + error kind for a failed serving op; the code is
/// [`status_for`], the kind is the `error` field of the JSON body.
pub fn serve_status(e: &ServeError) -> (u16, &'static str) {
    let kind = match e {
        ServeError::Busy => "busy",
        ServeError::Lost => "lost",
        ServeError::BackendPanicked(_) => "backend_panicked",
    };
    (status_for(e), kind)
}

/// `{"error": kind, "message": msg}` — the error body shape every
/// non-2xx JSON response carries.
pub fn error_body(kind: &str, msg: &str) -> String {
    Json::obj(vec![("error", kind.into()), ("message", msg.into())])
        .to_string()
}

/// Metrics/registry mutexes hold plain data — a panic mid-update
/// cannot break an invariant worth halting the listener for, so locks
/// shrug off poisoning instead of cascading it.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Shared state of one front end: engine handles, the session
/// registry, metrics, and the drain flag.
struct HttpState {
    classify: Option<Client>,
    stream: Option<StreamClient>,
    sessions: Mutex<HashMap<u64, StreamSession>>,
    metrics: Mutex<HttpMetrics>,
    draining: AtomicBool,
    next_id: AtomicU64,
}

/// (status, content-type, body) — what a route handler produces.
type Resp = (u16, &'static str, String);

/// A listening front end. Binding with port 0 picks an ephemeral port
/// — [`HttpServer::addr`] is the bound address to dial.
pub struct HttpServer {
    addr: SocketAddr,
    state: Arc<HttpState>,
    accept: thread::JoinHandle<()>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind and start serving. `classify`/`stream` are the engine
    /// handles routes dispatch to; pass `None` to leave a family of
    /// routes answering 503 (e.g. a pure streaming deployment).
    pub fn bind(
        addr: &str,
        classify: Option<Client>,
        stream: Option<StreamClient>,
        cfg: HttpConfig,
    ) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let state = Arc::new(HttpState {
            classify,
            stream,
            sessions: Mutex::new(HashMap::new()),
            metrics: Mutex::new(HttpMetrics::default()),
            draining: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
        });
        let conns = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let state = Arc::clone(&state);
            let conns = Arc::clone(&conns);
            thread::Builder::new()
                .name("minimalist-http-accept".to_string())
                .spawn(move || accept_loop(listener, state, conns, cfg))
                // lint: allow(panic, construction-time spawn failure: the listener has not served anything yet)
                .expect("spawning http accept thread")
        };
        Ok(HttpServer { addr: local, state, accept, conns })
    }

    /// The bound address (resolves the port when bound with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live sessions currently parked in the registry.
    pub fn live_sessions(&self) -> usize {
        lock(&self.state.sessions).len()
    }

    /// Graceful drain: stop accepting, let every connection finish the
    /// request it is serving (in-drain responses are marked
    /// `Connection: close`; idle connections notice within one
    /// keep-alive tick), join all threads, close the listener, and
    /// return the metrics snapshot. Call this **before** shutting down
    /// the engines behind it, so in-flight requests complete.
    pub fn shutdown(self) -> HttpMetrics {
        self.state.draining.store(true, Ordering::SeqCst);
        // the accept thread blocks in accept(): nudge it awake so it
        // observes the flag (the no-op connection is dropped unserved)
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
        let handles: Vec<_> = lock(&self.conns).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        lock(&self.state.metrics).clone()
    }
}

fn accept_loop(
    listener: TcpListener,
    state: Arc<HttpState>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    cfg: HttpConfig,
) {
    for res in listener.incoming() {
        if state.draining.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = res else { continue };
        lock(&state.metrics).connections += 1;
        let st = Arc::clone(&state);
        let c = cfg.clone();
        let spawned = thread::Builder::new()
            .name("minimalist-http-conn".to_string())
            .spawn(move || handle_connection(stream, st, c));
        match spawned {
            Ok(h) => {
                let mut guard = lock(&conns);
                // reap finished threads so a long-lived listener does
                // not accumulate one parked handle per past connection
                guard.retain(|h| !h.is_finished());
                guard.push(h);
            }
            Err(e) => eprintln!("minimalist-http: spawn failed: {e}"),
        }
    }
    // dropping the listener here closes it — post-drain dials are
    // refused at the socket level
}

fn handle_connection(stream: TcpStream, state: Arc<HttpState>, cfg: HttpConfig) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.keepalive));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        match read_request(&mut reader, &cfg.limits) {
            Ok(req) => {
                let t0 = Instant::now();
                // contain handler panics: answer 500 and keep listening
                // rather than letting one request kill the connection
                // thread silently
                let (status, ctype, body) =
                    catch_unwind(AssertUnwindSafe(|| respond(&state, &req)))
                        .unwrap_or_else(|_| {
                            (
                                500,
                                JSON,
                                error_body("internal", "handler panicked"),
                            )
                        });
                let close = !req.keep_alive()
                    || state.draining.load(Ordering::SeqCst);
                {
                    let mut m = lock(&state.metrics);
                    *m.by_status.entry(status).or_insert(0) += 1;
                    if (200..300).contains(&status) {
                        m.recorder.record(t0.elapsed());
                    }
                }
                let sent = write_response(
                    &mut writer,
                    status,
                    ctype,
                    body.as_bytes(),
                    close,
                );
                if sent.is_err() || close {
                    return;
                }
            }
            Err(ReadError::Idle) => {
                if state.draining.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(ReadError::Eof) | Err(ReadError::Io(_)) => return,
            Err(ReadError::Bad { status, msg }) => {
                {
                    let mut m = lock(&state.metrics);
                    m.protocol_errors += 1;
                    *m.by_status.entry(status).or_insert(0) += 1;
                }
                // a protocol violation leaves the stream position
                // undefined — answer and close
                let _ = write_response(
                    &mut writer,
                    status,
                    JSON,
                    error_body("protocol", &msg).as_bytes(),
                    true,
                );
                return;
            }
        }
    }
}

/// Route one parsed request. Total: every `(method, path)` lands on a
/// handler, a 405 (known path, wrong method), or a 404.
fn respond(state: &HttpState, req: &HttpRequest) -> Resp {
    let segs = req.path_segments();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => {
            let text =
                lock(&state.metrics).render(lock(&state.sessions).len());
            (200, TEXT, text)
        }
        ("POST", ["v1", "classify"]) => classify(state, req),
        ("POST", ["v1", "session"]) => open_session(state),
        ("POST", ["v1", "session", id, "frames"]) => {
            push_frames(state, id, req)
        }
        ("GET", ["v1", "session", id, "logits"]) => session_logits(state, id),
        ("DELETE", ["v1", "session", id]) => close_session(state, id),
        (
            _,
            ["healthz"]
            | ["metrics"]
            | ["v1", "classify"]
            | ["v1", "session"]
            | ["v1", "session", _]
            | ["v1", "session", _, "frames"]
            | ["v1", "session", _, "logits"],
        ) => (
            405,
            JSON,
            error_body(
                "method_not_allowed",
                &format!("{} is not valid here", req.method),
            ),
        ),
        _ => (
            404,
            JSON,
            error_body("not_found", &format!("no route for {}", req.target)),
        ),
    }
}

fn healthz(state: &HttpState) -> Resp {
    let status = if state.draining.load(Ordering::SeqCst) {
        "draining"
    } else {
        "ok"
    };
    let body = Json::obj(vec![
        ("status", status.into()),
        ("live_sessions", lock(&state.sessions).len().into()),
    ]);
    (200, JSON, body.to_string())
}

/// Record the failed op and build its response.
fn serve_failure(state: &HttpState, e: &ServeError) -> Resp {
    lock(&state.metrics).recorder.record_error(e);
    let (status, kind) = serve_status(e);
    (status, JSON, error_body(kind, &e.to_string()))
}

fn unavailable(what: &str) -> Resp {
    (
        503,
        JSON,
        error_body("unavailable", &format!("no {what} engine configured")),
    )
}

fn parse_json_body(req: &HttpRequest) -> Result<Json, Resp> {
    let text = std::str::from_utf8(&req.body).map_err(|_| {
        (400, JSON, error_body("bad_request", "body is not valid UTF-8"))
    })?;
    Json::parse(text).map_err(|e| {
        (400, JSON, error_body("bad_request", &format!("invalid JSON: {e}")))
    })
}

/// A required non-empty numeric array field, as f32.
fn f32_field(body: &Json, key: &str) -> Result<Vec<f32>, Resp> {
    let arr = body.get(key).and_then(Json::as_arr).ok_or_else(|| {
        (
            400,
            JSON,
            error_body(
                "bad_request",
                &format!("'{key}' must be an array of numbers"),
            ),
        )
    })?;
    if arr.is_empty() {
        return Err((
            400,
            JSON,
            error_body("bad_request", &format!("'{key}' must be non-empty")),
        ));
    }
    arr.iter()
        .map(|v| v.as_f64().map(|x| x as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| {
            (
                400,
                JSON,
                error_body(
                    "bad_request",
                    &format!("'{key}' must contain only numbers"),
                ),
            )
        })
}

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn classify(state: &HttpState, req: &HttpRequest) -> Resp {
    let Some(client) = &state.classify else {
        return unavailable("one-shot");
    };
    let body = match parse_json_body(req) {
        Ok(j) => j,
        Err(r) => return r,
    };
    let seq = match f32_field(&body, "sequence") {
        Ok(s) => s,
        Err(r) => return r,
    };
    let id = body
        .get("id")
        .and_then(Json::as_f64)
        .map(|x| x as u64)
        .unwrap_or_else(|| state.next_id.fetch_add(1, Ordering::Relaxed));
    let resp = client.classify(id, seq);
    match resp.result {
        Ok(label) => {
            let out = Json::obj(vec![
                ("id", (id as f64).into()),
                ("label", label.into()),
                ("latency_us", (resp.latency.as_micros() as f64).into()),
            ]);
            (200, JSON, out.to_string())
        }
        Err(e) => serve_failure(state, &e),
    }
}

fn open_session(state: &HttpState) -> Resp {
    let Some(stream) = &state.stream else {
        return unavailable("streaming");
    };
    match stream.open() {
        Ok(sess) => {
            let id = sess.id;
            lock(&state.sessions).insert(id, sess);
            let body = Json::obj(vec![("session", (id as f64).into())]);
            (201, JSON, body.to_string())
        }
        Err(e) => serve_failure(state, &e),
    }
}

/// Resolve a path id to a registered session handle (cloned out of the
/// registry so the lock is not held across the engine roundtrip).
fn session_handle(
    state: &HttpState,
    id_str: &str,
) -> Result<(u64, StreamSession), Resp> {
    let id: u64 = id_str.parse().map_err(|_| {
        (
            400,
            JSON,
            error_body(
                "bad_request",
                &format!("session id '{id_str}' is not an integer"),
            ),
        )
    })?;
    match lock(&state.sessions).get(&id) {
        Some(s) => Ok((id, s.clone())),
        None => Err((
            404,
            JSON,
            error_body("unknown_session", &format!("no session {id}")),
        )),
    }
}

/// A `Lost` op means the engine no longer knows the session (engine
/// restart, or shutdown behind the front end): evict the stale handle
/// so later requests get a clean 404 instead of piling 503s.
fn evict_if_lost(state: &HttpState, id: u64, e: &ServeError) {
    if *e == ServeError::Lost {
        lock(&state.sessions).remove(&id);
    }
}

fn push_frames(state: &HttpState, id_str: &str, req: &HttpRequest) -> Resp {
    let (id, sess) = match session_handle(state, id_str) {
        Ok(s) => s,
        Err(r) => return r,
    };
    let body = match parse_json_body(req) {
        Ok(j) => j,
        Err(r) => return r,
    };
    let values = match f32_field(&body, "values") {
        Ok(v) => v,
        Err(r) => return r,
    };
    match sess.push_frames(values) {
        Ok(frames) => (
            200,
            JSON,
            Json::obj(vec![("frames", frames.into())]).to_string(),
        ),
        Err(e) => {
            evict_if_lost(state, id, &e);
            serve_failure(state, &e)
        }
    }
}

fn session_logits(state: &HttpState, id_str: &str) -> Resp {
    let (id, sess) = match session_handle(state, id_str) {
        Ok(s) => s,
        Err(r) => return r,
    };
    match sess.logits() {
        Ok(logits) => {
            let out = Json::obj(vec![
                ("argmax", argmax(&logits).into()),
                ("logits", f32s_to_json(&logits)),
            ]);
            (200, JSON, out.to_string())
        }
        Err(e) => {
            evict_if_lost(state, id, &e);
            serve_failure(state, &e)
        }
    }
}

fn close_session(state: &HttpState, id_str: &str) -> Resp {
    let id: u64 = match id_str.parse() {
        Ok(id) => id,
        Err(_) => {
            return (
                400,
                JSON,
                error_body(
                    "bad_request",
                    &format!("session id '{id_str}' is not an integer"),
                ),
            )
        }
    };
    // removed from the registry unconditionally: whatever close()
    // returns, this id no longer names a live session here
    let Some(sess) = lock(&state.sessions).remove(&id) else {
        return (
            404,
            JSON,
            error_body("unknown_session", &format!("no session {id}")),
        );
    };
    match sess.close() {
        Ok(label) => (
            200,
            JSON,
            Json::obj(vec![("label", label.into())]).to_string(),
        ),
        Err(e) => serve_failure(state, &e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_errors_map_per_the_spec() {
        assert_eq!(serve_status(&ServeError::Busy), (429, "busy"));
        assert_eq!(serve_status(&ServeError::Lost), (503, "lost"));
        assert_eq!(
            serve_status(&ServeError::BackendPanicked("x".into())).0,
            503
        );
    }

    #[test]
    fn error_bodies_are_valid_json() {
        let j = Json::parse(&error_body("busy", "all slots leased")).unwrap();
        assert_eq!(j.req_str("error").unwrap(), "busy");
        assert_eq!(j.req_str("message").unwrap(), "all slots leased");
    }

    #[test]
    fn metrics_render_exposes_every_family() {
        let mut m = HttpMetrics {
            connections: 3,
            protocol_errors: 1,
            ..Default::default()
        };
        *m.by_status.entry(200).or_insert(0) += 4;
        *m.by_status.entry(429).or_insert(0) += 2;
        m.recorder.record(Duration::from_micros(120));
        m.recorder.record_error(&ServeError::Busy);
        m.recorder.delta.components_fired = 11;
        m.recorder.delta.components_skipped = 9;
        m.recorder.delta.shares_skipped = 2;
        m.recorder.energy.cap_charge(1e-15, 0.0, 0.5);
        m.recorder.energy.toggles_cached(7, 1e-16);
        m.recorder.energy.adc_conversion();
        m.recorder.energy.steps = 13;
        let text = m.render(5);
        assert!(text.contains("minimalist_http_connections_total 3"), "{text}");
        assert!(text.contains("minimalist_http_requests_total 6"), "{text}");
        assert!(
            text.contains("minimalist_http_protocol_errors_total 1"),
            "{text}"
        );
        assert!(text.contains("minimalist_http_sessions_live 5"), "{text}");
        assert!(
            text.contains("minimalist_http_responses_total{status=\"429\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_serve_errors_total{kind=\"busy\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("request_latency_us{quantile=\"0.5\"}"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_delta_components_fired_total 11"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_delta_components_skipped_total 9"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_delta_shares_skipped_total 2"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_energy_cap_events_total 1"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_energy_switch_toggles_total 7"),
            "{text}"
        );
        assert!(
            text.contains("minimalist_energy_adc_conversions_total 1"),
            "{text}"
        );
        assert!(text.contains("minimalist_energy_steps_total 13"), "{text}");
        assert!(text.contains("minimalist_energy_joules_total "), "{text}");
        assert!(text.contains("minimalist_energy_joules_per_step "), "{text}");
        assert!(m.summary().contains("requests=6"));
    }
}
