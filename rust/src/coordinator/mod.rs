//! The L3 coordinator: serving infrastructure around the mixed-signal
//! cores and the PJRT reference model.
//!
//! * [`engine`] — network-on-cores: the trained model mapped onto
//!   switched-capacitor cores with the event fabric in between
//! * [`backends`] — pluggable classification backends (golden /
//!   mixed-signal / PJRT) plus per-worker factories for sharding
//! * [`batcher`] — dynamic batching policy
//! * [`server`] — sharded serving engine: a leader thread batches
//!   requests and feeds a work queue consumed by N worker threads, each
//!   owning one backend instance (constructed on-thread; PJRT handles
//!   are not `Send`)
//! * [`metrics`] — latency/throughput accounting (per-worker recorders,
//!   merged into the aggregate at shutdown)

pub mod backends;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use backends::{GoldenBackend, MixedSignalBackend, PjrtBackend};
pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::MixedSignalEngine;
pub use metrics::LatencyRecorder;
pub use server::{Backend, Client, Response, ServeError, Server};
