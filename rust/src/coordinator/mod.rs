//! The L3 coordinator: serving infrastructure around the mixed-signal
//! cores and the PJRT reference model — from the network socket all
//! the way down to an engine slot.
//!
//! ## The full request path
//!
//! ```text
//!   TCP socket                      [`http`]   accept + connection threads
//!     → HTTP/1.1 parse        [`crate::util::http`]   bounded subset, JSON bodies
//!       → route               [`http`]   /v1/classify, /v1/session/…
//!         → leader thread     [`server`]   batches (one-shot) / routes by
//!                                          session affinity (streaming)
//!           → worker thread   [`server`]   owns one backend instance,
//!                                          constructed on-thread
//!             → engine slot   [`engine`]   lockstep step over the
//!                                          switched-capacitor cores
//! ```
//!
//! A one-shot `POST /v1/classify` becomes a [`batcher::Request`] on the
//! [`server::Server`] leader's queue; the leader batches by the
//! [`batcher::BatchPolicy`] and a worker classifies the batch on its
//! backend. A streaming session (`POST /v1/session`, then `frames`/
//! `logits`/`DELETE` by id) leases a resident slot in one worker's
//! backend for its whole lifetime — worker affinity, docs/adr/003 —
//! and the HTTP layer parks the [`server::StreamSession`] handle in a
//! registry so any connection can address it by id. Admission is
//! reject-not-queue at both layers: slot exhaustion surfaces as
//! [`server::ServeError::Busy`] in-process and 429 on the wire.
//!
//! ## Modules
//!
//! * [`http`] — the wire front end: listener, connection threads,
//!   routing, `/healthz` + `/metrics`, graceful drain (docs/adr/004;
//!   wire contract in docs/http-api.md)
//! * [`loadgen`] — closed-loop wire load generator (the `minimalist
//!   loadgen` CLI and the bench suite's `http_sweep` axis)
//! * [`server`] — the two in-process serving modes: [`server::Server`],
//!   a sharded batch engine (a leader thread batches requests and feeds
//!   a work queue consumed by N worker threads, each owning one backend
//!   instance — constructed on-thread; PJRT handles are not `Send`),
//!   and [`server::StreamServer`], streaming stateful sessions with
//!   worker affinity (each session's slot lives in one worker's
//!   backend; see docs/adr/003)
//! * [`batcher`] — dynamic batching policy for one-shot requests, and
//!   the per-session frame assembly ([`batcher::SessionQueue`]) of the
//!   streaming path
//! * [`backends`] — pluggable classification backends (golden /
//!   mixed-signal / PJRT) plus per-worker factories for sharding, and
//!   the streaming-session implementations over the golden nets and the
//!   engine's slot pool
//! * [`engine`] — network-on-cores: the trained model mapped onto
//!   switched-capacitor cores with the event fabric in between
//! * [`metrics`] — latency/throughput accounting (per-worker recorders,
//!   merged into the aggregate at shutdown; per-variant error counts),
//!   shared by the in-process servers and the HTTP layer

pub mod backends;
pub mod batcher;
pub mod engine;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod server;

pub use backends::{GoldenBackend, MixedSignalBackend, PjrtBackend};
pub use batcher::{BatchPolicy, Batcher, Request, SessionQueue};
pub use engine::MixedSignalEngine;
pub use http::{status_for, HttpConfig, HttpMetrics, HttpServer};
pub use metrics::LatencyRecorder;
pub use server::{
    Backend, Client, Response, ServeError, Server, SessionBackend,
    SessionRequest, SessionResponse, StreamClient, StreamServer, StreamSession,
};
