//! The L3 coordinator: serving infrastructure around the mixed-signal
//! cores and the PJRT reference model.
//!
//! * [`engine`] — network-on-cores: the trained model mapped onto
//!   switched-capacitor cores with the event fabric in between
//! * [`backends`] — pluggable classification backends (golden /
//!   mixed-signal / PJRT) plus per-worker factories for sharding, and
//!   the streaming-session implementations over the golden nets and the
//!   engine's slot pool
//! * [`batcher`] — dynamic batching policy for one-shot requests, and
//!   the per-session frame assembly ([`batcher::SessionQueue`]) of the
//!   streaming path
//! * [`server`] — the two serving modes: [`server::Server`], a sharded
//!   batch engine (a leader thread batches requests and feeds a work
//!   queue consumed by N worker threads, each owning one backend
//!   instance — constructed on-thread; PJRT handles are not `Send`),
//!   and [`server::StreamServer`], streaming stateful sessions with
//!   worker affinity (each session's slot lives in one worker's
//!   backend; see docs/adr/003)
//! * [`metrics`] — latency/throughput accounting (per-worker recorders,
//!   merged into the aggregate at shutdown; per-variant error counts)

pub mod backends;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use backends::{GoldenBackend, MixedSignalBackend, PjrtBackend};
pub use batcher::{BatchPolicy, Batcher, Request, SessionQueue};
pub use engine::MixedSignalEngine;
pub use metrics::LatencyRecorder;
pub use server::{
    Backend, Client, Response, ServeError, Server, SessionBackend,
    SessionRequest, SessionResponse, StreamClient, StreamServer, StreamSession,
};
