//! Closed-loop wire load generator for the HTTP front end
//! ([`crate::coordinator::http`]): N concurrent keep-alive
//! connections, each driving frame-paced streaming sessions end to end
//! — open, push frames in chunks, poll the running logits once
//! mid-sequence, close for the label — strictly in series per
//! connection (the response ack is the pacer, so offered load adapts
//! to what the server sustains instead of overrunning it).
//!
//! `Busy` rejections (429) are the admission control working as
//! specified (docs/adr/003): they are counted and retried after a
//! short backoff, not treated as failures. What *is* a failure:
//! unexpected statuses or malformed responses (`protocol_errors` —
//! the CI smoke gate asserts zero) and connect/IO breakage
//! (`transport_errors`, retried once per session by reconnecting).
//!
//! Used three ways: `minimalist loadgen` (CLI), the `http_sweep` axis
//! of [`crate::bench_suite`] (wire vs in-process), and the e2e test in
//! tests/http_api.rs.

use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyRecorder;
use crate::util::http::HttpClient;
use crate::util::json::Json;

/// Load shape. `connections × sessions_per_conn` completed sessions is
/// the run target; every session pushes `frames` frames of
/// `frame_width` values in chunks of `frames_per_push`.
#[derive(Debug, Clone)]
pub struct LoadGenOpts {
    /// Concurrent keep-alive connections.
    pub connections: usize,
    /// Sessions each connection drives, in series.
    pub sessions_per_conn: usize,
    /// Frames pushed per session.
    pub frames: usize,
    /// Frames per push request (chunk size).
    pub frames_per_push: usize,
    /// Values per frame — the serving network's input width.
    pub frame_width: usize,
    /// Poll `GET .../logits` once per session at the halfway point.
    pub poll_logits: bool,
}

impl Default for LoadGenOpts {
    /// The full run: hundreds of concurrent connections — the
    /// "sessions/s under load" measurement.
    fn default() -> Self {
        LoadGenOpts {
            connections: 200,
            sessions_per_conn: 8,
            frames: 64,
            frames_per_push: 8,
            frame_width: 1,
            poll_logits: true,
        }
    }
}

impl LoadGenOpts {
    /// CI smoke scale (`loadgen --quick`).
    pub fn quick() -> LoadGenOpts {
        LoadGenOpts {
            connections: 8,
            sessions_per_conn: 4,
            frames: 16,
            frames_per_push: 4,
            ..LoadGenOpts::default()
        }
    }
}

/// Aggregated outcome of a run (per-connection reports merged).
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Sessions driven open→close successfully.
    pub sessions_completed: u64,
    /// Complete frames accepted by the server.
    pub frames_pushed: u64,
    /// 429s observed (admission control, retried — not failures).
    pub busy_rejected: u64,
    /// Unexpected status or malformed response — the smoke-gate zero.
    pub protocol_errors: u64,
    /// Connect/IO failures (reconnected once per session).
    pub transport_errors: u64,
    /// Wall-clock duration of the whole run.
    pub wall: Duration,
    /// Per-push wire latency (the frame-chunk roundtrip).
    pub push: LatencyRecorder,
    /// Whole-session latency (open → close).
    pub session: LatencyRecorder,
}

impl LoadReport {
    fn merge(&mut self, other: &LoadReport) {
        self.sessions_completed += other.sessions_completed;
        self.frames_pushed += other.frames_pushed;
        self.busy_rejected += other.busy_rejected;
        self.protocol_errors += other.protocol_errors;
        self.transport_errors += other.transport_errors;
        self.push.merge(&other.push);
        self.session.merge(&other.session);
    }

    /// Completed sessions per wall-clock second.
    pub fn sessions_per_s(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.sessions_completed as f64 / s
        }
    }

    /// Pushed frames per wall-clock second.
    pub fn frames_per_s(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.frames_pushed as f64 / s
        }
    }

    /// The machine-readable artifact (`loadgen --out`): schema 4, the
    /// same quantities as the `http_sweep` rows of the bench suite.
    pub fn to_json(&self, target: &str, opts: &LoadGenOpts) -> Json {
        let pcts = self.push.percentiles(&[50.0, 95.0, 99.0]);
        Json::obj(vec![
            ("bench", "loadgen".into()),
            ("schema", 4usize.into()),
            ("status", "measured".into()),
            ("target", target.into()),
            ("connections", opts.connections.into()),
            ("sessions_per_conn", opts.sessions_per_conn.into()),
            ("frames_per_session", opts.frames.into()),
            ("frames_per_push", opts.frames_per_push.into()),
            ("sessions_completed", (self.sessions_completed as f64).into()),
            ("frames_pushed", (self.frames_pushed as f64).into()),
            ("busy_rejected", (self.busy_rejected as f64).into()),
            ("protocol_errors", (self.protocol_errors as f64).into()),
            ("transport_errors", (self.transport_errors as f64).into()),
            ("wall_s", self.wall.as_secs_f64().into()),
            ("sessions_per_s", self.sessions_per_s().into()),
            ("frames_per_s", self.frames_per_s().into()),
            ("push_p50_us", (pcts[0].as_micros() as f64).into()),
            ("push_p95_us", (pcts[1].as_micros() as f64).into()),
            ("push_p99_us", (pcts[2].as_micros() as f64).into()),
        ])
    }

    /// One-line human summary of the run.
    pub fn summary(&self) -> String {
        let pcts = self.push.percentiles(&[50.0, 95.0, 99.0]);
        format!(
            "sessions={} ({:.1}/s) frames={} ({:.0}/s) busy={} \
             protocol_err={} transport_err={} push p50={:?} p95={:?} p99={:?}",
            self.sessions_completed,
            self.sessions_per_s(),
            self.frames_pushed,
            self.frames_per_s(),
            self.busy_rejected,
            self.protocol_errors,
            self.transport_errors,
            pcts[0],
            pcts[1],
            pcts[2],
        )
    }
}

enum Outcome {
    Done,
    Busy,
    /// Response violated the spec — counted, session abandoned, the
    /// connection itself stays in sync (the full response was read).
    Protocol,
    /// The connection broke — reconnect and move on.
    Transport,
}

/// Run the full load against `target` (`host:port`); blocks until
/// every connection finishes its sessions (or exhausts its retry
/// budget against a saturated server).
pub fn run(target: &str, opts: &LoadGenOpts) -> LoadReport {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..opts.connections.max(1))
        .map(|c| {
            let target = target.to_string();
            let opts = opts.clone();
            thread::Builder::new()
                .name(format!("minimalist-loadgen-{c}"))
                .spawn(move || conn_loop(&target, &opts, c))
                // lint: allow(panic, load generator is a CLI driver: failing to spawn its own connections is fatal by design)
                .expect("spawning loadgen connection thread")
        })
        .collect();
    let mut total = LoadReport::default();
    for h in handles {
        if let Ok(rep) = h.join() {
            total.merge(&rep);
        } else {
            total.protocol_errors += 1;
        }
    }
    total.wall = t0.elapsed();
    total
}

fn conn_loop(target: &str, opts: &LoadGenOpts, salt: usize) -> LoadReport {
    let mut rep = LoadReport::default();
    let Ok(mut client) = HttpClient::connect(target) else {
        rep.transport_errors += 1;
        return rep;
    };
    // a saturated server answers 429 — retry with backoff, but bounded
    // so a misconfigured target cannot hang the run forever
    let budget = opts.sessions_per_conn * 50;
    let mut attempts = 0usize;
    while rep.sessions_completed < opts.sessions_per_conn as u64
        && attempts < budget
    {
        attempts += 1;
        match drive_session(&mut client, opts, &mut rep, salt + attempts) {
            Outcome::Done => {}
            Outcome::Busy => {
                rep.busy_rejected += 1;
                thread::sleep(Duration::from_millis(1));
            }
            Outcome::Protocol => {}
            Outcome::Transport => {
                rep.transport_errors += 1;
                match HttpClient::connect(target) {
                    Ok(c) => client = c,
                    Err(_) => break,
                }
            }
        }
    }
    rep
}

/// One full session on one connection. Frame values are synthesized
/// deterministically from `salt` so distinct sessions exercise
/// distinct sequences.
fn drive_session(
    client: &mut HttpClient,
    opts: &LoadGenOpts,
    rep: &mut LoadReport,
    salt: usize,
) -> Outcome {
    let t_open = Instant::now();
    let Ok(resp) = client.request("POST", "/v1/session", None) else {
        return Outcome::Transport;
    };
    if resp.status == 429 {
        return Outcome::Busy;
    }
    if resp.status != 201 {
        rep.protocol_errors += 1;
        return Outcome::Protocol;
    }
    let Some(id) = resp
        .json()
        .ok()
        .and_then(|j| j.req_f64("session").ok())
        .map(|x| x as u64)
    else {
        rep.protocol_errors += 1;
        return Outcome::Protocol;
    };
    let frames_path = format!("/v1/session/{id}/frames");
    let mut pushed = 0usize;
    let mut polled = false;
    while pushed < opts.frames {
        let n = opts.frames_per_push.min(opts.frames - pushed);
        let values: Vec<Json> = (0..n * opts.frame_width)
            .map(|i| {
                Json::Num(((salt * 31 + pushed + i) % 17) as f64 / 16.0)
            })
            .collect();
        let body = Json::obj(vec![("values", Json::Arr(values))]);
        let t = Instant::now();
        let Ok(resp) = client.request("POST", &frames_path, Some(&body))
        else {
            return Outcome::Transport;
        };
        if resp.status != 200 {
            rep.protocol_errors += 1;
            return Outcome::Protocol;
        }
        rep.push.record(t.elapsed());
        rep.frames_pushed += n as u64;
        pushed += n;
        if opts.poll_logits && !polled && pushed * 2 >= opts.frames {
            let path = format!("/v1/session/{id}/logits");
            let Ok(resp) = client.request("GET", &path, None) else {
                return Outcome::Transport;
            };
            if resp.status != 200 {
                rep.protocol_errors += 1;
                return Outcome::Protocol;
            }
            polled = true;
        }
    }
    let path = format!("/v1/session/{id}");
    let Ok(resp) = client.request("DELETE", &path, None) else {
        return Outcome::Transport;
    };
    if resp.status != 200 || resp.json().and_then(|j| j.req_f64("label")).is_err()
    {
        rep.protocol_errors += 1;
        return Outcome::Protocol;
    }
    rep.sessions_completed += 1;
    rep.session.record(t_open.elapsed());
    Outcome::Done
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merges_and_rates() {
        let mut a = LoadReport {
            sessions_completed: 4,
            frames_pushed: 64,
            busy_rejected: 1,
            ..Default::default()
        };
        let b = LoadReport {
            sessions_completed: 6,
            frames_pushed: 96,
            protocol_errors: 2,
            ..Default::default()
        };
        a.merge(&b);
        a.wall = Duration::from_secs(2);
        assert_eq!(a.sessions_completed, 10);
        assert_eq!(a.frames_pushed, 160);
        assert_eq!(a.busy_rejected, 1);
        assert_eq!(a.protocol_errors, 2);
        assert_eq!(a.sessions_per_s(), 5.0);
        assert_eq!(a.frames_per_s(), 80.0);
        let j = a.to_json("127.0.0.1:0", &LoadGenOpts::quick());
        assert_eq!(j.req_f64("schema").unwrap() as u64, 4);
        assert_eq!(j.req_f64("sessions_completed").unwrap(), 10.0);
        assert_eq!(j.req_f64("protocol_errors").unwrap(), 2.0);
        assert!(a.summary().contains("sessions=10"));
    }

    #[test]
    fn quick_opts_are_smoke_scale() {
        let q = LoadGenOpts::quick();
        assert!(q.connections <= 16 && q.frames <= 32);
        assert!(LoadGenOpts::default().connections >= 100);
    }
}
