//! The mixed-signal inference engine: a trained network mapped onto
//! switched-capacitor cores, stepped through full sequences with the
//! event fabric in between — the rust equivalent of the paper's
//! "mixed-signal simulation set up with equivalent weights and biases"
//! (Fig 4), and the physical backend of the serving coordinator.
//!
//! The engine *executes* a [`Plan`] (see [`crate::mapping`]): layers
//! wider than a core are column-split across tiles, layers with more
//! inputs than core rows are row-split — each row tile computes a
//! partial IMC charge share over its row slice, the partials are
//! combined as the row-count-weighted average
//! `(n₁·v₁ + n₂·v₂)/(n₁+n₂)` (the shorted-column-line semantics of
//! `imc_matmul`'s 1/N normalization), and the gate digitization plus
//! capacitor-swap state update run on the owner tile. Arbitrary network
//! shapes are therefore servable on the physics path.
//!
//! Serving throughput comes from **lockstep batching**: the cores hold
//! multi-slot analog state (one slot per concurrent sequence), and
//! `step_batch` advances all B sequences of a uniform-shape batch
//! through a single plan traversal per time step — per-core
//! weight/placement state is touched once per layer instead of once per
//! sequence (the amortization EdgeDRNN and Chipmunk build RNN
//! accelerators around). Slot RNG streams all clone the construction
//! stream, so batched results are bit-identical to sequential ones
//! (docs/adr/001 and 002 record both decisions).
//!
//! The same slot substrate carries **streaming sessions** (docs/adr/003):
//! [`MixedSignalEngine::provision_sessions`] builds a free pool of
//! resident slots, [`MixedSignalEngine::lease_slot`] pins a new
//! session's analog state (capacitor voltages, swap configuration, RNG
//! stream position) to one of them, and [`MixedSignalEngine::step_slots`]
//! advances any subset of live sessions — each on its own local clock —
//! through one lockstep traversal per tick. A streamed sequence is
//! bit-identical to a one-shot [`MixedSignalEngine::classify`] of the
//! same frames.

use anyhow::Result;

use crate::config::{CircuitConfig, CoreGeometry, MappingConfig};
use crate::energy::EnergyMeter;
use crate::mapping::Plan;
use crate::nn::mingru::{argmax, READOUT_STEPS};
use crate::nn::weights::NetworkWeights;
use crate::quant::codesign::{map_layer_with, volts_to_logical, LayerCircuit};
use crate::router::fabric::Fabric;
use crate::satsim::{ColumnConfig, Core, CoreStep, DeltaCounters};
use crate::util::pool::ScopedPool;

/// Lifetime-erased `*mut T` the threaded traversal hands to pool tasks.
/// Tasks index **disjoint** elements (one core / one staging buffer per
/// tile), so no two tasks materialize overlapping `&mut` — the wrapper
/// only exists because a raw pointer is not `Send`/`Sync` by itself.
struct SendPtrMut<T>(*mut T);

// SAFETY: tasks created by `ScopedPool::run` only dereference disjoint
// indices (each tile owns its core and staging slot), and the pool
// joins before the pointee's borrow ends in the caller.
unsafe impl<T> Send for SendPtrMut<T> {}
// SAFETY: as above — shared access to the wrapper never creates
// overlapping mutable references to the pointee.
unsafe impl<T> Sync for SendPtrMut<T> {}

impl<T> SendPtrMut<T> {
    /// Pointer to element `i` of the wrapped base pointer.
    ///
    /// # Safety
    /// `i` must be in bounds of the allocation the base pointer was
    /// taken from, and no other live reference may overlap element `i`.
    // SAFETY: caller upholds the `# Safety` contract above.
    unsafe fn at(&self, i: usize) -> *mut T {
        // SAFETY: bounds and aliasing are the caller's contract above.
        unsafe { self.0.add(i) }
    }
}

/// Per-sequence observables of one layer (logical units — directly
/// comparable to the golden model and to the python traces).
#[derive(Debug, Clone, Default)]
pub struct LayerTraceSeq {
    /// Gate values per step.
    pub z: Vec<Vec<f32>>,
    /// Candidate states per step.
    pub htilde: Vec<Vec<f32>>,
    /// Hidden states per step.
    pub h: Vec<Vec<f32>>,
    /// Readout/event outputs per step.
    pub y: Vec<Vec<f32>>,
}

/// A network instantiated on physical cores.
///
/// Holds `batch` lockstep slots of per-sequence state (slot 0 is the
/// sequential path): one event fabric, one readout ring, and one
/// inter-layer frame buffer per slot, on top of the cores' per-slot
/// analog state. `step` advances slot 0; `step_batch` advances every
/// slot of a uniform-shape batch through a single plan traversal.
pub struct MixedSignalEngine {
    /// The trained network this engine executes.
    pub weights: NetworkWeights,
    /// Circuit/noise configuration shared by every core.
    pub circuit: CircuitConfig,
    /// The layer→core placement this engine executes (also the source
    /// of truth for the core geometry).
    pub plan: Plan,
    /// Physical cores, in plan order.
    pub cores: Vec<Core>,
    /// Codesign diagnostics per layer.
    pub layer_circuits: Vec<LayerCircuit>,
    /// lockstep batch slots currently provisioned (≥ 1)
    batch: usize,
    /// per-slot event fabrics
    fabrics: Vec<Fabric>,
    /// per-slot readout rings (analog head states, logical units)
    rings: Vec<Vec<Vec<f32>>>,
    /// per-slot readout ring cursor (all cursors advance together under
    /// `step_batch`; streaming slots advance on their own ticks)
    ring_pos: Vec<usize>,
    /// per-slot time steps since that slot's last reset (readout
    /// normalization, and the local clock of a streaming session)
    steps_seen: Vec<usize>,
    /// per-slot input / inter-layer frame buffers
    x_bufs: Vec<Vec<f64>>,
    /// per-slot scratch: the logical frame tiled `replication` times
    /// (the physical input of a row-replicated layer)
    x_reps: Vec<Vec<f64>>,
    /// per-layer output scratch, reused across steps (the steady-state
    /// step makes zero heap allocations — see tests/hot_path_alloc.rs);
    /// the sequential/tracing path uses the singular buffers, the
    /// batched path the per-slot `_b` ones
    events: Vec<bool>,
    h_states: Vec<f32>,
    z_vals: Vec<f32>,
    ht_vals: Vec<f32>,
    events_b: Vec<Vec<bool>>,
    h_states_b: Vec<Vec<f32>>,
    /// row-split scratch, per slot: weighted partial sums, divided in
    /// place into the combined (row-count-weighted mean) node voltages
    accs: Vec<Vec<(f64, f64)>>,
    /// packed per-step input scratch for `classify_batch`
    batch_x: Vec<f32>,
    /// scratch slot-id list `step_batch` lends to the shared traversal
    /// (kept as `0..batch` so the batched step allocates nothing)
    slot_ids: Vec<usize>,
    /// whether the batch slots currently hold per-slot Monte-Carlo
    /// device instances (ADR-008) instead of the default clones of the
    /// construction device — set by
    /// [`MixedSignalEngine::provision_devices`], cleared by
    /// [`MixedSignalEngine::dissolve_devices`]
    per_slot_devices: bool,
    /// free-slot pool of the streaming-session mode (LIFO); empty in
    /// batch mode — see [`MixedSignalEngine::provision_sessions`]
    free_slots: Vec<usize>,
    /// per-slot lease flags of the streaming-session mode
    leased: Vec<bool>,
    /// reusable per-core observable buffer
    core_out: CoreStep,
    /// lanes of the threaded plan traversal (≥ 1; 1 = the serial path)
    engine_threads: usize,
    /// fork-join pool behind the threaded traversal (ADR-007); `Some`
    /// exactly when `engine_threads > 1`
    pool: Option<ScopedPool>,
    /// per-core `CoreStep` scratch of the threaded unsplit fan-out
    /// (tasks may not share the serial path's single `core_out`)
    core_outs: Vec<CoreStep>,
    /// per-core `(event, h)` output staging of the threaded unsplit
    /// fan-out, spliced into the per-slot buffers in core order
    tile_out: Vec<Vec<(bool, f32)>>,
    /// per-core partial-share staging of the threaded row-split fan-out
    tile_partials: Vec<Vec<(f64, f64)>>,
}

impl MixedSignalEngine {
    /// Plan the network onto cores of `geometry` with the default
    /// planner knobs and instantiate it. Any layer shape maps: narrow
    /// layers row-replicate, wide layers column-split, tall layers
    /// row-split with weighted partial-sum combination.
    pub fn new(
        weights: NetworkWeights,
        circuit: CircuitConfig,
        geometry: CoreGeometry,
    ) -> Result<MixedSignalEngine> {
        let plan = Plan::build(&weights.dims, &MappingConfig::with_geometry(geometry))?;
        MixedSignalEngine::from_plan(weights, circuit, plan)
    }

    /// Instantiate cores for an explicit (already built) placement plan.
    pub fn from_plan(
        weights: NetworkWeights,
        circuit: CircuitConfig,
        plan: Plan,
    ) -> Result<MixedSignalEngine> {
        plan.validate()?;
        plan.check_network(&weights)?;
        let geometry = plan.geometry;
        let mut cores = Vec::with_capacity(plan.n_cores);
        let mut layer_circuits = Vec::with_capacity(weights.n_layers());
        for (l, lw) in weights.layers.iter().enumerate() {
            let lp = &plan.layers[l];
            let lc = map_layer_with(lw, &circuit, lp.replication, lp.owner_rows_phys())?;
            for (ti, tile) in lp.tiles.iter().enumerate() {
                let col_cfgs: Vec<ColumnConfig> = (tile.cols.0..tile.cols.1)
                    .map(|j| {
                        let full = &lc.columns[j];
                        if lp.row_tiles == 1 {
                            // the whole (possibly replicated) column
                            full.clone()
                        } else {
                            // this tile's row slice; slope_m only
                            // matters on the owner tile, clamp it to
                            // the slice so every tile constructs
                            let (r0, r1) = tile.rows;
                            ColumnConfig {
                                w_h: full.w_h[r0..r1].to_vec(),
                                w_z: full.w_z[r0..r1].to_vec(),
                                slope_m: full.slope_m.min(r1 - r0),
                                offset_code: full.offset_code,
                                v_theta: full.v_theta,
                            }
                        }
                    })
                    .collect();
                cores.push(Core::new(
                    geometry,
                    col_cfgs,
                    &circuit,
                    (l as u64) << 16 | ti as u64,
                ));
            }
            layer_circuits.push(lc);
        }
        debug_assert_eq!(cores.len(), plan.n_cores);
        let widths: Vec<usize> = weights.layers.iter().map(|l| l.n_out).collect();
        let head = *weights.dims.last().unwrap();
        let max_dim = *weights.dims.iter().max().unwrap();
        Ok(MixedSignalEngine {
            batch: 1,
            fabrics: vec![Fabric::new(&widths)],
            rings: vec![vec![vec![0.0; head]; READOUT_STEPS]],
            ring_pos: vec![0],
            steps_seen: vec![0],
            x_bufs: vec![vec![0.0; max_dim]],
            // a replicated frame never exceeds the physical rows
            x_reps: vec![Vec::with_capacity(geometry.rows)],
            events: Vec::with_capacity(max_dim),
            h_states: Vec::with_capacity(max_dim),
            z_vals: Vec::with_capacity(max_dim),
            ht_vals: Vec::with_capacity(max_dim),
            events_b: vec![Vec::with_capacity(max_dim)],
            h_states_b: vec![Vec::with_capacity(max_dim)],
            // a column group is at most one core wide
            accs: vec![Vec::with_capacity(geometry.cols)],
            batch_x: vec![0.0; weights.dims[0]],
            slot_ids: vec![0],
            per_slot_devices: false,
            free_slots: Vec::new(),
            leased: vec![false],
            core_out: CoreStep::default(),
            engine_threads: 1,
            pool: None,
            core_outs: Vec::new(),
            tile_out: Vec::new(),
            tile_partials: Vec::new(),
            weights,
            circuit,
            plan,
            cores,
            layer_circuits,
        })
    }

    /// The physical core geometry every tile of the plan uses.
    pub fn geometry(&self) -> CoreGeometry {
        self.plan.geometry
    }

    /// Build an independent engine with the same network, circuit and
    /// plan — each serving worker owns one (a physical core bank holds
    /// one sequence's state, so engines are never shared).
    pub fn replicate(&self) -> Result<MixedSignalEngine> {
        let mut e = MixedSignalEngine::from_plan(
            self.weights.clone(),
            self.circuit.clone(),
            self.plan.clone(),
        )?;
        e.set_engine_threads(self.engine_threads);
        Ok(e)
    }

    /// Lanes the lockstep traversal (`step_batch` / `step_slots`) runs
    /// on. 1 is the serial path; above 1 the independent cores of each
    /// layer fan out across a resident [`ScopedPool`] (ADR-007).
    pub fn engine_threads(&self) -> usize {
        self.engine_threads
    }

    /// Set the traversal lane count (clamped to ≥ 1) and (re)provision
    /// the pool plus its staging scratch. An engine boundary like
    /// `reset_batch`, never part of the steady-state step — results are
    /// bit-identical at every thread count (tests/parallel_parity.rs),
    /// so this is purely a throughput knob.
    pub fn set_engine_threads(&mut self, threads: usize) {
        let t = threads.max(1);
        if t != self.engine_threads {
            self.engine_threads = t;
            self.pool = if t > 1 { Some(ScopedPool::new(t)) } else { None };
        }
        self.provision_pool_scratch();
    }

    /// Size the threaded traversal's per-core staging buffers for the
    /// current batch. Runs at thread/batch boundaries so the threaded
    /// step itself stays allocation-free (tests/hot_path_alloc.rs).
    fn provision_pool_scratch(&mut self) {
        if self.pool.is_none() {
            self.core_outs.clear();
            self.tile_out.clear();
            self.tile_partials.clear();
            return;
        }
        let n = self.cores.len();
        let slot_cap = self.batch * self.plan.geometry.cols;
        self.core_outs.resize_with(n, CoreStep::default);
        self.tile_out.resize_with(n, Vec::new);
        self.tile_partials.resize_with(n, Vec::new);
        for v in self.tile_out.iter_mut() {
            v.clear();
            v.reserve(slot_cap);
        }
        for v in self.tile_partials.iter_mut() {
            v.clear();
            v.reserve(slot_cap);
        }
    }

    /// Number of physical cores in the plan.
    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Lockstep batch slots currently provisioned on the cores.
    pub fn batch_slots(&self) -> usize {
        self.batch
    }

    /// Reset every provisioned slot (sequence boundary): core states,
    /// per-slot noise streams, fabrics, and readout rings. A global
    /// boundary — it would clobber live streaming sessions, so it
    /// refuses to run while any slot is leased.
    pub fn reset(&mut self) {
        assert_eq!(
            self.live_sessions(),
            0,
            "reset would clobber live streaming sessions — close them first"
        );
        for c in self.cores.iter_mut() {
            c.reset(&self.circuit);
        }
        for f in self.fabrics.iter_mut() {
            f.reset();
        }
        for ring in self.rings.iter_mut() {
            for r in ring.iter_mut() {
                r.fill(0.0);
            }
        }
        self.ring_pos.fill(0);
        self.steps_seen.fill(0);
    }

    /// Provision `batch` lockstep slots (clamped to ≥ 1) and reset —
    /// the start of a batched classification. Allocation happens here,
    /// at batch boundaries, never inside the steady-state `step_batch`
    /// (see tests/hot_path_alloc.rs). Leaves the engine in batch mode:
    /// any streaming-session pool is dissolved, so this refuses to run
    /// while sessions are live.
    pub fn reset_batch(&mut self, batch: usize) {
        // check before the pool is dissolved below, or live leases
        // would be erased unnoticed
        assert_eq!(
            self.live_sessions(),
            0,
            "reset_batch would clobber live streaming sessions — close them first"
        );
        let b = batch.max(1);
        if b != self.batch {
            // `set_slots` silently dissolves per-slot Monte-Carlo
            // devices (the columns re-clone the construction hardware),
            // so a width change under an active sweep is always a bug —
            // the caller must `dissolve_devices` first (ADR-008)
            assert!(
                !self.per_slot_devices,
                "reset_batch({b}) would dissolve {} provisioned per-slot \
                 device instances — call dissolve_devices first",
                self.batch
            );
            for core in self.cores.iter_mut() {
                core.set_slots(b, &self.circuit);
            }
            let widths: Vec<usize> =
                self.weights.layers.iter().map(|l| l.n_out).collect();
            let head = *self.weights.dims.last().unwrap();
            let max_dim = *self.weights.dims.iter().max().unwrap();
            let rows = self.plan.geometry.rows;
            let cols = self.plan.geometry.cols;
            self.fabrics.resize_with(b, || Fabric::new(&widths));
            self.rings
                .resize_with(b, || vec![vec![0.0; head]; READOUT_STEPS]);
            self.ring_pos.resize(b, 0);
            self.steps_seen.resize(b, 0);
            self.x_bufs.resize_with(b, || vec![0.0; max_dim]);
            self.x_reps.resize_with(b, || Vec::with_capacity(rows));
            self.events_b.resize_with(b, || Vec::with_capacity(max_dim));
            self.h_states_b.resize_with(b, || Vec::with_capacity(max_dim));
            self.accs.resize_with(b, || Vec::with_capacity(cols));
            self.batch_x.resize(b * self.weights.dims[0], 0.0);
            self.slot_ids.clear();
            self.slot_ids.extend(0..b);
            self.batch = b;
            self.provision_pool_scratch();
        }
        // batch mode: no leasable slots until provision_sessions
        self.free_slots.clear();
        self.leased.clear();
        self.leased.resize(b, false);
        self.reset();
    }

    /// Provision one independent **device instance per batch slot**
    /// (ADR-008): slot `i` refabricates every column's capacitor banks
    /// and SAR ADC from the per-instance seed
    /// [`crate::montecarlo::instance_seed`]`(master_seed, i)`, exactly
    /// as a whole fresh engine built with `circuit.seed = seeds[i]`
    /// would draw them, and its noise stream restarts from the
    /// post-fabrication RNG of that fabrication. This is the explicit
    /// opt-out of the ADR-001 slot-clone convention that batched
    /// bit-parity rests on — the engine stays in this mode (surviving
    /// same-width `reset_batch`/`classify_batch` calls) until
    /// [`MixedSignalEngine::dissolve_devices`], and `reset_batch`
    /// refuses width changes while instances are provisioned.
    ///
    /// An engine boundary like `set_engine_threads`: fabrication
    /// allocates freely; the steady-state step afterwards swaps device
    /// state pointer-wise and stays allocation-free.
    pub fn provision_devices(&mut self, master_seed: u64, instances: usize) {
        if self.per_slot_devices {
            // re-provisioning with a different width must not trip the
            // reset_batch guard — the old instances are dissolved first
            self.dissolve_devices();
        }
        self.reset_batch(instances.max(1));
        let b = self.batch;
        let seeds: Vec<u64> = (0..b)
            .map(|i| crate::montecarlo::instance_seed(master_seed, i))
            .collect();
        for core in self.cores.iter_mut() {
            core.provision_slot_devices(&self.circuit, &seeds);
        }
        self.per_slot_devices = true;
        // restart every slot from its own instance's post-fabrication
        // stream root (Core::reset restores slot_rng0s, not rng0)
        self.reset();
    }

    /// Whether the batch slots currently hold per-slot Monte-Carlo
    /// device instances (ADR-008) rather than construction clones.
    pub fn per_slot_devices(&self) -> bool {
        self.per_slot_devices
    }

    /// Return every slot to the ADR-001 convention: construction
    /// hardware restored to the working fields, instance devices
    /// dropped, all slot streams re-rooted at the construction stream.
    /// A no-op if no instances are provisioned.
    pub fn dissolve_devices(&mut self) {
        if !self.per_slot_devices {
            return;
        }
        for core in self.cores.iter_mut() {
            core.dissolve_slot_devices();
        }
        self.per_slot_devices = false;
        self.reset();
    }

    /// Provision `capacity` resident **session slots** (clamped to ≥ 1)
    /// and build the free pool — the start of streaming-session mode.
    /// Sessions then lease slots with [`MixedSignalEngine::lease_slot`],
    /// advance leased slots (each on its own clock) with
    /// [`MixedSignalEngine::step_slots`], read partial-sequence logits
    /// with [`MixedSignalEngine::logits_slot`], and return slots with
    /// [`MixedSignalEngine::release_slot`]. Batch and session mode
    /// share the slot substrate but not a lifetime: `reset_batch` (and
    /// therefore `classify_batch`) dissolves the pool, and both refuse
    /// to run while sessions are live.
    pub fn provision_sessions(&mut self, capacity: usize) {
        let c = capacity.max(1);
        self.reset_batch(c);
        self.free_slots.clear();
        self.free_slots.extend((0..c).rev());
    }

    /// Number of slots currently leased to streaming sessions.
    pub fn live_sessions(&self) -> usize {
        self.leased.iter().filter(|&&l| l).count()
    }

    /// Total session slots provisioned (0 in batch mode).
    pub fn session_capacity(&self) -> usize {
        self.free_slots.len() + self.live_sessions()
    }

    /// Lease a free session slot: the slot is reset to sequence-boundary
    /// state (fresh analog state, the construction noise stream, cleared
    /// fabric and readout) and marked live. Returns `None` when every
    /// provisioned slot is leased — the caller's eviction policy (the
    /// serving layer rejects with `ServeError::Busy`) decides what
    /// happens then.
    pub fn lease_slot(&mut self) -> Option<usize> {
        let slot = self.free_slots.pop()?;
        self.leased[slot] = true;
        self.reset_slot(slot);
        Some(slot)
    }

    /// Return a leased slot to the free pool (session close). The
    /// slot's analog state is left as-is — the next lease resets it.
    pub fn release_slot(&mut self, slot: usize) {
        assert!(
            self.leased.get(slot).copied().unwrap_or(false),
            "release of slot {slot}, which is not leased"
        );
        self.leased[slot] = false;
        self.free_slots.push(slot);
    }

    /// Reset one slot alone to sequence-boundary state — core slot
    /// state, noise stream, fabric, readout ring, and local clock —
    /// without touching any other slot. A recycled slot is
    /// bit-indistinguishable from a fresh sequential engine
    /// (tests/stream_parity.rs).
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(
            slot < self.batch,
            "slot {slot} out of range ({} provisioned)",
            self.batch
        );
        for c in self.cores.iter_mut() {
            c.reset_slot(slot, &self.circuit);
        }
        self.fabrics[slot].reset();
        for r in self.rings[slot].iter_mut() {
            r.fill(0.0);
        }
        self.ring_pos[slot] = 0;
        self.steps_seen[slot] = 0;
    }

    /// Append layer `l`'s observables (gate codes, pre-activations,
    /// states, events) to the diagnostic trace buffers. Tracing is the
    /// cold path — it clones per-layer copies on every step and is
    /// deliberately outside the zero-alloc steady-state contract (and
    /// outside repolint's hot-path manifest).
    fn append_traces(&self, l: usize, ts: &mut Vec<LayerTraceSeq>) {
        if ts.len() <= l {
            ts.resize_with(l + 1, LayerTraceSeq::default);
        }
        ts[l].z.push(self.z_vals.clone());
        ts[l].htilde.push(self.ht_vals.clone());
        ts[l].h.push(self.h_states.clone());
        ts[l].y
            .push(self.events.iter().map(|&b| b as u8 as f32).collect());
    }

    /// One network time step on slot 0 (the sequential path). `x` =
    /// dims[0] input values (analog pixel for the paper workload). If
    /// `traces` is Some, logical-unit observables are appended per layer.
    ///
    /// The steady-state path is allocation- and clone-free: the circuit
    /// config is threaded by reference and all per-step scratch lives in
    /// engine/core-owned buffers (tracing, a diagnostic path, allocates
    /// for the copies it appends).
    pub fn step(&mut self, t: u32, x: &[f32],
                mut traces: Option<&mut Vec<LayerTraceSeq>>) {
        let n_layers = self.weights.n_layers();
        debug_assert_eq!(x.len(), self.weights.dims[0]);
        for (b, &v) in self.x_bufs[0].iter_mut().zip(x.iter()) {
            *b = v as f64;
        }
        let mut x_len = x.len();
        let want_traces = traces.is_some();
        for l in 0..n_layers {
            let wh_scale = self.weights.layers[l].wh_scale;
            self.events.clear();
            self.h_states.clear();
            self.z_vals.clear();
            self.ht_vals.clear();
            let lp = &self.plan.layers[l];
            if lp.row_tiles == 1 {
                // physical input: the logical frame tiled `replication`
                // times (row replication of narrow layers); unreplicated
                // layers drive straight from the frame buffer
                let r = lp.replication;
                if r > 1 {
                    let (x_rep, x_buf) = (&mut self.x_reps[0], &self.x_bufs[0]);
                    x_rep.clear();
                    for _ in 0..r {
                        // lint: allow(alloc, extend of a cleared scratch buffer sized for the widest layer at build)
                        x_rep.extend_from_slice(&x_buf[..x_len]);
                    }
                }
                let (c0, c1) = self.plan.core_range(l);
                for core in self.cores[c0..c1].iter_mut() {
                    let x_phys: &[f64] = if r > 1 {
                        &self.x_reps[0]
                    } else {
                        &self.x_bufs[0][..x_len]
                    };
                    core.step(x_phys, &self.circuit, &mut self.core_out);
                    push_outputs(
                        &self.core_out,
                        wh_scale,
                        &self.circuit,
                        want_traces,
                        &mut self.events,
                        &mut self.h_states,
                        &mut self.z_vals,
                        &mut self.ht_vals,
                    );
                }
            } else {
                // row-split layer: every row tile contributes a partial
                // charge share over its input slice; the partials are
                // combined as the row-count-weighted mean and the gate
                // update runs on the owner tile (row tile 0)
                let n_in_total = lp.n_in as f64;
                for ct in 0..lp.col_tiles {
                    let owner = lp.owner_tile(ct).core;
                    let width = lp.owner_tile(ct).n_cols();
                    self.accs[0].clear();
                    // lint: allow(alloc, resize of a retained-capacity accumulator; width never exceeds the widest tile)
                    self.accs[0].resize(width, (0.0, 0.0));
                    for rt in 0..lp.row_tiles {
                        let tile = lp.tile(rt, ct);
                        let (r0, r1) = tile.rows;
                        let weight = (r1 - r0) as f64;
                        let partials = self.cores[tile.core]
                            .step_partial(&self.x_bufs[0][r0..r1], &self.circuit);
                        debug_assert_eq!(partials.len(), width);
                        for (a, p) in self.accs[0].iter_mut().zip(partials.iter()) {
                            a.0 += weight * p.0;
                            a.1 += weight * p.1;
                        }
                        if rt != 0 {
                            self.cores[tile.core].finish_partial_only();
                        }
                    }
                    // divide in place: acc becomes the combined means
                    for a in self.accs[0].iter_mut() {
                        a.0 /= n_in_total;
                        a.1 /= n_in_total;
                    }
                    self.cores[owner].step_finish(
                        &self.accs[0],
                        &self.circuit,
                        &mut self.core_out,
                    );
                    push_outputs(
                        &self.core_out,
                        wh_scale,
                        &self.circuit,
                        want_traces,
                        &mut self.events,
                        &mut self.h_states,
                        &mut self.z_vals,
                        &mut self.ht_vals,
                    );
                }
            }
            if let Some(ts) = traces.as_deref_mut() {
                self.append_traces(l, ts);
            }
            if l == n_layers - 1 {
                // head readout: analog states into the ring
                self.rings[0][self.ring_pos[0]].copy_from_slice(&self.h_states);
                self.ring_pos[0] = (self.ring_pos[0] + 1) % READOUT_STEPS;
            } else {
                // route binary events to the next layer's row drivers
                self.fabrics[0].route(l, t, &self.events);
                let port = &self.fabrics[0].ports[l];
                for (b, &bit) in self.x_bufs[0].iter_mut().zip(port.frame.iter()) {
                    *b = bit as u8 as f64;
                }
                x_len = self.weights.layers[l].n_out;
            }
        }
        self.steps_seen[0] += 1;
    }

    /// One lockstep time step of every provisioned batch slot: all B
    /// sequences advance through a *single* traversal of the plan, so
    /// per-core weight/placement state is touched once per layer and
    /// amortized across the concurrent streams. `xs` is the packed
    /// slot-major input, `batch_slots() * dims[0]` values (slot `s`'s
    /// frame at `xs[s*d_in .. (s+1)*d_in]`).
    ///
    /// Slot `s` of a freshly reset batch is bit-identical to a fresh
    /// sequential run over the same sequence: every slot's noise stream
    /// is a clone of the core's construction stream, exactly what
    /// `reset` + `step` replays (see `Core::slot_rngs`).
    ///
    /// Like `step`, the steady-state path performs zero heap
    /// allocations after warmup (tests/hot_path_alloc.rs).
    pub fn step_batch(&mut self, t: u32, xs: &[f32]) {
        let b = self.batch;
        let d_in = self.weights.dims[0];
        assert_eq!(
            xs.len(),
            b * d_in,
            "step_batch wants {b} slot-major frames of {d_in} values"
        );
        // lend the 0..batch scratch list out so the shared traversal can
        // borrow `self` — a pointer swap, not an allocation
        let slots = std::mem::take(&mut self.slot_ids);
        self.step_slots_inner(&slots, xs, Some(t));
        self.slot_ids = slots;
    }

    /// One lockstep time step of an arbitrary **subset** of slots — the
    /// streaming-session path. `slots` names the slots to advance
    /// (distinct, each `< batch_slots()`); `xs` packs one frame of
    /// `dims[0]` values per named slot, in `slots` order. Every listed
    /// slot advances its own local clock ([`MixedSignalEngine::logits_slot`]
    /// normalizes by it), so concurrently resident sessions of different
    /// ages advance through a single traversal of the plan exactly as a
    /// uniform batch does. Slots not listed are untouched.
    ///
    /// Bit-exactness: a slot stepped through any interleaving of
    /// `step_slots` calls produces exactly the outputs of a fresh
    /// sequential engine fed the same frames in the same order —
    /// per-slot noise streams, analog state, fabric, and readout are
    /// fully slot-local (pinned by tests/stream_parity.rs).
    pub fn step_slots(&mut self, slots: &[usize], xs: &[f32]) {
        self.step_slots_inner(slots, xs, None);
    }

    /// The single lockstep traversal behind `step_batch` (all slots,
    /// shared wall-clock `t`) and `step_slots` (subset, per-slot local
    /// clocks). `t_all` only tags routed events — it feeds no
    /// arithmetic — but the per-slot clock keeps streamed event traces
    /// coherent with their session's own time axis.
    fn step_slots_inner(&mut self, slots: &[usize], xs: &[f32], t_all: Option<u32>) {
        if self.pool.is_some() {
            // engine_threads > 1: the bit-identical fan-out twin below
            return self.step_slots_threaded(slots, xs, t_all);
        }
        let d_in = self.weights.dims[0];
        assert_eq!(
            xs.len(),
            slots.len() * d_in,
            "step wants one frame of {d_in} values per listed slot"
        );
        for &s in slots {
            assert!(
                s < self.batch,
                "slot {s} out of range ({} provisioned)",
                self.batch
            );
        }
        debug_assert!(
            slots
                .iter()
                .enumerate()
                .all(|(i, s)| !slots[..i].contains(s)),
            "duplicate slot in one lockstep step"
        );
        let n_layers = self.weights.n_layers();
        for (k, &s) in slots.iter().enumerate() {
            let frame = &xs[k * d_in..(k + 1) * d_in];
            for (dst, &v) in self.x_bufs[s].iter_mut().zip(frame.iter()) {
                *dst = v as f64;
            }
        }
        let mut x_len = d_in;
        for l in 0..n_layers {
            let wh_scale = self.weights.layers[l].wh_scale;
            let lp = &self.plan.layers[l];
            for &s in slots {
                self.events_b[s].clear();
                self.h_states_b[s].clear();
            }
            if lp.row_tiles == 1 {
                let r = lp.replication;
                if r > 1 {
                    for &s in slots {
                        let (x_rep, x_buf) =
                            (&mut self.x_reps[s], &self.x_bufs[s]);
                        x_rep.clear();
                        for _ in 0..r {
                            // lint: allow(alloc, extend of a cleared scratch buffer sized for the widest layer at build)
                            x_rep.extend_from_slice(&x_buf[..x_len]);
                        }
                    }
                }
                let (c0, c1) = self.plan.core_range(l);
                // slots iterate *inside* the core loop: the core's
                // capacitor arrays (weights, mismatch, noise aggregates)
                // stay hot across all the slot-steps
                for core in self.cores[c0..c1].iter_mut() {
                    for &s in slots {
                        let x_phys: &[f64] = if r > 1 {
                            &self.x_reps[s]
                        } else {
                            &self.x_bufs[s][..x_len]
                        };
                        core.step_slot(s, x_phys, &self.circuit, &mut self.core_out);
                        push_outputs(
                            &self.core_out,
                            wh_scale,
                            &self.circuit,
                            false,
                            &mut self.events_b[s],
                            &mut self.h_states_b[s],
                            &mut self.z_vals,
                            &mut self.ht_vals,
                        );
                    }
                }
            } else {
                // row-split layer: per-slot weighted partial sums; the
                // per-slot in-flight noise streams of the owner tile let
                // every tile run all listed slots before the owner
                // finishes
                let n_in_total = lp.n_in as f64;
                for ct in 0..lp.col_tiles {
                    let owner = lp.owner_tile(ct).core;
                    let width = lp.owner_tile(ct).n_cols();
                    for &s in slots {
                        self.accs[s].clear();
                        // lint: allow(alloc, resize of a retained-capacity accumulator; width never exceeds the widest tile)
                        self.accs[s].resize(width, (0.0, 0.0));
                    }
                    for rt in 0..lp.row_tiles {
                        let tile = lp.tile(rt, ct);
                        let (r0, r1) = tile.rows;
                        let weight = (r1 - r0) as f64;
                        for &s in slots {
                            let partials = self.cores[tile.core]
                                .step_partial_slot(
                                    s,
                                    &self.x_bufs[s][r0..r1],
                                    &self.circuit,
                                );
                            debug_assert_eq!(partials.len(), width);
                            for (a, p) in
                                self.accs[s].iter_mut().zip(partials.iter())
                            {
                                a.0 += weight * p.0;
                                a.1 += weight * p.1;
                            }
                        }
                        if rt != 0 {
                            for &s in slots {
                                self.cores[tile.core].finish_partial_only_slot(s);
                            }
                        }
                    }
                    for &s in slots {
                        for a in self.accs[s].iter_mut() {
                            a.0 /= n_in_total;
                            a.1 /= n_in_total;
                        }
                        self.cores[owner].step_finish_slot(
                            s,
                            &self.accs[s],
                            &self.circuit,
                            &mut self.core_out,
                        );
                        push_outputs(
                            &self.core_out,
                            wh_scale,
                            &self.circuit,
                            false,
                            &mut self.events_b[s],
                            &mut self.h_states_b[s],
                            &mut self.z_vals,
                            &mut self.ht_vals,
                        );
                    }
                }
            }
            if l == n_layers - 1 {
                for &s in slots {
                    self.rings[s][self.ring_pos[s]]
                        .copy_from_slice(&self.h_states_b[s]);
                    self.ring_pos[s] = (self.ring_pos[s] + 1) % READOUT_STEPS;
                }
            } else {
                for &s in slots {
                    let t = t_all.unwrap_or(self.steps_seen[s] as u32);
                    self.fabrics[s].route(l, t, &self.events_b[s]);
                    let port = &self.fabrics[s].ports[l];
                    for (dst, &bit) in
                        self.x_bufs[s].iter_mut().zip(port.frame.iter())
                    {
                        *dst = bit as u8 as f64;
                    }
                }
                x_len = self.weights.layers[l].n_out;
            }
        }
        for &s in slots {
            self.steps_seen[s] += 1;
        }
    }

    /// The fan-out twin of `step_slots_inner`, taken when
    /// `engine_threads > 1` (ADR-007). Independent cores of each layer
    /// run as pool tasks — one task per tile, each owning its core and
    /// a per-core staging buffer — and the main thread joins for
    /// everything order-sensitive: the weighted row-split combine, the
    /// owner-tile finish, output splicing, event routing, and the
    /// readout ring. Per-core call sequences (and therefore RNG streams
    /// and meters) are exactly those of the serial path, and the main
    /// thread replays the serial float-accumulation and output order,
    /// so results are bit-identical at every thread count
    /// (tests/parallel_parity.rs). `DeltaCounters`/energy stay per-core
    /// and merge in core-index order at read time — deterministic
    /// regardless of task scheduling. Steady-state allocation stays
    /// zero: staging is provisioned by `provision_pool_scratch` and the
    /// pool's `run` is allocation-free (tests/hot_path_alloc.rs).
    fn step_slots_threaded(&mut self, slots: &[usize], xs: &[f32], t_all: Option<u32>) {
        let d_in = self.weights.dims[0];
        assert_eq!(
            xs.len(),
            slots.len() * d_in,
            "step wants one frame of {d_in} values per listed slot"
        );
        for &s in slots {
            assert!(
                s < self.batch,
                "slot {s} out of range ({} provisioned)",
                self.batch
            );
        }
        debug_assert!(
            slots
                .iter()
                .enumerate()
                .all(|(i, s)| !slots[..i].contains(s)),
            "duplicate slot in one lockstep step"
        );
        let n_layers = self.weights.n_layers();
        for (k, &s) in slots.iter().enumerate() {
            let frame = &xs[k * d_in..(k + 1) * d_in];
            for (dst, &v) in self.x_bufs[s].iter_mut().zip(frame.iter()) {
                *dst = v as f64;
            }
        }
        let mut x_len = d_in;
        for l in 0..n_layers {
            let wh_scale = self.weights.layers[l].wh_scale;
            for &s in slots {
                self.events_b[s].clear();
                self.h_states_b[s].clear();
            }
            if self.plan.layers[l].row_tiles == 1 {
                let r = self.plan.layers[l].replication;
                if r > 1 {
                    for &s in slots {
                        let (x_rep, x_buf) =
                            (&mut self.x_reps[s], &self.x_bufs[s]);
                        x_rep.clear();
                        for _ in 0..r {
                            // lint: allow(alloc, extend of a cleared scratch buffer sized for the widest layer at build)
                            x_rep.extend_from_slice(&x_buf[..x_len]);
                        }
                    }
                }
                let (c0, c1) = self.plan.core_range(l);
                let n_tiles = c1 - c0;
                for k in 0..n_tiles {
                    let width = self.plan.layers[l].tiles[k].n_cols();
                    let stage = &mut self.tile_out[c0 + k];
                    stage.clear();
                    // lint: allow(alloc, resize of a retained-capacity staging buffer provisioned at reset_batch)
                    stage.resize(slots.len() * width, (false, 0.0));
                }
                let cores_base = SendPtrMut(self.cores.as_mut_ptr());
                let outs_base = SendPtrMut(self.core_outs.as_mut_ptr());
                let stage_base = SendPtrMut(self.tile_out.as_mut_ptr());
                let lp = &self.plan.layers[l];
                let circuit = &self.circuit;
                let x_bufs = &self.x_bufs;
                let x_reps = &self.x_reps;
                let pool =
                    self.pool.as_ref().expect("threaded step without a pool");
                pool.run(n_tiles, &|k| {
                    let width = lp.tiles[k].n_cols();
                    // SAFETY: task k solely owns core `c0 + k` and its
                    // staging/scratch slots for this fan-out (one task
                    // per tile), and `run` joins before the borrows
                    // behind these pointers end.
                    let core = unsafe { &mut *cores_base.at(c0 + k) };
                    let out = unsafe { &mut *outs_base.at(c0 + k) };
                    let stage = unsafe { &mut *stage_base.at(c0 + k) };
                    for (pos, &s) in slots.iter().enumerate() {
                        let x_phys: &[f64] = if r > 1 {
                            &x_reps[s]
                        } else {
                            &x_bufs[s][..x_len]
                        };
                        core.step_slot(s, x_phys, circuit, out);
                        debug_assert_eq!(out.steps.len(), width);
                        for (dst, st) in stage
                            [pos * width..(pos + 1) * width]
                            .iter_mut()
                            .zip(out.steps.iter())
                        {
                            *dst = (
                                st.y,
                                volts_to_logical(st.v_h, wh_scale, circuit)
                                    as f32,
                            );
                        }
                    }
                });
                // splice the staged outputs in core order — exactly the
                // push order of the serial path
                for k in 0..n_tiles {
                    let width = lp.tiles[k].n_cols();
                    for (pos, &s) in slots.iter().enumerate() {
                        let stage = &self.tile_out[c0 + k];
                        for &(y, h) in
                            &stage[pos * width..(pos + 1) * width]
                        {
                            self.events_b[s].push(y); // lint: allow(alloc, push into a cleared per-layer buffer that reuses its capacity)
                            self.h_states_b[s].push(h); // lint: allow(alloc, push into a cleared per-layer buffer that reuses its capacity)
                        }
                    }
                }
            } else {
                // row-split layer: every tile's partial half-step is an
                // independent task (tiles are core-disjoint by plan
                // validation); the weighted combine and the owner-tile
                // finish stay on the main thread, in serial order
                let lp = &self.plan.layers[l];
                let n_in_total = lp.n_in as f64;
                let n_tiles = lp.row_tiles * lp.col_tiles;
                for m in 0..n_tiles {
                    let (rt, ct) = (m % lp.row_tiles, m / lp.row_tiles);
                    let tile = lp.tile(rt, ct);
                    let width = lp.owner_tile(ct).n_cols();
                    let stage = &mut self.tile_partials[tile.core];
                    stage.clear();
                    // lint: allow(alloc, resize of a retained-capacity staging buffer provisioned at reset_batch)
                    stage.resize(slots.len() * width, (0.0, 0.0));
                }
                let cores_base = SendPtrMut(self.cores.as_mut_ptr());
                let parts_base = SendPtrMut(self.tile_partials.as_mut_ptr());
                let circuit = &self.circuit;
                let x_bufs = &self.x_bufs;
                let pool =
                    self.pool.as_ref().expect("threaded step without a pool");
                pool.run(n_tiles, &|m| {
                    let (rt, ct) = (m % lp.row_tiles, m / lp.row_tiles);
                    let tile = lp.tile(rt, ct);
                    let width = lp.owner_tile(ct).n_cols();
                    // SAFETY: every tile is its own core (plan
                    // validation), so task m solely owns core
                    // `tile.core` and its staging slot; `run` joins
                    // before the borrows behind these pointers end.
                    let core = unsafe { &mut *cores_base.at(tile.core) };
                    let stage = unsafe { &mut *parts_base.at(tile.core) };
                    let (r0, r1) = tile.rows;
                    for (pos, &s) in slots.iter().enumerate() {
                        let partials = core.step_partial_slot(
                            s,
                            &x_bufs[s][r0..r1],
                            circuit,
                        );
                        debug_assert_eq!(partials.len(), width);
                        stage[pos * width..(pos + 1) * width]
                            .copy_from_slice(partials);
                    }
                    if rt != 0 {
                        // non-owner tiles close their half-step in-task:
                        // the same per-core call sequence as serial
                        for &s in slots {
                            core.finish_partial_only_slot(s);
                        }
                    }
                });
                // weighted combine + owner finish, replaying the serial
                // accumulation order (rt ascending per slot)
                for ct in 0..lp.col_tiles {
                    let owner = lp.owner_tile(ct).core;
                    let width = lp.owner_tile(ct).n_cols();
                    for &s in slots {
                        self.accs[s].clear();
                        // lint: allow(alloc, resize of a retained-capacity accumulator; width never exceeds the widest tile)
                        self.accs[s].resize(width, (0.0, 0.0));
                    }
                    for rt in 0..lp.row_tiles {
                        let tile = lp.tile(rt, ct);
                        let (r0, r1) = tile.rows;
                        let weight = (r1 - r0) as f64;
                        for (pos, &s) in slots.iter().enumerate() {
                            let stage = &self.tile_partials[tile.core];
                            for (a, p) in self.accs[s].iter_mut().zip(
                                stage[pos * width..(pos + 1) * width].iter(),
                            ) {
                                a.0 += weight * p.0;
                                a.1 += weight * p.1;
                            }
                        }
                    }
                    for &s in slots {
                        for a in self.accs[s].iter_mut() {
                            a.0 /= n_in_total;
                            a.1 /= n_in_total;
                        }
                        self.cores[owner].step_finish_slot(
                            s,
                            &self.accs[s],
                            &self.circuit,
                            &mut self.core_out,
                        );
                        push_outputs(
                            &self.core_out,
                            wh_scale,
                            &self.circuit,
                            false,
                            &mut self.events_b[s],
                            &mut self.h_states_b[s],
                            &mut self.z_vals,
                            &mut self.ht_vals,
                        );
                    }
                }
            }
            if l == n_layers - 1 {
                for &s in slots {
                    self.rings[s][self.ring_pos[s]]
                        .copy_from_slice(&self.h_states_b[s]);
                    self.ring_pos[s] = (self.ring_pos[s] + 1) % READOUT_STEPS;
                }
            } else {
                for &s in slots {
                    let t = t_all.unwrap_or(self.steps_seen[s] as u32);
                    self.fabrics[s].route(l, t, &self.events_b[s]);
                    let port = &self.fabrics[s].ports[l];
                    for (dst, &bit) in
                        self.x_bufs[s].iter_mut().zip(port.frame.iter())
                    {
                        *dst = bit as u8 as f64;
                    }
                }
                x_len = self.weights.layers[l].n_out;
            }
        }
        for &s in slots {
            self.steps_seen[s] += 1;
        }
    }

    /// Classifier logits of batch slot `slot`: mean of the *populated*
    /// readout ring entries plus the digital bias — sequences shorter
    /// than `READOUT_STEPS` average only the steps actually seen (no
    /// zero-padding bias). Normalized by the **slot's own** step count,
    /// so a streaming session polled mid-sequence reads the running
    /// logits of exactly the frames it has pushed so far.
    pub fn logits_slot(&self, slot: usize) -> Vec<f32> {
        let head_lw = self.weights.layers.last().unwrap();
        let n = head_lw.n_out;
        let mut out = vec![0.0f32; n];
        for r in &self.rings[slot] {
            for j in 0..n {
                out[j] += r[j];
            }
        }
        let denom = self.steps_seen[slot].clamp(1, READOUT_STEPS) as f32;
        for j in 0..n {
            out[j] = out[j] / denom + head_lw.bh[j];
        }
        out
    }

    /// Classifier logits of the sequential path (slot 0).
    pub fn logits(&self) -> Vec<f32> {
        self.logits_slot(0)
    }

    /// Run a full sequence and classify (resets state first).
    pub fn classify(&mut self, seq: &[f32]) -> usize {
        let d_in = self.weights.dims[0];
        self.reset();
        for (t, x) in seq.chunks(d_in).enumerate() {
            self.step(t as u32, x, None);
        }
        argmax(&self.logits())
    }

    /// Classify a uniform-shape batch in lockstep: all sequences advance
    /// together, one plan traversal per time step. Returns one label per
    /// sequence, equal to what `classify` would return for each of them
    /// individually (the per-slot RNG convention makes the two paths
    /// bit-identical — pinned by tests/batch_parity.rs).
    ///
    /// Sequences must share one length, and that length must be a
    /// multiple of the input width — serve ragged traffic through
    /// [`crate::coordinator::BatchPolicy::bucketed`] (the leader then
    /// only ever drains uniform-length batches), or group by length as
    /// [`crate::coordinator::MixedSignalBackend`] does.
    pub fn classify_batch(&mut self, seqs: &[&[f32]]) -> Vec<usize> {
        let Some(first) = seqs.first() else {
            return Vec::new();
        };
        let d_in = self.weights.dims[0];
        assert!(
            seqs.iter().all(|s| s.len() == first.len()),
            "classify_batch requires a uniform-length batch \
             (got lengths {:?})",
            seqs.iter().map(|s| s.len()).collect::<Vec<_>>()
        );
        assert_eq!(
            first.len() % d_in,
            0,
            "sequence length must be a multiple of the input width {d_in}"
        );
        let b = seqs.len();
        let t_len = first.len() / d_in;
        self.reset_batch(b);
        // lend the packed scratch out so `step_batch` can borrow `self`
        let mut xs = std::mem::take(&mut self.batch_x);
        for t in 0..t_len {
            for (s, seq) in seqs.iter().enumerate() {
                xs[s * d_in..(s + 1) * d_in]
                    .copy_from_slice(&seq[t * d_in..(t + 1) * d_in]);
            }
            self.step_batch(t as u32, &xs);
        }
        self.batch_x = xs;
        (0..b).map(|s| argmax(&self.logits_slot(s))).collect()
    }

    /// Aggregate energy across all cores.
    pub fn energy(&self) -> EnergyMeter {
        let mut m = EnergyMeter::new();
        for c in &self.cores {
            m.merge(&c.meter);
        }
        m
    }

    /// (events routed, mean events per frame) aggregated over every
    /// slot's fabric — the sparsity measurement of all traffic served.
    pub fn fabric_stats(&self) -> (u64, f64) {
        let events: u64 = self.fabrics.iter().map(|f| f.events_routed).sum();
        let frames: u64 = self.fabrics.iter().map(|f| f.frames_routed).sum();
        let mean = if frames == 0 {
            0.0
        } else {
            events as f64 / frames as f64
        };
        (events, mean)
    }

    /// Cumulative delta-sparsity skip counters aggregated across every
    /// core (ADR-005): components fired vs skipped under the
    /// accumulating-delta rule, and whole column charge-shares skipped
    /// vs executed. All zeros unless the engine runs with
    /// `circuit.delta > 0` — the default path never touches the delta
    /// machinery. Like [`MixedSignalEngine::energy`], the counters are
    /// lifetime-cumulative (sequence resets do not clear them), which
    /// is what the serving layer's shutdown merge and `/metrics`
    /// exposure rely on.
    pub fn delta_stats(&self) -> DeltaCounters {
        let mut d = DeltaCounters::default();
        for c in &self.cores {
            d.merge(&c.delta_counters());
        }
        d
    }
}

/// Append one core's observables to the layer output buffers (free
/// function so the engine can lend out disjoint scratch fields).
#[allow(clippy::too_many_arguments)]
fn push_outputs(
    out: &CoreStep,
    wh_scale: f32,
    cfg: &CircuitConfig,
    want_traces: bool,
    events: &mut Vec<bool>,
    h_states: &mut Vec<f32>,
    z_vals: &mut Vec<f32>,
    ht_vals: &mut Vec<f32>,
) {
    for s in &out.steps {
        events.push(s.y); // lint: allow(alloc, push into a cleared per-layer buffer that reuses its capacity)
        h_states.push(volts_to_logical(s.v_h, wh_scale, cfg) as f32); // lint: allow(alloc, push into a cleared per-layer buffer that reuses its capacity)
        if want_traces {
            z_vals.push(s.z.value()); // lint: allow(alloc, tracing is the diagnostic cold path)
            ht_vals.push(volts_to_logical(s.v_htilde, wh_scale, cfg) as f32); // lint: allow(alloc, tracing is the diagnostic cold path)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mingru::GoldenNetwork;
    use crate::nn::weights::synthetic_network;
    use crate::quant::codesign::snap_network;

    fn toy_engine(ideal: bool) -> MixedSignalEngine {
        let weights = synthetic_network(&[1, 12, 10], 11);
        let circuit = if ideal {
            CircuitConfig::ideal()
        } else {
            CircuitConfig::default()
        };
        MixedSignalEngine::new(
            weights,
            circuit,
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap()
    }

    #[test]
    fn builds_one_core_per_layer() {
        let e = toy_engine(true);
        assert_eq!(e.n_cores(), 2);
        assert_eq!(e.plan.n_cores, 2);
    }

    #[test]
    fn ideal_engine_matches_golden_up_to_swap_granularity() {
        // The satsim swaps k = round(z·n) of n caps, i.e. quantizes the
        // mixing ratio to 1/n; the golden model uses z exactly. Over a
        // short sequence the traces must agree within that granularity.
        let mut e = toy_engine(true);
        let weights = e.weights.clone();
        let mut g = GoldenNetwork::new(weights);
        let seq: Vec<f32> = (0..40).map(|t| ((t * 13) % 17) as f32 / 16.0).collect();
        e.reset();
        g.reset();
        let mut worst: f32 = 0.0;
        for (t, x) in seq.iter().enumerate() {
            let mut traces = Vec::new();
            e.step(t as u32, &[*x], Some(&mut traces));
            g.step(&[*x], None);
            for (hs, hg) in traces[0].h.last().unwrap().iter()
                .zip(g.states[0].h.iter())
            {
                worst = worst.max((hs - hg).abs());
            }
        }
        // 12 caps → granularity ~1/24 of the state range per step;
        // accumulated differences stay small for short sequences
        assert!(worst < 0.25, "worst |Δh| = {worst}");
    }

    #[test]
    fn delta_engine_skips_and_tracks_delta_golden() {
        // Hidden-layer frames are binary events, so any threshold in
        // (0,1) skips every component that did not toggle — the ideal
        // delta engine must still track the golden model running the
        // same accumulating-delta rule, within swap granularity.
        let weights = synthetic_network(&[1, 12, 10], 11);
        let delta = 0.05;
        let circuit = CircuitConfig { delta, ..CircuitConfig::ideal() };
        let mut e = MixedSignalEngine::new(
            weights.clone(),
            circuit,
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap();
        let mut g = GoldenNetwork::with_delta(weights, delta);
        e.reset();
        g.reset();
        let mut worst: f32 = 0.0;
        for t in 0..40 {
            let x = [((t * 13) % 17) as f32 / 16.0];
            let mut traces = Vec::new();
            e.step(t as u32, &x, Some(&mut traces));
            g.step(&x, None);
            for (hs, hg) in traces[0].h.last().unwrap().iter()
                .zip(g.states[0].h.iter())
            {
                worst = worst.max((hs - hg).abs());
            }
        }
        assert!(worst < 0.25, "worst |Δh| = {worst}");
        let d = e.delta_stats();
        assert!(d.components_fired > 0);
        assert!(d.components_skipped > 0, "binary frames must skip");
        assert!(d.skip_ratio() > 0.0 && d.skip_ratio() < 1.0);
    }

    #[test]
    fn classify_deterministic_and_energy_positive() {
        let mut e = toy_engine(false);
        let seq: Vec<f32> = (0..30).map(|t| (t % 4) as f32 / 3.0).collect();
        let a = e.classify(&seq);
        let m1 = e.energy();
        let b = e.classify(&seq);
        assert_eq!(a, b);
        assert!(m1.total_j() > 0.0);
        assert!(m1.steps >= 30);
    }

    #[test]
    fn row_split_network_constructs_and_classifies() {
        // The former `rejects_row_split_layers` case, inverted: 100
        // inputs on 64-row cores now plan as 2 row tiles and serve on
        // the physics path.
        let weights = synthetic_network(&[100, 8], 1);
        let mut e = MixedSignalEngine::new(
            weights,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 64, cols: 64 },
        )
        .unwrap();
        assert_eq!(e.plan.layers[0].row_tiles, 2);
        assert_eq!(e.n_cores(), 2);
        let seq: Vec<f32> =
            (0..100 * 12).map(|t| ((t * 7) % 13) as f32 / 12.0).collect();
        let a = e.classify(&seq);
        assert_eq!(a, e.classify(&seq), "row-split classify must be deterministic");
        // the combined path produced real (finite, moving) head states
        let logits = e.logits();
        assert!(logits.iter().all(|l| l.is_finite()));
        let bh = &e.weights.layers.last().unwrap().bh;
        assert!(
            logits.iter().zip(bh.iter()).any(|(l, b)| (l - b).abs() > 1e-4),
            "head states never moved off the bias"
        );
        assert!(e.energy().total_j() > 0.0);
    }

    #[test]
    fn row_split_ideal_engine_tracks_golden() {
        // Engine-vs-golden parity on a forced row split (n_in > rows):
        // snap the network so both sides use the deployed (realizable)
        // gate slope, then compare h traces within swap granularity.
        let raw = synthetic_network(&[100, 8], 3);
        let nw = snap_network(&raw, &CircuitConfig::ideal(), 64).unwrap();
        let mut e = MixedSignalEngine::new(
            nw.clone(),
            CircuitConfig::ideal(),
            CoreGeometry { rows: 64, cols: 64 },
        )
        .unwrap();
        assert!(e.plan.layers[0].is_row_split());
        let mut g = GoldenNetwork::new(nw);
        e.reset();
        g.reset();
        let mut worst: f32 = 0.0;
        for t in 0..30 {
            let x: Vec<f32> =
                (0..100).map(|i| ((t * 31 + i * 7) % 11) as f32 / 10.0).collect();
            let mut traces = Vec::new();
            e.step(t as u32, &x, Some(&mut traces));
            g.step(&x, None);
            for (hs, hg) in traces[0].h.last().unwrap().iter()
                .zip(g.states[0].h.iter())
            {
                worst = worst.max((hs - hg).abs());
            }
        }
        // owner bank has 64 pairs → fine swap granularity; the bound
        // matches the unsplit toy parity test above
        assert!(worst < 0.25, "row-split worst |Δh| = {worst}");
    }

    #[test]
    fn classify_batch_of_one_matches_classify() {
        // noisy circuit: this pins the per-slot RNG convention, not just
        // the arithmetic
        let mut a = toy_engine(false);
        let mut b = a.replicate().unwrap();
        let seq: Vec<f32> = (0..30).map(|t| (t % 4) as f32 / 3.0).collect();
        let want = a.classify(&seq);
        assert_eq!(b.classify_batch(&[&seq]), vec![want]);
        // bit-exact, not just same argmax
        assert_eq!(b.logits_slot(0), a.logits());
        // and the engine still serves the sequential path afterwards
        assert_eq!(b.classify(&seq), want);
    }

    #[test]
    fn batch_slots_classify_their_own_sequences() {
        let mut seq_engine = toy_engine(false);
        let mut bat_engine = seq_engine.replicate().unwrap();
        let seqs: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                (0..24).map(|t| ((t * (s + 2)) % 5) as f32 / 4.0).collect()
            })
            .collect();
        let want: Vec<usize> =
            seqs.iter().map(|s| seq_engine.classify(s)).collect();
        let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
        assert_eq!(bat_engine.classify_batch(&refs), want);
        assert_eq!(bat_engine.batch_slots(), 3);
    }

    #[test]
    fn classify_batch_rejects_ragged_and_accepts_empty() {
        let mut e = toy_engine(true);
        assert!(e.classify_batch(&[]).is_empty());
        let (a, b) = (vec![0.5f32; 8], vec![0.5f32; 12]);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                e.classify_batch(&[&a, &b])
            }),
        );
        assert!(result.is_err(), "ragged batch must be rejected");
    }

    #[test]
    fn provisioned_slots_match_fresh_engines_with_instance_seeds() {
        // the ADR-008 anchor invariant at engine level: MC slot `s`
        // must be bit-identical to a whole fresh engine built with
        // `circuit.seed = instance_seed(master, s)`
        let mut mc = toy_engine(false);
        let master = 0x5EED_CAFE;
        mc.provision_devices(master, 3);
        assert!(mc.per_slot_devices());
        let seqs: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                (0..24).map(|t| ((t * (s + 2)) % 5) as f32 / 4.0).collect()
            })
            .collect();
        let refs: Vec<&[f32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let labels = mc.classify_batch(&refs);
        for s in 0..3 {
            let seed = crate::montecarlo::instance_seed(master, s);
            let circuit = CircuitConfig { seed, ..mc.circuit.clone() };
            let mut fresh = MixedSignalEngine::new(
                mc.weights.clone(),
                circuit,
                CoreGeometry { rows: 16, cols: 16 },
            )
            .unwrap();
            assert_eq!(fresh.classify(&seqs[s]), labels[s]);
            assert_eq!(
                fresh.logits(),
                mc.logits_slot(s),
                "slot {s} diverged from its fresh-engine anchor"
            );
        }
        // dissolving restores the ADR-001 clone convention bit-exactly
        mc.dissolve_devices();
        assert!(!mc.per_slot_devices());
        let mut plain = toy_engine(false);
        let want: Vec<usize> = seqs.iter().map(|s| plain.classify(s)).collect();
        assert_eq!(mc.classify_batch(&refs), want);
    }

    #[test]
    fn reset_batch_refuses_width_change_with_devices() {
        let mut e = toy_engine(false);
        e.provision_devices(7, 2);
        let blew = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| e.reset_batch(4)),
        );
        assert!(blew.is_err(), "width change must refuse under a sweep");
        // same-width resets keep the instances installed
        e.reset_batch(2);
        assert!(e.per_slot_devices());
        e.dissolve_devices();
        e.reset_batch(4);
        assert_eq!(e.batch_slots(), 4);
    }

    #[test]
    fn leased_slots_stream_bit_identical_to_sequential() {
        // two sessions of different lengths, interleaved frame by frame
        // through the subset path, under full noise — each must read the
        // exact logits of a one-shot sequential run of its own frames
        let mut seq = toy_engine(false);
        let mut stream = seq.replicate().unwrap();
        stream.provision_sessions(3);
        assert_eq!(stream.session_capacity(), 3);
        let a = stream.lease_slot().unwrap();
        let b = stream.lease_slot().unwrap();
        assert_ne!(a, b);
        assert_eq!(stream.live_sessions(), 2);
        let seq_a: Vec<f32> = (0..20).map(|t| (t % 4) as f32 / 3.0).collect();
        let seq_b: Vec<f32> = (0..12).map(|t| ((t * 3) % 5) as f32 / 4.0).collect();
        for t in 0..20 {
            if t < 12 {
                stream.step_slots(&[a, b], &[seq_a[t], seq_b[t]]);
            } else {
                stream.step_slots(&[a], &[seq_a[t]]);
            }
        }
        let (la, lb) = (stream.logits_slot(a), stream.logits_slot(b));
        seq.classify(&seq_a);
        assert_eq!(la, seq.logits(), "session A diverged from one-shot");
        seq.classify(&seq_b);
        assert_eq!(lb, seq.logits(), "session B diverged from one-shot");
    }

    #[test]
    fn released_slot_recycles_bit_clean() {
        let mut seq = toy_engine(false);
        let mut stream = seq.replicate().unwrap();
        stream.provision_sessions(1);
        // first session: abandoned mid-sequence
        let s0 = stream.lease_slot().unwrap();
        assert!(stream.lease_slot().is_none(), "capacity 1 must exhaust");
        stream.step_slots(&[s0], &[0.7]);
        stream.step_slots(&[s0], &[0.2]);
        stream.release_slot(s0);
        assert_eq!(stream.live_sessions(), 0);
        // second session reuses the slot and must match a fresh run
        let s1 = stream.lease_slot().unwrap();
        assert_eq!(s1, s0);
        let frames: Vec<f32> = (0..24).map(|t| (t % 3) as f32 / 2.0).collect();
        for &f in &frames {
            stream.step_slots(&[s1], &[f]);
        }
        seq.classify(&frames);
        assert_eq!(stream.logits_slot(s1), seq.logits());
    }

    #[test]
    fn batch_mode_has_no_leasable_slots() {
        let mut e = toy_engine(true);
        assert_eq!(e.session_capacity(), 0);
        assert!(e.lease_slot().is_none());
        // provisioning sessions, then returning to batch mode, drains
        // the pool again
        e.provision_sessions(2);
        assert_eq!(e.session_capacity(), 2);
        e.reset_batch(4);
        assert_eq!(e.session_capacity(), 0);
        assert!(e.lease_slot().is_none());
    }

    #[test]
    fn reset_refuses_while_sessions_live() {
        let mut e = toy_engine(true);
        e.provision_sessions(2);
        let s = e.lease_slot().unwrap();
        let blew = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| e.reset_batch(2)));
        assert!(blew.is_err(), "reset_batch must refuse with a live session");
        e.release_slot(s);
        e.reset_batch(2); // fine once the session is closed
    }

    #[test]
    fn replicate_builds_an_equivalent_engine() {
        let mut a = toy_engine(false);
        let mut b = a.replicate().unwrap();
        assert_eq!(a.n_cores(), b.n_cores());
        let seq: Vec<f32> = (0..24).map(|t| (t % 3) as f32 / 2.0).collect();
        // same seed/config → replicas classify identically
        assert_eq!(a.classify(&seq), b.classify(&seq));
    }

    #[test]
    fn column_split_across_cores() {
        let weights = synthetic_network(&[4, 40], 5);
        let e = MixedSignalEngine::new(
            weights,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap();
        assert_eq!(e.n_cores(), 3); // 40 cols over 16-wide cores
    }
}
