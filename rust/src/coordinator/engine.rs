//! The mixed-signal inference engine: a trained network mapped onto
//! switched-capacitor cores, stepped through full sequences with the
//! event fabric in between — the rust equivalent of the paper's
//! "mixed-signal simulation set up with equivalent weights and biases"
//! (Fig 4), and the physical backend of the serving coordinator.

use anyhow::{bail, Result};

use crate::config::{CircuitConfig, CoreGeometry};
use crate::energy::EnergyMeter;
use crate::nn::mingru::{argmax, READOUT_STEPS};
use crate::nn::weights::NetworkWeights;
use crate::quant::codesign::{map_layer, volts_to_logical, LayerCircuit};
use crate::router::fabric::Fabric;
use crate::satsim::Core;

/// Per-sequence observables of one layer (logical units — directly
/// comparable to the golden model and to the python traces).
#[derive(Debug, Clone, Default)]
pub struct LayerTraceSeq {
    pub z: Vec<Vec<f32>>,
    pub htilde: Vec<Vec<f32>>,
    pub h: Vec<Vec<f32>>,
    pub y: Vec<Vec<f32>>,
}

/// A network instantiated on physical cores.
pub struct MixedSignalEngine {
    pub weights: NetworkWeights,
    pub circuit: CircuitConfig,
    pub geometry: CoreGeometry,
    pub cores: Vec<Core>,
    /// Codesign diagnostics per layer.
    pub layer_circuits: Vec<LayerCircuit>,
    fabric: Fabric,
    /// readout ring (analog head states, logical units)
    ring: Vec<Vec<f32>>,
    ring_pos: usize,
    /// scratch input buffer
    x_buf: Vec<f64>,
}

impl MixedSignalEngine {
    /// Map the network onto cores. Requires every layer's input dim to
    /// fit the core rows (the paper network does; row-split layers are
    /// served by the golden/PJRT paths — DESIGN.md §4 notes the scope).
    pub fn new(
        weights: NetworkWeights,
        circuit: CircuitConfig,
        geometry: CoreGeometry,
    ) -> Result<MixedSignalEngine> {
        let mut cores = Vec::new();
        let mut layer_circuits = Vec::new();
        for (l, lw) in weights.layers.iter().enumerate() {
            if lw.n_in > geometry.rows {
                bail!(
                    "layer {l}: input dim {} exceeds core rows {} — \
                     row-split layers are not supported by the \
                     mixed-signal engine",
                    lw.n_in,
                    geometry.rows
                );
            }
            let lc = map_layer(lw, &circuit, geometry.rows)?;
            // column-split across as many cores as needed
            for (tile, chunk) in lc.columns.chunks(geometry.cols).enumerate() {
                cores.push(Core::new(
                    geometry,
                    chunk.to_vec(),
                    &circuit,
                    (l as u64) << 16 | tile as u64,
                ));
            }
            layer_circuits.push(lc);
        }
        let widths: Vec<usize> =
            weights.layers.iter().map(|l| l.n_out).collect();
        let head = *weights.dims.last().unwrap();
        let max_dim = *weights.dims.iter().max().unwrap();
        Ok(MixedSignalEngine {
            fabric: Fabric::new(&widths),
            ring: vec![vec![0.0; head]; READOUT_STEPS],
            ring_pos: 0,
            x_buf: vec![0.0; max_dim],
            weights,
            circuit,
            geometry,
            cores,
            layer_circuits,
        })
    }

    /// Build an independent engine with the same network, circuit and
    /// geometry — each serving worker owns one (a physical core bank
    /// holds one sequence's state, so engines are never shared).
    pub fn replicate(&self) -> Result<MixedSignalEngine> {
        MixedSignalEngine::new(
            self.weights.clone(),
            self.circuit.clone(),
            self.geometry,
        )
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn reset(&mut self) {
        let cfg = self.circuit.clone();
        for c in self.cores.iter_mut() {
            c.reset(&cfg);
        }
        self.fabric.reset();
        for r in self.ring.iter_mut() {
            r.fill(0.0);
        }
        self.ring_pos = 0;
    }

    /// Cores belonging to layer `l` (column-split tiles in order).
    fn layer_core_range(&self, l: usize) -> (usize, usize) {
        let geometry_cols = self.cores[0].geometry.cols;
        let mut start = 0;
        for lw in self.weights.layers.iter().take(l) {
            start += lw.n_out.div_ceil(geometry_cols);
        }
        let count = self.weights.layers[l].n_out.div_ceil(geometry_cols);
        (start, start + count)
    }

    /// One network time step. `x` = dims[0] input values (analog pixel
    /// for the paper workload). If `traces` is Some, logical-unit
    /// observables are appended per layer.
    pub fn step(&mut self, t: u32, x: &[f32],
                mut traces: Option<&mut Vec<LayerTraceSeq>>) {
        let n_layers = self.weights.n_layers();
        debug_assert_eq!(x.len(), self.weights.dims[0]);
        for (b, &v) in self.x_buf.iter_mut().zip(x.iter()) {
            *b = v as f64;
        }
        let mut x_len = x.len();
        for l in 0..n_layers {
            let lw = &self.weights.layers[l];
            let (c0, c1) = self.layer_core_range(l);
            let cfg = self.circuit.clone();
            let mut events: Vec<bool> = Vec::with_capacity(lw.n_out);
            let mut h_states: Vec<f32> = Vec::with_capacity(lw.n_out);
            let mut z_vals: Vec<f32> = Vec::new();
            let mut ht_vals: Vec<f32> = Vec::new();
            // physical input: the logical frame tiled `replication` times
            // (row replication of narrow layers; DESIGN.md §5)
            let r = self.layer_circuits[l].replication;
            let mut x_slice: Vec<f64> = Vec::with_capacity(r * x_len);
            for _ in 0..r {
                x_slice.extend_from_slice(&self.x_buf[..x_len]);
            }
            for core in self.cores[c0..c1].iter_mut() {
                let out = core.step(&x_slice, &cfg);
                for s in &out.steps {
                    events.push(s.y);
                    h_states.push(
                        volts_to_logical(s.v_h, lw.wh_scale, &cfg) as f32
                    );
                    if traces.is_some() {
                        z_vals.push(s.z.value());
                        ht_vals.push(volts_to_logical(
                            s.v_htilde, lw.wh_scale, &cfg) as f32);
                    }
                }
            }
            if let Some(ts) = traces.as_deref_mut() {
                if ts.len() <= l {
                    ts.resize_with(l + 1, LayerTraceSeq::default);
                }
                ts[l].z.push(z_vals);
                ts[l].htilde.push(ht_vals);
                ts[l].h.push(h_states.clone());
                ts[l].y.push(events.iter().map(|&b| b as u8 as f32).collect());
            }
            if l == n_layers - 1 {
                // head readout: analog states into the ring
                self.ring[self.ring_pos].copy_from_slice(&h_states);
                self.ring_pos = (self.ring_pos + 1) % READOUT_STEPS;
            } else {
                // route binary events to the next layer's row drivers
                self.fabric.route(l, t, &events);
                let port = &self.fabric.ports[l];
                for (b, &bit) in self.x_buf.iter_mut().zip(port.frame.iter()) {
                    *b = bit as u8 as f64;
                }
                x_len = lw.n_out;
            }
        }
    }

    /// Classifier logits (mean of the readout ring + digital bias).
    pub fn logits(&self) -> Vec<f32> {
        let head_lw = self.weights.layers.last().unwrap();
        let n = head_lw.n_out;
        let mut out = vec![0.0f32; n];
        for r in &self.ring {
            for j in 0..n {
                out[j] += r[j];
            }
        }
        for j in 0..n {
            out[j] = out[j] / READOUT_STEPS as f32 + head_lw.bh[j];
        }
        out
    }

    /// Run a full sequence and classify (resets state first).
    pub fn classify(&mut self, seq: &[f32]) -> usize {
        let d_in = self.weights.dims[0];
        self.reset();
        for (t, x) in seq.chunks(d_in).enumerate() {
            self.step(t as u32, x, None);
        }
        argmax(&self.logits())
    }

    /// Aggregate energy across all cores.
    pub fn energy(&self) -> EnergyMeter {
        let mut m = EnergyMeter::new();
        for c in &self.cores {
            m.merge(&c.meter);
        }
        m
    }

    pub fn fabric_stats(&self) -> (u64, f64) {
        (self.fabric.events_routed, self.fabric.mean_events_per_frame())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mingru::GoldenNetwork;
    use crate::nn::weights::synthetic_network;

    fn toy_engine(ideal: bool) -> MixedSignalEngine {
        let weights = synthetic_network(&[1, 12, 10], 11);
        let circuit = if ideal {
            CircuitConfig::ideal()
        } else {
            CircuitConfig::default()
        };
        MixedSignalEngine::new(
            weights,
            circuit,
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap()
    }

    #[test]
    fn builds_one_core_per_layer() {
        let e = toy_engine(true);
        assert_eq!(e.n_cores(), 2);
    }

    #[test]
    fn ideal_engine_matches_golden_up_to_swap_granularity() {
        // The satsim swaps k = round(z·n) of n caps, i.e. quantizes the
        // mixing ratio to 1/n; the golden model uses z exactly. Over a
        // short sequence the traces must agree within that granularity.
        let mut e = toy_engine(true);
        let weights = e.weights.clone();
        let mut g = GoldenNetwork::new(weights);
        let seq: Vec<f32> = (0..40).map(|t| ((t * 13) % 17) as f32 / 16.0).collect();
        e.reset();
        g.reset();
        let mut worst: f32 = 0.0;
        for (t, x) in seq.iter().enumerate() {
            let mut traces = Vec::new();
            e.step(t as u32, &[*x], Some(&mut traces));
            g.step(&[*x], None);
            for (hs, hg) in traces[0].h.last().unwrap().iter()
                .zip(g.states[0].h.iter())
            {
                worst = worst.max((hs - hg).abs());
            }
        }
        // 12 caps → granularity ~1/24 of the state range per step;
        // accumulated differences stay small for short sequences
        assert!(worst < 0.25, "worst |Δh| = {worst}");
    }

    #[test]
    fn classify_deterministic_and_energy_positive() {
        let mut e = toy_engine(false);
        let seq: Vec<f32> = (0..30).map(|t| (t % 4) as f32 / 3.0).collect();
        let a = e.classify(&seq);
        let m1 = e.energy();
        let b = e.classify(&seq);
        assert_eq!(a, b);
        assert!(m1.total_j() > 0.0);
        assert!(m1.steps >= 30);
    }

    #[test]
    fn rejects_row_split_layers() {
        let weights = synthetic_network(&[100, 8], 1);
        let res = MixedSignalEngine::new(
            weights,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 64, cols: 64 },
        );
        assert!(res.is_err());
    }

    #[test]
    fn replicate_builds_an_equivalent_engine() {
        let mut a = toy_engine(false);
        let mut b = a.replicate().unwrap();
        assert_eq!(a.n_cores(), b.n_cores());
        let seq: Vec<f32> = (0..24).map(|t| (t % 3) as f32 / 2.0).collect();
        // same seed/config → replicas classify identically
        assert_eq!(a.classify(&seq), b.classify(&seq));
    }

    #[test]
    fn column_split_across_cores() {
        let weights = synthetic_network(&[4, 40], 5);
        let e = MixedSignalEngine::new(
            weights,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 16, cols: 16 },
        )
        .unwrap();
        assert_eq!(e.n_cores(), 3); // 40 cols over 16-wide cores
    }
}
