//! Serving metrics: latency distribution and throughput accounting for
//! the request loop (the headline numbers of the end-to-end driver).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<u64>,
    started: Instant,
    pub items: u64,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder { samples_us: Vec::new(), started: Instant::now(), items: 0 }
    }

    pub fn record(&mut self, latency: Duration) {
        self.samples_us.push(latency.as_micros() as u64);
        self.items += 1;
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        Duration::from_micros(s[idx.min(s.len() - 1)])
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.samples_us.iter().sum::<u64>() / self.samples_us.len() as u64,
        )
    }

    /// Requests per second since construction.
    pub fn throughput(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.items as f64 / dt
        }
    }

    /// Fold another recorder into this one (per-worker recorders are
    /// merged into the aggregate at shutdown). Latency samples are
    /// concatenated; `started` becomes the earliest of the two so the
    /// aggregate throughput covers the whole serving window.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.items += other.items;
        self.started = self.started.min(other.started);
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?} thpt={:.1}/s",
            self.items,
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.throughput()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100u64 {
            r.record(Duration::from_micros(i * 10));
        }
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert!(r.percentile(95.0) <= r.percentile(99.0));
        assert_eq!(r.items, 100);
        assert!(r.mean() > Duration::ZERO);
    }

    #[test]
    fn empty_recorder_is_safe() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile(99.0), Duration::ZERO);
        assert_eq!(r.mean(), Duration::ZERO);
    }

    #[test]
    fn merge_concatenates_samples_and_counts() {
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        for i in 1..=10u64 {
            a.record(Duration::from_micros(i * 100));
            b.record(Duration::from_micros(i * 200));
        }
        let started_a = a.started;
        a.merge(&b);
        assert_eq!(a.items, 20);
        assert!(a.percentile(100.0) >= Duration::from_micros(2000));
        assert!(a.started <= started_a);
        // merging an empty recorder is a no-op on the samples
        let items = a.items;
        a.merge(&LatencyRecorder::new());
        assert_eq!(a.items, items);
    }
}
