//! Serving metrics: latency distribution and throughput accounting for
//! the request loop (the headline numbers of the end-to-end driver).

use std::time::{Duration, Instant};

use crate::coordinator::server::ServeError;
use crate::energy::EnergyMeter;
use crate::satsim::DeltaCounters;

#[derive(Debug, Clone)]
/// Latency/throughput accumulator for one worker (mergeable).
pub struct LatencyRecorder {
    samples_us: Vec<u64>,
    started: Instant,
    /// When the most recent sample was recorded — the end of the
    /// throughput window (an idle recorder queried later must not see
    /// its rate decay toward zero, and a merged aggregate must not
    /// count a late-joining worker's dead time).
    last_sample: Option<Instant>,
    /// Successfully served requests.
    pub items: u64,
    /// Requests that failed (backend panic, worker lost, session slots
    /// exhausted) — latency is not recorded for these, only the count.
    /// Total across every variant, including errors recorded without a
    /// classification via [`LatencyRecorder::record_errors`].
    pub errors: u64,
    /// [`ServeError::Lost`] failures (server/worker went away).
    pub errors_lost: u64,
    /// [`ServeError::Busy`] rejections (streaming slots exhausted).
    pub errors_busy: u64,
    /// [`ServeError::BackendPanicked`] failures (batch poisoned).
    pub errors_panicked: u64,
    /// Delta-sparsity skip counters of the backend(s) this recorder
    /// covers (ADR-005). Workers fold their engine's
    /// `MixedSignalEngine::delta_stats` in when their loop exits, and
    /// [`LatencyRecorder::merge`] aggregates across workers at
    /// shutdown — the same lifecycle as the latency samples. All zeros
    /// for non-delta backends.
    pub delta: DeltaCounters,
    /// §4.2 energy meter of the backend(s) this recorder covers.
    /// Workers fold their engine's live `MixedSignalEngine::energy`
    /// state in when their loop exits
    /// ([`crate::coordinator::server::Backend::energy_stats`]), and
    /// [`LatencyRecorder::merge`] sums the meters across workers at
    /// shutdown via [`EnergyMeter::merge_disjoint`] — each worker
    /// stepped through its own requests, so steps sum rather than
    /// lockstep-max. All zeros for backends without simulated cores
    /// (golden, PJRT).
    pub energy: EnergyMeter,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    /// An empty recorder; the throughput window starts now.
    pub fn new() -> LatencyRecorder {
        LatencyRecorder {
            samples_us: Vec::new(),
            started: Instant::now(),
            last_sample: None,
            items: 0,
            errors: 0,
            errors_lost: 0,
            errors_busy: 0,
            errors_panicked: 0,
            delta: DeltaCounters::default(),
            energy: EnergyMeter::new(),
        }
    }

    /// Record one served request's latency.
    pub fn record(&mut self, latency: Duration) {
        self.samples_us.push(latency.as_micros() as u64);
        self.items += 1;
        self.last_sample = Some(Instant::now());
    }

    /// Account `n` failed requests (no latency sample — the error path's
    /// timing says nothing about serving latency). Unclassified: the
    /// per-variant counters stay untouched. Prefer
    /// [`LatencyRecorder::record_error_n`] where the [`ServeError`] is
    /// at hand, so the end-of-run report can break failures out.
    pub fn record_errors(&mut self, n: u64) {
        self.errors += n;
        if n > 0 {
            self.last_sample = Some(Instant::now());
        }
    }

    /// Account one classified failure.
    pub fn record_error(&mut self, e: &ServeError) {
        self.record_error_n(e, 1);
    }

    /// Account `n` failures of one [`ServeError`] variant — feeds both
    /// the total and the per-variant breakdown `summary()` prints.
    pub fn record_error_n(&mut self, e: &ServeError, n: u64) {
        match e {
            ServeError::Lost => self.errors_lost += n,
            ServeError::Busy => self.errors_busy += n,
            ServeError::BackendPanicked(_) => self.errors_panicked += n,
        }
        self.record_errors(n);
    }

    /// Single-percentile query (sorts a copy — fine for one-off asks;
    /// use [`LatencyRecorder::percentiles`] for several at once).
    pub fn percentile(&self, p: f64) -> Duration {
        self.percentiles(&[p])[0]
    }

    /// Several percentiles from **one** sort of the sample buffer.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<Duration> {
        if self.samples_us.is_empty() {
            return vec![Duration::ZERO; ps.len()];
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        ps.iter().map(|&p| Self::pct_of(&s, p)).collect()
    }

    fn pct_of(sorted: &[u64], p: f64) -> Duration {
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        Duration::from_micros(sorted[idx.min(sorted.len() - 1)])
    }

    /// Mean recorded latency.
    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.samples_us.iter().sum::<u64>() / self.samples_us.len() as u64,
        )
    }

    /// Requests per second over the active window — from construction to
    /// the *last recorded sample* (not to the moment of the call, which
    /// would dilute the rate of any recorder queried after it went
    /// idle, and would skew merged recorders whose workers started or
    /// finished at different times).
    pub fn throughput(&self) -> f64 {
        let Some(end) = self.last_sample else { return 0.0 };
        let dt = end.duration_since(self.started).as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.items as f64 / dt
        }
    }

    /// Fold another recorder into this one (per-worker recorders are
    /// merged into the aggregate at shutdown). Latency samples are
    /// concatenated; `started` becomes the earliest and `last_sample`
    /// the latest of the two, so the aggregate throughput covers the
    /// whole serving window and nothing more.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.items += other.items;
        self.errors += other.errors;
        self.errors_lost += other.errors_lost;
        self.errors_busy += other.errors_busy;
        self.errors_panicked += other.errors_panicked;
        self.delta.merge(&other.delta);
        self.energy.merge_disjoint(&other.energy);
        self.started = self.started.min(other.started);
        self.last_sample = self.last_sample.max(other.last_sample);
    }

    /// One-line human summary (count, rate, percentiles).
    pub fn summary(&self) -> String {
        // one sort for all three percentiles
        let pcts = self.percentiles(&[50.0, 95.0, 99.0]);
        let mut s = format!(
            "n={} err={} mean={:?} p50={:?} p95={:?} p99={:?} thpt={:.1}/s",
            self.items,
            self.errors,
            self.mean(),
            pcts[0],
            pcts[1],
            pcts[2],
            self.throughput()
        );
        if self.errors > 0 {
            // break the failures out so e.g. streaming Busy rejections
            // are visible at a glance, not folded into one number
            s.push_str(&format!(
                " [lost={} busy={} panicked={}]",
                self.errors_lost, self.errors_busy, self.errors_panicked
            ));
        }
        if self.delta.components_fired + self.delta.components_skipped > 0 {
            // delta-sparsity accounting, only when a delta backend ran
            s.push_str(&format!(
                " delta[fired={} skipped={} ratio={:.3}]",
                self.delta.components_fired,
                self.delta.components_skipped,
                self.delta.skip_ratio()
            ));
        }
        if self.energy.steps > 0 {
            // §4.2 accounting, only when a mixed-signal backend ran
            s.push_str(&format!(
                " energy[steps={} pJ/step={:.2}]",
                self.energy.steps,
                self.energy.per_step_j() * 1e12
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100u64 {
            r.record(Duration::from_micros(i * 10));
        }
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert!(r.percentile(95.0) <= r.percentile(99.0));
        assert_eq!(r.items, 100);
        assert!(r.mean() > Duration::ZERO);
        // the batched query agrees with the one-off queries
        let pcts = r.percentiles(&[50.0, 95.0, 99.0]);
        assert_eq!(pcts[0], r.percentile(50.0));
        assert_eq!(pcts[1], r.percentile(95.0));
        assert_eq!(pcts[2], r.percentile(99.0));
    }

    #[test]
    fn empty_recorder_is_safe() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile(99.0), Duration::ZERO);
        assert_eq!(r.mean(), Duration::ZERO);
        assert_eq!(r.throughput(), 0.0);
    }

    #[test]
    fn merge_concatenates_samples_and_counts() {
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        for i in 1..=10u64 {
            a.record(Duration::from_micros(i * 100));
            b.record(Duration::from_micros(i * 200));
        }
        b.record_errors(2);
        let started_a = a.started;
        a.merge(&b);
        assert_eq!(a.items, 20);
        assert_eq!(a.errors, 2);
        assert!(a.percentile(100.0) >= Duration::from_micros(2000));
        assert!(a.started <= started_a);
        // merging an empty recorder is a no-op on the samples
        let items = a.items;
        a.merge(&LatencyRecorder::new());
        assert_eq!(a.items, items);
    }

    #[test]
    fn throughput_window_ends_at_last_sample() {
        let mut r = LatencyRecorder::new();
        for _ in 0..50 {
            r.record(Duration::from_micros(100));
        }
        let at_once = r.throughput();
        assert!(at_once > 0.0);
        // going idle must not decay the measured rate: the window is
        // anchored on the recorded instants, not on the query time
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(r.throughput(), at_once);
    }

    #[test]
    fn errors_counted_without_latency_samples() {
        let mut r = LatencyRecorder::new();
        r.record_errors(3);
        assert_eq!(r.errors, 3);
        assert_eq!(r.items, 0);
        assert_eq!(r.mean(), Duration::ZERO);
        assert!(r.summary().contains("err=3"));
    }

    #[test]
    fn error_variants_break_out_and_merge() {
        let mut a = LatencyRecorder::new();
        a.record_error(&ServeError::Busy);
        a.record_error_n(&ServeError::Lost, 2);
        a.record_error(&ServeError::BackendPanicked("boom".into()));
        assert_eq!(a.errors, 4);
        assert_eq!((a.errors_lost, a.errors_busy, a.errors_panicked), (2, 1, 1));
        let s = a.summary();
        assert!(s.contains("err=4"), "{s}");
        assert!(s.contains("lost=2") && s.contains("busy=1"), "{s}");
        assert!(s.contains("panicked=1"), "{s}");
        // merging folds the per-variant counters too
        let mut b = LatencyRecorder::new();
        b.record_error(&ServeError::Busy);
        a.merge(&b);
        assert_eq!(a.errors_busy, 2);
        assert_eq!(a.errors, 5);
        // an error-free recorder prints no breakdown
        assert!(!LatencyRecorder::new().summary().contains("lost="));
    }

    #[test]
    fn delta_counters_merge_and_print() {
        // skip counters ride the same merge path as latency samples
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        b.delta.components_fired = 30;
        b.delta.components_skipped = 70;
        b.delta.shares_skipped = 5;
        let mut c = LatencyRecorder::new();
        c.delta.components_fired = 10;
        a.merge(&b);
        a.merge(&c);
        assert_eq!(a.delta.components_fired, 40);
        assert_eq!(a.delta.components_skipped, 70);
        assert_eq!(a.delta.shares_skipped, 5);
        let s = a.summary();
        assert!(s.contains("delta[fired=40 skipped=70"), "{s}");
        // recorders that never saw a delta backend stay silent
        assert!(!LatencyRecorder::new().summary().contains("delta["));
    }

    #[test]
    fn energy_meters_merge_disjoint_and_print() {
        // per-worker meters cover different time steps: steps sum
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        b.energy.cap_charge(1e-15, 0.0, 0.5);
        b.energy.steps = 40;
        let mut c = LatencyRecorder::new();
        c.energy.cap_charge(1e-15, 0.0, 0.5);
        c.energy.steps = 60;
        a.merge(&b);
        a.merge(&c);
        assert_eq!(a.energy.steps, 100);
        assert_eq!(a.energy.cap_events, 2);
        assert!((a.energy.per_step_j() - a.energy.total_j() / 100.0).abs() < 1e-30);
        let s = a.summary();
        assert!(s.contains("energy[steps=100"), "{s}");
        // recorders that never saw a mixed-signal backend stay silent
        assert!(!LatencyRecorder::new().summary().contains("energy["));
    }
}
