//! Dynamic request batching, and the session-aware frame assembly of
//! the streaming path.
//!
//! **One-shot requests** ([`Batcher`]): the PJRT backend amortizes XLA
//! dispatch over batched sequences (the AOT artifact is compiled for a
//! fixed batch dimension), so the coordinator collects requests until
//! the batch fills or a deadline expires — the standard serving
//! trade-off between utilization and tail latency. The mixed-signal
//! backend executes uniform-shape batches in lockstep (one analog state
//! slot per sequence, one plan traversal per time step) — serve it with
//! `bucket_by_length` so every drained batch is a single lockstep group.
//!
//! **Streaming sessions** ([`SessionQueue`]): frames arrive
//! incrementally per session instead of as whole sequences, so there is
//! nothing to bucket — the queue buffers each live session's pushed
//! values and, per tick, hands the serving worker *one frame from every
//! session that has one* ([`SessionQueue::next_tick`]), which the
//! backend advances through a single lockstep traversal
//! (`MixedSignalEngine::step_slots`). Sessions that pushed more than
//! one frame drain over consecutive ticks; sessions with nothing
//! pending simply sit out the tick, their analog state resident in
//! their slot.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// One queued classification request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Client-chosen id, echoed in the response. **Not** unique: two
    /// clients (or one careless client) may reuse an id concurrently.
    pub id: u64,
    /// Frame-major input values.
    pub sequence: Vec<f32>,
    /// When the request entered the queue (latency accounting).
    pub enqueued: Instant,
    /// Server-assigned routing key: the leader stamps each submission
    /// with a monotonic ticket and pairs drained requests back to their
    /// response channels by it, so duplicate client ids cannot
    /// cross-wire responses. 0 until the leader assigns it.
    pub ticket: u64,
}

impl Request {
    /// A request carrying `sequence`, enqueued now.
    pub fn new(id: u64, sequence: Vec<f32>) -> Request {
        Request { id, sequence, enqueued: Instant::now(), ticket: 0 }
    }
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are pending.
    pub max_batch: usize,
    /// Dispatch a partial batch after this long.
    pub max_wait: Duration,
    /// When set, a drained batch only ever contains sequences of one
    /// length (FIFO within the length bucket, oldest bucket first).
    /// Backends that require uniform batch shapes — the PJRT executable
    /// is compiled for a fixed [T, B, d] — must be served with this on;
    /// the default (off) passes ragged batches through untouched, which
    /// the golden and mixed-signal backends handle per-sequence.
    pub bucket_by_length: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            bucket_by_length: false,
        }
    }
}

impl BatchPolicy {
    /// Policy with default bucketing (off) — the common construction.
    pub fn new(max_batch: usize, max_wait: Duration) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait, bucket_by_length: false }
    }

    /// Same policy with length bucketing on (uniform-shape backends).
    pub fn bucketed(self) -> BatchPolicy {
        BatchPolicy { bucket_by_length: true, ..self }
    }
}

impl From<&crate::config::ServeConfig> for BatchPolicy {
    fn from(c: &crate::config::ServeConfig) -> BatchPolicy {
        // no clamping here: Batcher::new is the single authority for
        // rejecting a zero max_batch
        BatchPolicy {
            max_batch: c.max_batch,
            max_wait: Duration::from_millis(c.max_wait_ms),
            bucket_by_length: false,
        }
    }
}

/// Accumulates requests and decides when a batch is ready.
#[derive(Debug)]
pub struct Batcher {
    /// The dispatch policy in force.
    pub policy: BatchPolicy,
    queue: Vec<Request>,
    oldest: Option<Instant>,
}

impl Batcher {
    /// An empty batcher with `policy`.
    pub fn new(policy: BatchPolicy) -> Batcher {
        // max_batch = 0 would make ready() true and drain() empty forever
        // — a busy-loop for any dispatch loop driving this. Clamp here so
        // every entry point (CLI flags, configs, tests) is covered.
        let policy = BatchPolicy { max_batch: policy.max_batch.max(1), ..policy };
        Batcher { policy, queue: Vec::new(), oldest: None }
    }

    /// Enqueue a request.
    pub fn push(&mut self, req: Request) {
        if self.queue.is_empty() {
            self.oldest = Some(req.enqueued);
        }
        self.queue.push(req);
    }

    /// Pending request count.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether no requests are pending.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// A batch is ready when full, or when the oldest request has waited
    /// past the deadline (and the queue is non-empty).
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.oldest {
            Some(t0) if !self.queue.is_empty() => {
                now.duration_since(t0) >= self.policy.max_wait
            }
            _ => false,
        }
    }

    /// Instant at which the oldest queued request times out, if any —
    /// what the leader thread sleeps toward between submissions.
    pub fn deadline(&self) -> Option<Instant> {
        if self.queue.is_empty() {
            None
        } else {
            self.oldest.map(|t0| t0 + self.policy.max_wait)
        }
    }

    /// Remove and return up to max_batch requests: plain FIFO by
    /// default; with `bucket_by_length`, the FIFO prefix restricted to
    /// the oldest request's sequence length (so the oldest request is
    /// always served first and uniform-shape backends never see a
    /// ragged batch).
    pub fn drain(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<Request> = if self.policy.bucket_by_length
            && !self.queue.is_empty()
            && self.queue[..n].iter().any(|r| {
                r.sequence.len() != self.queue[0].sequence.len()
            }) {
            // mixed-length prefix: one order-preserving partition pass —
            // O(queue) moves, not O(queue × batch) element shifts
            let len0 = self.queue[0].sequence.len();
            let max = self.policy.max_batch;
            let mut batch = Vec::with_capacity(n);
            let mut rest = Vec::with_capacity(self.queue.len());
            for req in self.queue.drain(..) {
                if batch.len() < max && req.sequence.len() == len0 {
                    batch.push(req);
                } else {
                    rest.push(req);
                }
            }
            self.queue = rest;
            batch
        } else {
            // plain FIFO, and the bucketed common case: a prefix that is
            // already uniform-length drains in place
            self.queue.drain(..n).collect()
        };
        // pushes arrive in enqueue order, so the remaining head is the
        // oldest survivor even after a bucketed (non-prefix) removal
        self.oldest = self.queue.first().map(|r| r.enqueued);
        batch
    }
}

/// Per-session pending input of the streaming path.
#[derive(Debug)]
struct SessionBuf {
    /// Engine slot the session's analog state is pinned to.
    slot: usize,
    /// Pushed values not yet consumed by a tick (flat; frames are cut
    /// off the front `frame_width` values at a time).
    pending: VecDeque<f32>,
}

/// The session-aware companion of [`Batcher`]: buffers incrementally
/// pushed frames per live session and assembles lockstep ticks. Keyed
/// by session id in a `BTreeMap`, so tick composition is deterministic
/// (ascending session id) — convenient for tests, irrelevant for
/// results, which are bit-exact per slot regardless of interleaving.
///
/// Pending input is unbounded: backpressure is the client's ack — the
/// serving worker only replies `Pushed` after a push's frames are
/// consumed, so a client that waits for acks (everything in this repo
/// does) keeps at most one push in flight per session. A client that
/// fires `push_frames_nowait` without ever draining acks can grow the
/// buffer without limit; a per-session cap is future work if untrusted
/// clients ever reach this queue.
#[derive(Debug)]
pub struct SessionQueue {
    frame_width: usize,
    sessions: BTreeMap<u64, SessionBuf>,
}

impl SessionQueue {
    /// `frame_width` = input values per time step (the network's
    /// `dims[0]`); pushed payloads are cut into frames of this width.
    pub fn new(frame_width: usize) -> SessionQueue {
        assert!(frame_width >= 1, "frame width must be positive");
        SessionQueue { frame_width, sessions: BTreeMap::new() }
    }

    /// Values per complete frame (the network's input width).
    pub fn frame_width(&self) -> usize {
        self.frame_width
    }

    /// Register a live session on engine slot `slot`.
    pub fn open(&mut self, session: u64, slot: usize) {
        let prev = self.sessions.insert(
            session,
            SessionBuf { slot, pending: VecDeque::new() },
        );
        debug_assert!(prev.is_none(), "session {session} opened twice");
    }

    /// Whether `session` has an assembly queue.
    pub fn contains(&self, session: u64) -> bool {
        self.sessions.contains_key(&session)
    }

    /// Engine slot of a live session.
    pub fn slot(&self, session: u64) -> Option<usize> {
        self.sessions.get(&session).map(|b| b.slot)
    }

    /// Live sessions registered.
    pub fn live(&self) -> usize {
        self.sessions.len()
    }

    /// Append pushed values to a session's pending input. Returns the
    /// number of full frames this push completed — counting a frame
    /// finished by previously buffered residue values, so the count a
    /// client paces itself on is the frames that will actually advance.
    /// `None` (payload dropped) for unknown sessions.
    pub fn push(&mut self, session: u64, values: &[f32]) -> Option<usize> {
        let w = self.frame_width;
        match self.sessions.get_mut(&session) {
            Some(buf) => {
                let before = buf.pending.len();
                buf.pending.extend(values.iter().copied());
                Some(buf.pending.len() / w - before / w)
            }
            None => None,
        }
    }

    /// Unregister a session, returning its slot (to be released back to
    /// the backend's pool). Pending values that never formed a full
    /// frame — or frames not yet ticked — are dropped with it: close is
    /// a statement that the sequence ends *now*.
    pub fn close(&mut self, session: u64) -> Option<usize> {
        self.sessions.remove(&session).map(|b| b.slot)
    }

    /// True while any session has at least one full frame pending.
    pub fn has_ready(&self) -> bool {
        self.sessions
            .values()
            .any(|b| b.pending.len() >= self.frame_width)
    }

    /// Assemble one lockstep tick: pop one frame from every session
    /// with a full frame pending, filling `slots` (engine slot ids) and
    /// `frames` (packed values, `frame_width` per slot, in `slots`
    /// order). Returns the number of sessions advancing this tick; the
    /// output buffers are caller-owned scratch, cleared here.
    pub fn next_tick(&mut self, slots: &mut Vec<usize>, frames: &mut Vec<f32>) -> usize {
        slots.clear();
        frames.clear();
        for buf in self.sessions.values_mut() {
            if buf.pending.len() >= self.frame_width {
                slots.push(buf.slot);
                for _ in 0..self.frame_width {
                    frames.push(buf.pending.pop_front().expect("len checked"));
                }
            }
        }
        slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: Instant) -> Request {
        Request { id, sequence: vec![0.0; 4], enqueued: t, ticket: 0 }
    }

    #[test]
    fn fills_then_fires() {
        let mut b = Batcher::new(BatchPolicy::new(3, Duration::from_secs(10)));
        let t = Instant::now();
        b.push(req(1, t));
        b.push(req(2, t));
        assert!(!b.ready(t));
        b.push(req(3, t));
        assert!(b.ready(t));
        let batch = b.drain();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_partial_batch() {
        let mut b = Batcher::new(BatchPolicy::new(100, Duration::from_millis(1)));
        let t0 = Instant::now();
        b.push(req(1, t0));
        assert!(!b.ready(t0));
        let later = t0 + Duration::from_millis(2);
        assert!(b.ready(later));
        assert_eq!(b.drain().len(), 1);
    }

    #[test]
    fn fifo_overflow_keeps_remainder() {
        let mut b = Batcher::new(BatchPolicy::new(2, Duration::from_secs(1)));
        let t = Instant::now();
        for i in 0..5 {
            b.push(req(i, t));
        }
        assert_eq!(b.drain().iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert!(b.ready(t)); // still ≥ max_batch queued
    }

    #[test]
    fn empty_queue_never_ready() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now() + Duration::from_secs(60)));
    }

    #[test]
    fn empty_flush_is_a_noop() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.drain().is_empty());
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        assert!(b.deadline().is_none());
        // draining an empty queue must not fabricate a deadline either
        assert!(!b.ready(Instant::now() + Duration::from_secs(60)));
    }

    #[test]
    fn deadline_tracks_oldest_and_clears_on_drain() {
        let mut b = Batcher::new(BatchPolicy::new(100, Duration::from_millis(10)));
        let t0 = Instant::now();
        b.push(req(1, t0));
        b.push(req(2, t0 + Duration::from_millis(5)));
        // the deadline belongs to the *oldest* request
        assert_eq!(b.deadline(), Some(t0 + Duration::from_millis(10)));
        b.drain();
        assert!(b.deadline().is_none());
    }

    #[test]
    fn timeout_fires_partial_batch_then_deadline_advances() {
        let mut b = Batcher::new(BatchPolicy::new(2, Duration::from_millis(1)));
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i, t0 + Duration::from_millis(i)));
        }
        // overflow drain takes the first two; the remainder's deadline
        // is re-anchored on the now-oldest request
        assert_eq!(b.drain().len(), 2);
        assert_eq!(b.deadline(), Some(t0 + Duration::from_millis(2 + 1)));
        // the leftover fires alone once its own deadline passes
        assert!(!b.ready(t0 + Duration::from_millis(2)));
        assert!(b.ready(t0 + Duration::from_millis(4)));
        assert_eq!(b.drain().len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn mixed_sequence_lengths_pass_through_untouched() {
        let mut b = Batcher::new(BatchPolicy::new(8, Duration::from_secs(1)));
        let t = Instant::now();
        let lens = [1usize, 256, 0, 64, 7];
        for (i, &n) in lens.iter().enumerate() {
            b.push(Request {
                id: i as u64,
                sequence: vec![0.5; n],
                enqueued: t,
                ticket: 0,
            });
        }
        let batch = b.drain();
        assert_eq!(batch.len(), lens.len());
        // FIFO order and per-request payloads survive batching — with
        // bucketing OFF (the default), the batcher groups by arrival,
        // not by shape; ragged batches are the documented contract the
        // golden and mixed-signal backends serve per-sequence
        for (r, &n) in batch.iter().zip(lens.iter()) {
            assert_eq!(r.sequence.len(), n);
        }
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4]
        );
    }

    #[test]
    fn length_bucketing_never_mixes_shapes() {
        let mut b = Batcher::new(
            BatchPolicy::new(8, Duration::from_secs(1)).bucketed(),
        );
        let t = Instant::now();
        for (i, &n) in [4usize, 4, 2, 4, 2].iter().enumerate() {
            b.push(Request {
                id: i as u64,
                sequence: vec![0.5; n],
                enqueued: t + Duration::from_millis(i as u64),
                ticket: 0,
            });
        }
        // first drain: the oldest request's length (4), FIFO within it
        let a = b.drain();
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert!(a.iter().all(|r| r.sequence.len() == 4));
        // the leftovers' deadline re-anchors on the now-oldest request
        assert_eq!(b.deadline(), Some(t + Duration::from_millis(2) + Duration::from_secs(1)));
        // second drain: the remaining length-2 bucket
        let c = b.drain();
        assert_eq!(c.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert!(b.is_empty());
    }

    #[test]
    fn length_bucketing_respects_max_batch() {
        let mut b = Batcher::new(
            BatchPolicy::new(2, Duration::from_secs(1)).bucketed(),
        );
        let t = Instant::now();
        for i in 0..3u64 {
            b.push(Request {
                id: i,
                sequence: vec![0.0; 6],
                enqueued: t,
                ticket: 0,
            });
        }
        assert_eq!(b.drain().len(), 2);
        assert_eq!(b.drain().len(), 1);
    }

    #[test]
    fn zero_max_batch_is_clamped_not_livelocked() {
        let mut b = Batcher::new(BatchPolicy::new(0, Duration::from_millis(1)));
        assert_eq!(b.policy.max_batch, 1);
        // an empty queue must never report ready (len 0 >= 0 trap)
        assert!(!b.ready(Instant::now() + Duration::from_secs(1)));
        let t = Instant::now();
        b.push(req(1, t));
        assert!(b.ready(t));
        assert_eq!(b.drain().len(), 1);
    }

    #[test]
    fn policy_from_serve_config() {
        let sc = crate::config::ServeConfig {
            workers: 4,
            max_batch: 32,
            max_wait_ms: 7,
            ..Default::default()
        };
        let p = BatchPolicy::from(&sc);
        assert_eq!(p.max_batch, 32);
        assert_eq!(p.max_wait, Duration::from_millis(7));
    }

    #[test]
    fn session_queue_assembles_lockstep_ticks() {
        let mut q = SessionQueue::new(2);
        q.open(10, 0);
        q.open(11, 3);
        assert_eq!(q.live(), 2);
        assert_eq!(q.slot(11), Some(3));
        // session 10: two full frames; session 11: one frame + residue
        assert_eq!(q.push(10, &[1.0, 2.0, 3.0, 4.0]), Some(2));
        assert_eq!(q.push(11, &[5.0, 6.0, 7.0]), Some(1));
        assert_eq!(q.push(99, &[0.0]), None, "unknown session refused");
        let (mut slots, mut frames) = (Vec::new(), Vec::new());
        // tick 1: both sessions advance, ascending session-id order
        assert_eq!(q.next_tick(&mut slots, &mut frames), 2);
        assert_eq!(slots, vec![0, 3]);
        assert_eq!(frames, vec![1.0, 2.0, 5.0, 6.0]);
        // tick 2: only session 10 has a full frame left (11 holds half)
        assert_eq!(q.next_tick(&mut slots, &mut frames), 1);
        assert_eq!(slots, vec![0]);
        assert_eq!(frames, vec![3.0, 4.0]);
        assert!(!q.has_ready());
        assert_eq!(q.next_tick(&mut slots, &mut frames), 0);
        // the residue completes once the rest of the frame arrives —
        // and the completed frame is credited to the completing push
        assert_eq!(q.push(11, &[8.0]), Some(1));
        assert!(q.has_ready());
        assert_eq!(q.next_tick(&mut slots, &mut frames), 1);
        assert_eq!(frames, vec![7.0, 8.0]);
    }

    #[test]
    fn session_queue_close_returns_slot_and_drops_residue() {
        let mut q = SessionQueue::new(1);
        q.open(1, 5);
        assert_eq!(q.push(1, &[0.5, 0.6]), Some(2));
        assert_eq!(q.close(1), Some(5));
        assert_eq!(q.close(1), None, "double close must be visible");
        assert!(!q.contains(1));
        assert!(!q.has_ready(), "closed session's frames must be gone");
        assert_eq!(q.live(), 0);
    }
}
