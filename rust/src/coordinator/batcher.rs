//! Dynamic request batching.
//!
//! The PJRT backend amortizes XLA dispatch over batched sequences (the
//! AOT artifact is compiled for a fixed batch dimension), so the
//! coordinator collects requests until the batch fills or a deadline
//! expires — the standard serving trade-off between utilization and
//! tail latency. The mixed-signal backend processes per-sequence (a
//! physical core bank holds one sequence's state), so it drains batches
//! of size 1..n through the core array sequentially.

use std::time::{Duration, Instant};

/// One queued classification request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub sequence: Vec<f32>,
    pub enqueued: Instant,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) }
    }
}

impl From<&crate::config::ServeConfig> for BatchPolicy {
    fn from(c: &crate::config::ServeConfig) -> BatchPolicy {
        // no clamping here: Batcher::new is the single authority for
        // rejecting a zero max_batch
        BatchPolicy {
            max_batch: c.max_batch,
            max_wait: Duration::from_millis(c.max_wait_ms),
        }
    }
}

/// Accumulates requests and decides when a batch is ready.
#[derive(Debug)]
pub struct Batcher {
    pub policy: BatchPolicy,
    queue: Vec<Request>,
    oldest: Option<Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        // max_batch = 0 would make ready() true and drain() empty forever
        // — a busy-loop for any dispatch loop driving this. Clamp here so
        // every entry point (CLI flags, configs, tests) is covered.
        let policy = BatchPolicy { max_batch: policy.max_batch.max(1), ..policy };
        Batcher { policy, queue: Vec::new(), oldest: None }
    }

    pub fn push(&mut self, req: Request) {
        if self.queue.is_empty() {
            self.oldest = Some(req.enqueued);
        }
        self.queue.push(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// A batch is ready when full, or when the oldest request has waited
    /// past the deadline (and the queue is non-empty).
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.oldest {
            Some(t0) if !self.queue.is_empty() => {
                now.duration_since(t0) >= self.policy.max_wait
            }
            _ => false,
        }
    }

    /// Instant at which the oldest queued request times out, if any —
    /// what the leader thread sleeps toward between submissions.
    pub fn deadline(&self) -> Option<Instant> {
        if self.queue.is_empty() {
            None
        } else {
            self.oldest.map(|t0| t0 + self.policy.max_wait)
        }
    }

    /// Remove and return up to max_batch requests (FIFO).
    pub fn drain(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<Request> = self.queue.drain(..n).collect();
        self.oldest = self.queue.first().map(|r| r.enqueued);
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: Instant) -> Request {
        Request { id, sequence: vec![0.0; 4], enqueued: t }
    }

    #[test]
    fn fills_then_fires() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        b.push(req(1, t));
        b.push(req(2, t));
        assert!(!b.ready(t));
        b.push(req(3, t));
        assert!(b.ready(t));
        let batch = b.drain();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_partial_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        let t0 = Instant::now();
        b.push(req(1, t0));
        assert!(!b.ready(t0));
        let later = t0 + Duration::from_millis(2);
        assert!(b.ready(later));
        assert_eq!(b.drain().len(), 1);
    }

    #[test]
    fn fifo_overflow_keeps_remainder() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        let t = Instant::now();
        for i in 0..5 {
            b.push(req(i, t));
        }
        assert_eq!(b.drain().iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert!(b.ready(t)); // still ≥ max_batch queued
    }

    #[test]
    fn empty_queue_never_ready() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now() + Duration::from_secs(60)));
    }

    #[test]
    fn empty_flush_is_a_noop() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.drain().is_empty());
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        assert!(b.deadline().is_none());
        // draining an empty queue must not fabricate a deadline either
        assert!(!b.ready(Instant::now() + Duration::from_secs(60)));
    }

    #[test]
    fn deadline_tracks_oldest_and_clears_on_drain() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        });
        let t0 = Instant::now();
        b.push(req(1, t0));
        b.push(req(2, t0 + Duration::from_millis(5)));
        // the deadline belongs to the *oldest* request
        assert_eq!(b.deadline(), Some(t0 + Duration::from_millis(10)));
        b.drain();
        assert!(b.deadline().is_none());
    }

    #[test]
    fn timeout_fires_partial_batch_then_deadline_advances() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        });
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i, t0 + Duration::from_millis(i)));
        }
        // overflow drain takes the first two; the remainder's deadline
        // is re-anchored on the now-oldest request
        assert_eq!(b.drain().len(), 2);
        assert_eq!(b.deadline(), Some(t0 + Duration::from_millis(2 + 1)));
        // the leftover fires alone once its own deadline passes
        assert!(!b.ready(t0 + Duration::from_millis(2)));
        assert!(b.ready(t0 + Duration::from_millis(4)));
        assert_eq!(b.drain().len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn mixed_sequence_lengths_pass_through_untouched() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(1),
        });
        let t = Instant::now();
        let lens = [1usize, 256, 0, 64, 7];
        for (i, &n) in lens.iter().enumerate() {
            b.push(Request {
                id: i as u64,
                sequence: vec![0.5; n],
                enqueued: t,
            });
        }
        let batch = b.drain();
        assert_eq!(batch.len(), lens.len());
        // FIFO order and per-request payloads survive batching — the
        // batcher groups by arrival, not by shape; shape handling is the
        // backend's contract
        for (r, &n) in batch.iter().zip(lens.iter()) {
            assert_eq!(r.sequence.len(), n);
        }
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4]
        );
    }

    #[test]
    fn zero_max_batch_is_clamped_not_livelocked() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 0,
            max_wait: Duration::from_millis(1),
        });
        assert_eq!(b.policy.max_batch, 1);
        // an empty queue must never report ready (len 0 >= 0 trap)
        assert!(!b.ready(Instant::now() + Duration::from_secs(1)));
        let t = Instant::now();
        b.push(req(1, t));
        assert!(b.ready(t));
        assert_eq!(b.drain().len(), 1);
    }

    #[test]
    fn policy_from_serve_config() {
        let sc = crate::config::ServeConfig {
            workers: 4,
            max_batch: 32,
            max_wait_ms: 7,
        };
        let p = BatchPolicy::from(&sc);
        assert_eq!(p.max_batch, 32);
        assert_eq!(p.max_wait, Duration::from_millis(7));
    }
}
