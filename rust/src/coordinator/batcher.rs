//! Dynamic request batching.
//!
//! The PJRT backend amortizes XLA dispatch over batched sequences (the
//! AOT artifact is compiled for a fixed batch dimension), so the
//! coordinator collects requests until the batch fills or a deadline
//! expires — the standard serving trade-off between utilization and
//! tail latency. The mixed-signal backend processes per-sequence (a
//! physical core bank holds one sequence's state), so it drains batches
//! of size 1..n through the core array sequentially.

use std::time::{Duration, Instant};

/// One queued classification request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub sequence: Vec<f32>,
    pub enqueued: Instant,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) }
    }
}

/// Accumulates requests and decides when a batch is ready.
#[derive(Debug)]
pub struct Batcher {
    pub policy: BatchPolicy,
    queue: Vec<Request>,
    oldest: Option<Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, queue: Vec::new(), oldest: None }
    }

    pub fn push(&mut self, req: Request) {
        if self.queue.is_empty() {
            self.oldest = Some(req.enqueued);
        }
        self.queue.push(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// A batch is ready when full, or when the oldest request has waited
    /// past the deadline (and the queue is non-empty).
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.oldest {
            Some(t0) if !self.queue.is_empty() => {
                now.duration_since(t0) >= self.policy.max_wait
            }
            _ => false,
        }
    }

    /// Remove and return up to max_batch requests (FIFO).
    pub fn drain(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<Request> = self.queue.drain(..n).collect();
        self.oldest = self.queue.first().map(|r| r.enqueued);
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: Instant) -> Request {
        Request { id, sequence: vec![0.0; 4], enqueued: t }
    }

    #[test]
    fn fills_then_fires() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        b.push(req(1, t));
        b.push(req(2, t));
        assert!(!b.ready(t));
        b.push(req(3, t));
        assert!(b.ready(t));
        let batch = b.drain();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_partial_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        let t0 = Instant::now();
        b.push(req(1, t0));
        assert!(!b.ready(t0));
        let later = t0 + Duration::from_millis(2);
        assert!(b.ready(later));
        assert_eq!(b.drain().len(), 1);
    }

    #[test]
    fn fifo_overflow_keeps_remainder() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        let t = Instant::now();
        for i in 0..5 {
            b.push(req(i, t));
        }
        assert_eq!(b.drain().iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert!(b.ready(t)); // still ≥ max_batch queued
    }

    #[test]
    fn empty_queue_never_ready() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now() + Duration::from_secs(60)));
    }
}
