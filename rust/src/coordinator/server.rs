//! Thread-based serving loop (tokio is not in the offline crate set; the
//! workload — long sequences through a single-core simulator — is CPU-
//! bound, so an async reactor would buy nothing here anyway).
//!
//! Architecture: clients submit requests over an mpsc channel; the
//! leader thread runs the batcher; worker backends classify and push
//! results back through per-request response channels. Backends are
//! pluggable ([`Backend`]): golden model, mixed-signal engine, or the
//! PJRT executable.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, Batcher, Request};
use crate::coordinator::metrics::LatencyRecorder;

/// A sequence classifier backend. Not required to be `Send`: the PJRT
/// executable wraps non-Send XLA handles, so backends are *constructed on
/// the server thread* via the factory passed to [`Server::spawn_with`].
pub trait Backend {
    fn name(&self) -> &str;
    /// Classify a batch of sequences (all the same length).
    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize>;
}

/// Response to one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub label: usize,
    pub latency: Duration,
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking classify: submit and wait.
    pub fn classify(&self, id: u64, sequence: Vec<f32>) -> Response {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(
                Request { id, sequence, enqueued: Instant::now() },
                rtx,
            ))
            .expect("server gone");
        rrx.recv().expect("server dropped response")
    }

    /// Fire-and-forget submit returning the response receiver.
    pub fn submit(&self, id: u64, sequence: Vec<f32>) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(
                Request { id, sequence, enqueued: Instant::now() },
                rtx,
            ))
            .expect("server gone");
        rrx
    }
}

/// A running server; join() returns the final metrics.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: thread::JoinHandle<LatencyRecorder>,
}

impl Server {
    /// Spawn the leader loop with a `Send` backend.
    pub fn spawn(backend: Box<dyn Backend + Send>, policy: BatchPolicy) -> Server {
        Server::spawn_with(move || backend as Box<dyn Backend>, policy)
    }

    /// Spawn the leader loop, constructing the backend *on* the server
    /// thread (required for PJRT, whose handles are not `Send`).
    pub fn spawn_with<F>(factory: F, policy: BatchPolicy) -> Server
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = thread::spawn(move || {
            let mut backend = factory();
            let mut batcher = Batcher::new(policy);
            let mut waiters: Vec<(u64, mpsc::Sender<Response>, Instant)> =
                Vec::new();
            let mut metrics = LatencyRecorder::new();
            let mut open = true;
            while open || !batcher.is_empty() {
                // Pull at least one message (with a deadline so partial
                // batches still fire), then drain whatever else arrived.
                let timeout = policy.max_wait.max(Duration::from_micros(100));
                match rx.recv_timeout(timeout) {
                    Ok(Msg::Submit(req, rtx)) => {
                        waiters.push((req.id, rtx, req.enqueued));
                        batcher.push(req);
                        while let Ok(m) = rx.try_recv() {
                            match m {
                                Msg::Submit(req, rtx) => {
                                    waiters.push((req.id, rtx, req.enqueued));
                                    batcher.push(req);
                                }
                                Msg::Shutdown => open = false,
                            }
                        }
                    }
                    Ok(Msg::Shutdown) => open = false,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
                let now = Instant::now();
                if batcher.ready(now) || (!open && !batcher.is_empty()) {
                    let batch = batcher.drain();
                    let seqs: Vec<Vec<f32>> =
                        batch.iter().map(|r| r.sequence.clone()).collect();
                    let labels = backend.classify_batch(&seqs);
                    for (req, label) in batch.iter().zip(labels) {
                        let pos = waiters
                            .iter()
                            .position(|(id, _, _)| *id == req.id)
                            .expect("response channel lost");
                        let (_, rtx, enq) = waiters.swap_remove(pos);
                        let latency = enq.elapsed();
                        metrics.record(latency);
                        let _ = rtx.send(Response { id: req.id, label, latency });
                    }
                }
            }
            metrics
        });
        Server { tx, handle }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Stop accepting requests, drain the queue, return metrics.
    pub fn shutdown(self) -> LatencyRecorder {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.join().expect("server thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test backend: label = round(sum of the sequence) mod 10.
    struct SumBackend;

    impl Backend for SumBackend {
        fn name(&self) -> &str {
            "sum"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            seqs.iter()
                .map(|s| (s.iter().sum::<f32>().round() as usize) % 10)
                .collect()
        }
    }

    #[test]
    fn serves_blocking_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let client = server.client();
        let r = client.classify(1, vec![1.0, 2.0]);
        assert_eq!(r.label, 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        );
        let client = server.client();
        let receivers: Vec<_> = (0..20)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.label, i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 20);
        assert!(metrics.throughput() > 0.0);
    }

    #[test]
    fn shutdown_drains_pending() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy { max_batch: 1000, max_wait: Duration::from_secs(60) },
        );
        let client = server.client();
        let rxs: Vec<_> = (0..5).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown(); // must flush despite huge deadline
        assert_eq!(metrics.items, 5);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }
}
