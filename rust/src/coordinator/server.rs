//! Thread-based serving loop (tokio is not in the offline crate set; the
//! workload — long sequences through the simulators — is CPU-bound, so
//! an async reactor would buy nothing here anyway).
//!
//! Architecture: clients submit requests over an mpsc channel to a
//! *leader* thread that runs the dynamic batcher. The leader stamps
//! every submission with a monotonic **ticket** and keeps the response
//! channel keyed by it, so drained requests pair back to their waiters
//! in O(1) — client-chosen ids are echoed, never used for routing
//! (duplicates are harmless). Ready batches are pushed onto a shared
//! work queue feeding N *worker* threads, each of which owns one
//! backend instance — constructed *on* the worker thread via the
//! factory it was spawned with, because the PJRT backend wraps
//! non-`Send` XLA handles.
//!
//! Failure containment: a backend panic fails only the requests of the
//! batch it was classifying — the panic is caught, every request of the
//! batch receives [`ServeError::BackendPanicked`], and the worker keeps
//! serving. A worker that dies outright (e.g. its factory panicked)
//! only loses its own metrics; [`Server::shutdown`] joins what survives
//! and returns the merged [`LatencyRecorder`] instead of propagating.
//!
//! Backends are pluggable ([`Backend`]): golden model, mixed-signal
//! engine, or the PJRT executable.
//!
//! **Streaming sessions** ([`StreamServer`]): the second serving mode,
//! for frame-by-frame traffic whose state outlives any single request.
//! A client opens a session, pushes frames incrementally, polls running
//! logits, and closes for the final label. Sessions have **worker
//! affinity**: the leader pins each session to one worker at open (the
//! session's slot — its analog state — lives in that worker's backend),
//! routes every subsequent op of the session to the same worker, and
//! rejects opens beyond `workers × slots-per-worker` with
//! [`ServeError::Busy`] (sessions are resident state, so exhaustion is
//! rejected, not queued — see docs/adr/003). Within a worker, all live
//! sessions with pending frames advance together, one lockstep
//! traversal per tick ([`SessionQueue`] assembles the ticks).

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, Batcher, Request, SessionQueue};
use crate::coordinator::metrics::LatencyRecorder;
use crate::energy::EnergyMeter;
use crate::satsim::DeltaCounters;

/// A sequence classifier backend. Not required to be `Send`: the PJRT
/// executable wraps non-Send XLA handles, so backends are *constructed
/// on their worker thread* via the factory passed to
/// [`Server::spawn_with`] / [`Server::spawn_sharded`].
pub trait Backend {
    /// Short backend label for logs and summaries.
    fn name(&self) -> &str;
    /// Classify a batch of sequences. The default serving contract is
    /// **ragged** — sequences may differ in length: the golden backend
    /// processes them per-sequence and the mixed-signal backend groups
    /// them by length for its lockstep batch path. Backends compiled
    /// for one batch shape (PJRT) must be served with
    /// [`BatchPolicy::bucketed`], which guarantees uniform-length
    /// batches at the leader; the mixed-signal backend is fastest under
    /// the same policy (one lockstep group per batch).
    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize>;

    /// The backend's streaming-session interface, when it has one.
    /// `None` (the default) means the backend can only classify whole
    /// sequences — [`StreamServer`] fails every session op against it.
    /// Backends that *can* pin per-session state to resident slots
    /// (golden with provisioned session nets, mixed-signal with a
    /// provisioned engine slot pool) return themselves.
    fn streaming(&mut self) -> Option<&mut dyn SessionBackend> {
        None
    }

    /// Delta-sparsity skip counters accumulated by this backend's
    /// engine (ADR-005), if it has any. `None` (the default) means the
    /// backend has no delta machinery; the worker loops fold a `Some`
    /// into their [`LatencyRecorder`] when they exit, so the shutdown
    /// merge reports fleet-wide skip ratios alongside the latencies.
    fn delta_stats(&self) -> Option<DeltaCounters> {
        None
    }

    /// Live cumulative energy meter of this backend's simulated cores
    /// (§4.2 accounting: cap events, switch toggles, conversions,
    /// joules), if it has one. `None` (the default) means the backend
    /// has no energy machinery — the golden and PJRT backends burn no
    /// simulated charge. Follows the same lifecycle as
    /// [`Backend::delta_stats`]: the worker loops fold a `Some` into
    /// their [`LatencyRecorder`] at exit, and the shutdown merge sums
    /// the meters across workers so the end-of-run summary and the
    /// `/metrics` endpoint report fleet-wide joules per step.
    fn energy_stats(&self) -> Option<EnergyMeter> {
        None
    }
}

/// Streaming-session counterpart of [`Backend`]: state that outlives a
/// request. A session leases one backend **slot** at open, pushes
/// frames incrementally (any subset of live sessions advances together
/// through one lockstep traversal per tick), can be polled for running
/// logits mid-sequence, and frees its slot at close. The serving
/// guarantee is the one-shot guarantee: a streamed sequence yields
/// **bit-identical** logits to a single `classify_batch` of the same
/// frames (tests/stream_parity.rs).
pub trait SessionBackend {
    /// Resident session slots this backend holds (live + free).
    fn session_capacity(&self) -> usize;
    /// Input values per frame (one time step) — pushed payloads are cut
    /// into frames of this width.
    fn frame_width(&self) -> usize;
    /// Lease a slot for a new session, resetting it to
    /// sequence-boundary state. `None` when every slot is leased — the
    /// caller rejects with [`ServeError::Busy`].
    fn open_session(&mut self) -> Option<usize>;
    /// Advance the listed sessions by one frame each, in lockstep.
    /// `frames` packs `frame_width()` values per listed slot, in
    /// `slots` order.
    fn step_sessions(&mut self, slots: &[usize], frames: &[f32]);
    /// Running logits of a live session — the partial-sequence readout
    /// over the frames consumed so far.
    fn session_logits(&self, slot: usize) -> Vec<f32>;
    /// Close a session: final label over the frames seen; the slot
    /// returns to the free pool.
    fn close_session(&mut self, slot: usize) -> usize;
}

/// Why a request failed instead of producing a label.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The backend panicked while classifying this request's batch; the
    /// payload message is preserved for diagnosis.
    BackendPanicked(String),
    /// The server (leader or the serving worker) went away before a
    /// response could be produced — or, on the streaming path, the
    /// session is not (or no longer) known to the server.
    Lost,
    /// Every streaming-session slot is leased: the open was rejected.
    /// Sessions are resident state, so unlike one-shot requests they
    /// are not queued — the client retries after closing something (or
    /// the operator provisions more slots via `--sessions`/workers).
    Busy,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BackendPanicked(msg) => {
                write!(f, "backend panicked: {msg}")
            }
            ServeError::Lost => write!(f, "server dropped the response"),
            ServeError::Busy => {
                write!(f, "all streaming session slots are busy")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Response to one request.
#[derive(Debug, Clone)]
pub struct Response {
    /// Mirrors the request id.
    pub id: u64,
    /// The served label, or why serving failed.
    pub result: Result<usize, ServeError>,
    /// Queue + service time for this request.
    pub latency: Duration,
}

impl Response {
    /// The served label, for drivers that expect success; panics with
    /// the serving error otherwise.
    pub fn label(&self) -> usize {
        match &self.result {
            Ok(l) => *l,
            // lint: allow(panic, documented contract: drivers calling label opt into panicking on a serve error)
            Err(e) => panic!("request {} failed: {e}", self.id),
        }
    }
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// One unit of worker work: a drained batch with its response channels.
type Job = Vec<(Request, mpsc::Sender<Response>)>;

/// A per-worker backend constructor, invoked on the worker's own thread.
type BoxedFactory = Box<dyn FnOnce() -> Box<dyn Backend> + Send>;

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking classify: submit and wait. Never panics — if the server
    /// (or the worker holding this request) dies, the response carries
    /// [`ServeError::Lost`].
    pub fn classify(&self, id: u64, sequence: Vec<f32>) -> Response {
        let (rtx, rrx) = mpsc::channel();
        let req = Request::new(id, sequence);
        let enqueued = req.enqueued;
        if self.tx.send(Msg::Submit(req, rtx)).is_err() {
            return Response {
                id,
                result: Err(ServeError::Lost),
                latency: enqueued.elapsed(),
            };
        }
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Response {
                id,
                result: Err(ServeError::Lost),
                latency: enqueued.elapsed(),
            },
        }
    }

    /// Fire-and-forget submit returning the response receiver. If the
    /// server is gone the receiver's `recv()` errors immediately.
    pub fn submit(&self, id: u64, sequence: Vec<f32>) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(Request::new(id, sequence), rtx));
        rrx
    }
}

/// A running server; `shutdown()` drains the queue and returns the
/// merged metrics of all workers.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    /// The leader returns its own recorder: requests it had to drop
    /// (every worker dead) are counted there as errors, so losses are
    /// visible in the merged metrics, not just client-side.
    leader: thread::JoinHandle<LatencyRecorder>,
    workers: Vec<thread::JoinHandle<LatencyRecorder>>,
}

impl Server {
    /// Spawn a single-worker server with a `Send` backend.
    pub fn spawn(backend: Box<dyn Backend + Send>, policy: BatchPolicy) -> Server {
        Server::spawn_with(move || backend as Box<dyn Backend>, policy)
    }

    /// Spawn a single-worker server, constructing the backend *on* the
    /// worker thread (required for PJRT, whose handles are not `Send`).
    pub fn spawn_with<F>(factory: F, policy: BatchPolicy) -> Server
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        Server::spawn_parts(vec![Box::new(factory)], policy)
    }

    /// Spawn a sharded server: `workers` threads (clamped to ≥ 1), each
    /// constructing its own backend instance by calling `factory` on its
    /// own thread, all fed from one work-distribution queue. The backend
    /// instances themselves never cross threads, preserving the
    /// non-`Send` PJRT constraint; only the factory must be `Send + Sync`.
    pub fn spawn_sharded<F>(factory: F, policy: BatchPolicy, workers: usize) -> Server
    where
        F: Fn() -> Box<dyn Backend> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let factories: Vec<BoxedFactory> = (0..workers.max(1))
            .map(|_| {
                let f = Arc::clone(&factory);
                Box::new(move || (*f)()) as BoxedFactory
            })
            .collect();
        Server::spawn_parts(factories, policy)
    }

    fn spawn_parts(factories: Vec<BoxedFactory>, policy: BatchPolicy) -> Server {
        assert!(!factories.is_empty(), "server needs at least one worker");
        let (tx, rx) = mpsc::channel::<Msg>();
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let workers: Vec<thread::JoinHandle<LatencyRecorder>> = factories
            .into_iter()
            .enumerate()
            .map(|(w, factory)| {
                let job_rx = Arc::clone(&job_rx);
                thread::Builder::new()
                    .name(format!("minimalist-worker-{w}"))
                    .spawn(move || worker_loop(factory, job_rx))
                    // lint: allow(panic, construction-time spawn failure: no server exists yet to degrade)
                    .expect("spawning worker thread")
            })
            .collect();
        let leader = thread::Builder::new()
            .name("minimalist-leader".to_string())
            .spawn(move || leader_loop(rx, job_tx, policy))
            // lint: allow(panic, construction-time spawn failure: no server exists yet to degrade)
            .expect("spawning leader thread");
        Server { tx, leader, workers }
    }

    /// A cloneable submit handle to this server.
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Number of worker threads serving this instance.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting requests, drain the queue, return the merged
    /// metrics of every worker that survived. Thread panics are
    /// reported, not propagated — a dead worker costs its metrics, not
    /// the shutdown.
    pub fn shutdown(self) -> LatencyRecorder {
        let _ = self.tx.send(Msg::Shutdown);
        let leader_metrics = match self.leader.join() {
            Ok(m) => Some(m),
            Err(_) => {
                eprintln!(
                    "minimalist-server: leader thread panicked; \
                     in-flight requests were dropped"
                );
                None
            }
        };
        let mut merged: Option<LatencyRecorder> = None;
        for w in self.workers {
            match w.join() {
                Ok(m) => match merged.as_mut() {
                    Some(acc) => acc.merge(&m),
                    None => merged = Some(m),
                },
                Err(_) => eprintln!(
                    "minimalist-server: a worker thread panicked; \
                     its metrics are lost"
                ),
            }
        }
        let mut merged = merged.unwrap_or_default();
        if let Some(lm) = leader_metrics {
            merged.merge(&lm);
        }
        merged
    }
}

/// Stamp a submission with the next routing ticket and queue it.
fn enqueue(
    batcher: &mut Batcher,
    waiters: &mut HashMap<u64, mpsc::Sender<Response>>,
    next_ticket: &mut u64,
    mut req: Request,
    rtx: mpsc::Sender<Response>,
) {
    req.ticket = *next_ticket;
    *next_ticket += 1;
    waiters.insert(req.ticket, rtx);
    batcher.push(req);
}

/// The leader: accepts submissions, runs the batching policy, pairs
/// each drained request with its response channel by ticket, and pushes
/// the batch onto the work queue. Exits (dropping the queue sender,
/// which stops the workers) once shut down and fully drained. Returns a
/// recorder holding only the error count of requests it had to drop.
fn leader_loop(
    rx: mpsc::Receiver<Msg>,
    job_tx: mpsc::Sender<Job>,
    policy: BatchPolicy,
) -> LatencyRecorder {
    let mut lost = LatencyRecorder::new();
    let mut batcher = Batcher::new(policy);
    let mut waiters: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
    let mut next_ticket: u64 = 1; // 0 marks "not yet assigned"
    let mut open = true;
    while open || !batcher.is_empty() {
        // Block until the next message or the oldest request's deadline
        // (so partial batches still fire), then drain whatever else
        // already arrived.
        let timeout = batcher
            .deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(policy.max_wait)
            .max(Duration::from_micros(100));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(req, rtx)) => {
                enqueue(&mut batcher, &mut waiters, &mut next_ticket, req, rtx);
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Submit(req, rtx) => {
                            enqueue(
                                &mut batcher,
                                &mut waiters,
                                &mut next_ticket,
                                req,
                                rtx,
                            );
                        }
                        Msg::Shutdown => open = false,
                    }
                }
            }
            Ok(Msg::Shutdown) => open = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
        // Dispatch every ready batch — with several queued batches this
        // is what spreads work across the idle workers.
        loop {
            let now = Instant::now();
            if !(batcher.ready(now) || (!open && !batcher.is_empty())) {
                break;
            }
            let batch = batcher.drain();
            if batch.is_empty() {
                break; // defensive: never dispatch (or spin on) empty jobs
            }
            let job: Job = batch
                .into_iter()
                .map(|req| {
                    let rtx = waiters
                        .remove(&req.ticket)
                        // lint: allow(panic, leader-local invariant: submit inserts the waiter before enqueueing the ticket)
                        .expect("waiter registered at submit");
                    (req, rtx)
                })
                .collect();
            if let Err(mpsc::SendError(job)) = job_tx.send(job) {
                // every worker died: this job's requests plus everything
                // still queued are lost — account them so the merged
                // metrics show the failure instead of "err=0"
                lost.record_error_n(
                    &ServeError::Lost,
                    (job.len() + waiters.len()) as u64,
                );
                return lost;
            }
        }
    }
    lost
}

/// Render a caught panic payload for the error response.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// One worker: construct the backend on this thread, then pull batches
/// off the shared queue until the leader hangs up. A backend panic
/// fails that batch's requests and the worker keeps serving (every
/// backend re-derives its per-sequence state from scratch on classify,
/// so a caught panic cannot corrupt later results).
fn worker_loop(
    factory: BoxedFactory,
    job_rx: Arc<Mutex<mpsc::Receiver<Job>>>,
) -> LatencyRecorder {
    let mut backend = factory();
    let mut metrics = LatencyRecorder::new();
    loop {
        // Hold the lock only while receiving — classification runs
        // unlocked so the other workers can keep pulling jobs.
        let job = {
            // lint: allow(panic, a poisoned job queue means a sibling worker died mid-recv; this worker cannot continue either)
            let rx = job_rx.lock().expect("job queue poisoned");
            rx.recv()
        };
        let Ok(mut job) = job else { break };
        // take, don't clone: the job is owned and the payloads are not
        // needed again after classification
        let seqs: Vec<Vec<f32>> = job
            .iter_mut()
            .map(|(r, _)| std::mem::take(&mut r.sequence))
            .collect();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || backend.classify_batch(&seqs),
        ));
        match outcome {
            Ok(labels) => {
                for ((req, rtx), label) in job.into_iter().zip(labels) {
                    let latency = req.enqueued.elapsed();
                    metrics.record(latency);
                    let _ = rtx.send(Response {
                        id: req.id,
                        result: Ok(label),
                        latency,
                    });
                }
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                let err = ServeError::BackendPanicked(msg);
                metrics.record_error_n(&err, job.len() as u64);
                for (req, rtx) in job {
                    let _ = rtx.send(Response {
                        id: req.id,
                        result: Err(err.clone()),
                        latency: req.enqueued.elapsed(),
                    });
                }
            }
        }
    }
    if let Some(d) = backend.delta_stats() {
        metrics.delta.merge(&d);
    }
    if let Some(m) = backend.energy_stats() {
        metrics.energy.merge(&m);
    }
    metrics
}

// ---------------------------------------------------------------------------
// Streaming sessions
// ---------------------------------------------------------------------------

/// One operation on a streaming session — the request half of the
/// session protocol. Clients normally use the typed methods on
/// [`StreamClient`] / [`StreamSession`] rather than building these.
#[derive(Debug, Clone)]
pub enum SessionRequest {
    /// Open a new session: lease a resident backend slot.
    Open,
    /// Append input values — one or more frames' worth; values that do
    /// not complete a frame are buffered until the rest arrives.
    PushFrames(Vec<f32>),
    /// Read the running logits over the frames consumed so far.
    PollLogits,
    /// End the sequence: final label, slot back to the free pool.
    Close,
}

/// The response half of the session protocol.
#[derive(Debug, Clone)]
pub enum SessionResponse {
    /// Session opened; `session` keys every later op.
    Opened { session: u64 },
    /// Push accepted and consumed; `frames` counts the full frames this
    /// push completed — including one finished by previously buffered
    /// residue values (values short of a frame are buffered until the
    /// rest of the frame arrives).
    Pushed { frames: usize },
    /// Running logits of the partial sequence.
    Logits(Vec<f32>),
    /// Final label; the session is gone.
    Closed { label: usize },
    /// The op failed ([`ServeError::Busy`] on open when every slot is
    /// leased; [`ServeError::Lost`] for unknown sessions or a dead
    /// worker).
    Failed(ServeError),
}

/// Leader-bound envelope: a session op with its response channel.
struct SessionMsg {
    session: u64,
    req: SessionRequest,
    rtx: mpsc::Sender<SessionResponse>,
}

enum StreamMsg {
    Op(SessionMsg),
    /// Worker→leader note: an open the leader admitted failed on the
    /// worker side (defensive pool exhaustion, or a backend without a
    /// streaming interface) — the leader must un-register the session
    /// and give the capacity back, or the admission counter leaks.
    OpenFailed { session: u64 },
    Shutdown,
}

/// Worker-bound envelope (the leader has already routed it).
struct SessionJob {
    session: u64,
    req: SessionRequest,
    rtx: mpsc::Sender<SessionResponse>,
    enqueued: Instant,
}

/// Handle for opening sessions on a running [`StreamServer`].
#[derive(Clone)]
pub struct StreamClient {
    tx: mpsc::Sender<StreamMsg>,
}

impl StreamClient {
    /// Open a session (blocking). [`ServeError::Busy`] when every slot
    /// across all workers is leased.
    pub fn open(&self) -> Result<StreamSession, ServeError> {
        let (rtx, rrx) = mpsc::channel();
        let msg = StreamMsg::Op(SessionMsg {
            session: 0,
            req: SessionRequest::Open,
            rtx,
        });
        if self.tx.send(msg).is_err() {
            return Err(ServeError::Lost);
        }
        match rrx.recv() {
            Ok(SessionResponse::Opened { session }) => {
                Ok(StreamSession { tx: self.tx.clone(), id: session })
            }
            Ok(SessionResponse::Failed(e)) => Err(e),
            Ok(_) | Err(_) => Err(ServeError::Lost),
        }
    }
}

/// One live streaming session. Dropping the handle without
/// [`StreamSession::close`] leaks the slot until shutdown — close is
/// what returns it to the pool.
///
/// Clones address the **same** server-side session (the id is the
/// identity — the HTTP front end keeps one handle in its registry and
/// clones it per request). [`StreamSession::close`] consumes one
/// handle and retires the session itself: ops on surviving clones fail
/// with [`ServeError::Lost`] from then on.
#[derive(Clone)]
pub struct StreamSession {
    tx: mpsc::Sender<StreamMsg>,
    /// Server-assigned session id (echoed in [`SessionResponse::Opened`]).
    pub id: u64,
}

impl StreamSession {
    fn submit(&self, req: SessionRequest) -> mpsc::Receiver<SessionResponse> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(StreamMsg::Op(SessionMsg { session: self.id, req, rtx }));
        rrx
    }

    fn roundtrip(&self, req: SessionRequest) -> Result<SessionResponse, ServeError> {
        match self.submit(req).recv() {
            Ok(SessionResponse::Failed(e)) => Err(e),
            Ok(r) => Ok(r),
            Err(_) => Err(ServeError::Lost),
        }
    }

    /// Push input values (one or more frames) and wait for them to be
    /// consumed; returns the number of full frames advanced.
    pub fn push_frames(&self, values: Vec<f32>) -> Result<usize, ServeError> {
        match self.roundtrip(SessionRequest::PushFrames(values))? {
            SessionResponse::Pushed { frames } => Ok(frames),
            _ => Err(ServeError::Lost),
        }
    }

    /// Fire-and-forget push returning the ack receiver — what a driver
    /// uses to keep several sessions' frames in flight at once, so the
    /// worker's tick sees them together and advances them in lockstep.
    pub fn push_frames_nowait(
        &self,
        values: Vec<f32>,
    ) -> mpsc::Receiver<SessionResponse> {
        self.submit(SessionRequest::PushFrames(values))
    }

    /// Running logits over the frames pushed (and consumed) so far —
    /// bit-identical to a one-shot classification of that prefix.
    pub fn logits(&self) -> Result<Vec<f32>, ServeError> {
        match self.roundtrip(SessionRequest::PollLogits)? {
            SessionResponse::Logits(l) => Ok(l),
            _ => Err(ServeError::Lost),
        }
    }

    /// Close the session: final label over every frame pushed; the slot
    /// returns to the free pool for the next open.
    pub fn close(self) -> Result<usize, ServeError> {
        match self.roundtrip(SessionRequest::Close)? {
            SessionResponse::Closed { label } => Ok(label),
            _ => Err(ServeError::Lost),
        }
    }
}

/// A running streaming-session server; `shutdown()` drains in-flight
/// ops and returns the merged metrics (per-frame push latencies, error
/// breakdown). Live sessions at shutdown are dropped — later ops on
/// their handles fail with [`ServeError::Lost`].
pub struct StreamServer {
    tx: mpsc::Sender<StreamMsg>,
    leader: thread::JoinHandle<LatencyRecorder>,
    workers: Vec<thread::JoinHandle<LatencyRecorder>>,
}

impl StreamServer {
    /// Spawn a streaming server: `workers` threads, each constructing
    /// its own streaming-capable backend via `factory` (on its own
    /// thread, as [`Server::spawn_sharded`] does), each holding
    /// `slots_per_worker` resident session slots. The leader admits at
    /// most `workers × slots_per_worker` live sessions and rejects the
    /// rest with [`ServeError::Busy`]; `slots_per_worker` must match
    /// what the factory provisions (the backend's own pool is the
    /// defensive second check).
    pub fn spawn<F>(factory: F, workers: usize, slots_per_worker: usize) -> StreamServer
    where
        F: Fn() -> Box<dyn Backend> + Send + Sync + 'static,
    {
        let n = workers.max(1);
        let factory = Arc::new(factory);
        let (tx, rx) = mpsc::channel::<StreamMsg>();
        let mut worker_txs = Vec::with_capacity(n);
        let workers: Vec<thread::JoinHandle<LatencyRecorder>> = (0..n)
            .map(|w| {
                let f = Arc::clone(&factory);
                let (jtx, jrx) = mpsc::channel::<SessionJob>();
                worker_txs.push(jtx);
                let leader_tx = tx.clone();
                thread::Builder::new()
                    .name(format!("minimalist-stream-worker-{w}"))
                    .spawn(move || {
                        stream_worker_loop(Box::new(move || (*f)()), jrx, leader_tx)
                    })
                    // lint: allow(panic, construction-time spawn failure: no server exists yet to degrade)
                    .expect("spawning stream worker thread")
            })
            .collect();
        let capacity = slots_per_worker.max(1);
        let leader = thread::Builder::new()
            .name("minimalist-stream-leader".to_string())
            .spawn(move || stream_leader_loop(rx, worker_txs, capacity))
            // lint: allow(panic, construction-time spawn failure: no server exists yet to degrade)
            .expect("spawning stream leader thread");
        StreamServer { tx, leader, workers }
    }

    /// A cloneable handle for opening sessions on this server.
    pub fn client(&self) -> StreamClient {
        StreamClient { tx: self.tx.clone() }
    }

    /// Number of worker threads (= backend instances).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting ops, drain what is queued, and return the merged
    /// metrics of the leader and every worker that survived (same
    /// containment policy as [`Server::shutdown`]).
    pub fn shutdown(self) -> LatencyRecorder {
        let _ = self.tx.send(StreamMsg::Shutdown);
        let mut merged = match self.leader.join() {
            Ok(m) => m,
            Err(_) => {
                eprintln!(
                    "minimalist-stream-server: leader thread panicked; \
                     in-flight session ops were dropped"
                );
                LatencyRecorder::new()
            }
        };
        for w in self.workers {
            match w.join() {
                Ok(m) => merged.merge(&m),
                Err(_) => eprintln!(
                    "minimalist-stream-server: a worker thread panicked; \
                     its sessions and metrics are lost"
                ),
            }
        }
        merged
    }
}

/// The streaming leader: owns the session table (session id → worker),
/// admits or rejects opens against the capacity, and forwards each
/// session's ops to its pinned worker. A worker whose channel is gone
/// is marked dead and excluded from placement — its capacity is lost,
/// not resurrected as a black hole that eats every subsequent open.
/// Returns a recorder holding the leader-side error counts (Busy
/// rejections, ops lost to dead workers).
fn stream_leader_loop(
    rx: mpsc::Receiver<StreamMsg>,
    worker_txs: Vec<mpsc::Sender<SessionJob>>,
    capacity: usize,
) -> LatencyRecorder {
    let mut rec = LatencyRecorder::new();
    let mut sessions: HashMap<u64, usize> = HashMap::new();
    let mut live = vec![0usize; worker_txs.len()];
    let mut dead = vec![false; worker_txs.len()];
    let mut next_session: u64 = 1;
    while let Ok(msg) = rx.recv() {
        let SessionMsg { session, req, rtx } = match msg {
            StreamMsg::Op(op) => op,
            StreamMsg::OpenFailed { session } => {
                // the worker could not actually lease a slot for an
                // admitted open: give the capacity back
                if let Some(w) = sessions.remove(&session) {
                    live[w] = live[w].saturating_sub(1);
                }
                continue;
            }
            StreamMsg::Shutdown => break,
        };
        match req {
            SessionRequest::Open => {
                // place on the least-loaded *alive* worker with a free
                // slot; a worker discovered dead at send time is marked
                // and the open re-placed on the next candidate — a
                // client's open only fails once no alive worker has
                // room, never because the probe happened to pick a
                // corpse first
                let mut rtx = rtx;
                loop {
                    let target = live
                        .iter()
                        .enumerate()
                        .filter(|&(w, &n)| !dead[w] && n < capacity)
                        .min_by_key(|&(_, &n)| n)
                        .map(|(w, _)| w);
                    let Some(w) = target else {
                        // all alive workers full (or none alive): reject
                        let e = if dead.iter().all(|&d| d) {
                            ServeError::Lost
                        } else {
                            ServeError::Busy
                        };
                        rec.record_error(&e);
                        let _ = rtx.send(SessionResponse::Failed(e));
                        break;
                    };
                    let id = next_session;
                    next_session += 1;
                    let job = SessionJob {
                        session: id,
                        req: SessionRequest::Open,
                        rtx,
                        enqueued: Instant::now(),
                    };
                    match worker_txs[w].send(job) {
                        Ok(()) => {
                            sessions.insert(id, w);
                            live[w] += 1;
                            break;
                        }
                        Err(mpsc::SendError(job)) => {
                            dead[w] = true;
                            rtx = job.rtx;
                        }
                    }
                }
            }
            req => {
                let Some(&w) = sessions.get(&session) else {
                    let _ = rtx.send(SessionResponse::Failed(ServeError::Lost));
                    continue;
                };
                let closing = matches!(req, SessionRequest::Close);
                let job = SessionJob { session, req, rtx, enqueued: Instant::now() };
                match worker_txs[w].send(job) {
                    Ok(()) => {
                        if closing {
                            sessions.remove(&session);
                            live[w] -= 1;
                        }
                    }
                    Err(mpsc::SendError(job)) => {
                        // the worker died with the session's state
                        dead[w] = true;
                        rec.record_error(&ServeError::Lost);
                        sessions.remove(&session);
                        live[w] = live[w].saturating_sub(1);
                        let _ = job.rtx.send(SessionResponse::Failed(ServeError::Lost));
                    }
                }
            }
        }
    }
    rec
}

/// Drain every full frame queued across the worker's live sessions:
/// each iteration advances *all* sessions with a pending frame through
/// one lockstep traversal (`SessionBackend::step_sessions`).
fn flush_session_ticks(
    sb: &mut dyn SessionBackend,
    queue: &mut SessionQueue,
    slots: &mut Vec<usize>,
    frames: &mut Vec<f32>,
) {
    while queue.next_tick(slots, frames) > 0 {
        sb.step_sessions(slots, frames);
    }
}

/// One streaming worker: owns a streaming-capable backend and the
/// sessions pinned to it. Ops are drained in arrival order; pushes are
/// buffered and consumed by lockstep ticks once the worker has seen
/// everything queued, so concurrently pushed sessions advance together.
/// Logits/close flush the session's pending frames first — an op
/// ordered after a push observes that push. No panic containment here:
/// a panicking streaming backend has corrupt resident state, so the
/// worker dies and its sessions fail with [`ServeError::Lost`] (see
/// docs/adr/003).
fn stream_worker_loop(
    factory: BoxedFactory,
    rx: mpsc::Receiver<SessionJob>,
    leader_tx: mpsc::Sender<StreamMsg>,
) -> LatencyRecorder {
    let mut backend = factory();
    let mut metrics = LatencyRecorder::new();
    if backend.streaming().is_none() {
        // not streaming-capable: fail everything (configuration error
        // surfaced per-op instead of a worker panic); admitted opens
        // are reported back so the leader's capacity does not leak
        while let Ok(job) = rx.recv() {
            if matches!(job.req, SessionRequest::Open) {
                let _ = leader_tx.send(StreamMsg::OpenFailed { session: job.session });
            }
            metrics.record_error(&ServeError::Lost);
            let _ = job.rtx.send(SessionResponse::Failed(ServeError::Lost));
        }
        return metrics;
    }
    // lint: allow(panic, streaming support was verified at loop entry before any session was admitted)
    let width = backend.streaming().expect("checked above").frame_width().max(1);
    let mut queue = SessionQueue::new(width);
    // pushes acked after the tick flush that consumed their frames
    let mut pending_acks: Vec<(mpsc::Sender<SessionResponse>, Instant, usize)> = Vec::new();
    let (mut slots, mut frames) = (Vec::new(), Vec::new());
    loop {
        let Ok(first) = rx.recv() else { break };
        let mut batch = vec![first];
        while let Ok(j) = rx.try_recv() {
            batch.push(j);
        }
        for job in batch {
            let SessionJob { session, req, rtx, enqueued } = job;
            // lint: allow(panic, streaming support was verified at loop entry before any session was admitted)
            let sb = backend.streaming().expect("checked above");
            match req {
                SessionRequest::Open => match sb.open_session() {
                    Some(slot) => {
                        queue.open(session, slot);
                        let _ = rtx.send(SessionResponse::Opened { session });
                    }
                    None => {
                        // the leader's admission should prevent this;
                        // kept as the defensive second check — and the
                        // leader is told, so its live count rolls back
                        let _ = leader_tx.send(StreamMsg::OpenFailed { session });
                        metrics.record_error(&ServeError::Busy);
                        let _ = rtx.send(SessionResponse::Failed(ServeError::Busy));
                    }
                },
                SessionRequest::PushFrames(values) => {
                    match queue.push(session, &values) {
                        Some(n) => pending_acks.push((rtx, enqueued, n)),
                        None => {
                            metrics.record_error(&ServeError::Lost);
                            let _ = rtx.send(SessionResponse::Failed(ServeError::Lost));
                        }
                    }
                }
                SessionRequest::PollLogits => {
                    // consume everything pushed before the poll
                    flush_session_ticks(sb, &mut queue, &mut slots, &mut frames);
                    match queue.slot(session) {
                        Some(slot) => {
                            let l = sb.session_logits(slot);
                            let _ = rtx.send(SessionResponse::Logits(l));
                        }
                        None => {
                            metrics.record_error(&ServeError::Lost);
                            let _ = rtx.send(SessionResponse::Failed(ServeError::Lost));
                        }
                    }
                }
                SessionRequest::Close => {
                    flush_session_ticks(sb, &mut queue, &mut slots, &mut frames);
                    match queue.close(session) {
                        Some(slot) => {
                            let label = sb.close_session(slot);
                            let _ = rtx.send(SessionResponse::Closed { label });
                        }
                        None => {
                            metrics.record_error(&ServeError::Lost);
                            let _ = rtx.send(SessionResponse::Failed(ServeError::Lost));
                        }
                    }
                }
            }
        }
        // the lockstep tick: every session that queued frames in this
        // round advances together through one traversal per time step
        // lint: allow(panic, streaming support was verified at loop entry before any session was admitted)
        let sb = backend.streaming().expect("checked above");
        flush_session_ticks(sb, &mut queue, &mut slots, &mut frames);
        for (rtx, enqueued, n) in pending_acks.drain(..) {
            metrics.record(enqueued.elapsed());
            let _ = rtx.send(SessionResponse::Pushed { frames: n });
        }
    }
    if let Some(d) = backend.delta_stats() {
        metrics.delta.merge(&d);
    }
    if let Some(m) = backend.energy_stats() {
        metrics.energy.merge(&m);
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test backend: label = round(sum of the sequence) mod 10.
    struct SumBackend;

    impl Backend for SumBackend {
        fn name(&self) -> &str {
            "sum"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            seqs.iter()
                .map(|s| (s.iter().sum::<f32>().round() as usize) % 10)
                .collect()
        }
    }

    #[test]
    fn serves_blocking_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(4, Duration::from_millis(1)),
        );
        let client = server.client();
        let r = client.classify(1, vec![1.0, 2.0]);
        assert_eq!(r.label(), 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 1);
        assert_eq!(metrics.errors, 0);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(8, Duration::from_millis(2)),
        );
        let client = server.client();
        let receivers: Vec<_> = (0..20)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.label(), i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 20);
        assert!(metrics.throughput() > 0.0);
    }

    #[test]
    fn shutdown_drains_pending() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(1000, Duration::from_secs(60)),
        );
        let client = server.client();
        let rxs: Vec<_> = (0..5).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown(); // must flush despite huge deadline
        assert_eq!(metrics.items, 5);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn sharded_serves_all_and_merges_metrics() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::new(4, Duration::from_millis(1)),
            4,
        );
        assert_eq!(server.n_workers(), 4);
        let client = server.client();
        let rxs: Vec<_> = (0..40)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().label(), i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 40);
    }

    #[test]
    fn sharded_shutdown_drains_pending() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::new(1000, Duration::from_secs(60)),
            3,
        );
        let client = server.client();
        let rxs: Vec<_> = (0..7).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 7);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::default(),
            0,
        );
        assert_eq!(server.n_workers(), 1);
        let r = server.client().classify(9, vec![4.0]);
        assert_eq!(r.label(), 4);
        server.shutdown();
    }

    #[test]
    fn duplicate_request_ids_route_to_their_own_waiters() {
        // Regression: routing used to pair responses with waiters by the
        // client-chosen id — two in-flight requests with the same id
        // could swap answers. Tickets make the id purely cosmetic.
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(8, Duration::from_millis(5)),
        );
        let client = server.client();
        // same id, different payloads, in one batch window
        let rx_a = client.submit(7, vec![1.0]);
        let rx_b = client.submit(7, vec![2.0]);
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.id, 7);
        assert_eq!(b.id, 7);
        assert_eq!(a.label(), 1, "first waiter must get its own answer");
        assert_eq!(b.label(), 2, "second waiter must get its own answer");
        server.shutdown();
    }

    /// Panics on any sequence whose first element is negative.
    struct FussyBackend;

    impl Backend for FussyBackend {
        fn name(&self) -> &str {
            "fussy"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            assert!(
                seqs.iter().all(|s| s.first().map(|&x| x >= 0.0).unwrap_or(true)),
                "negative input"
            );
            seqs.iter().map(|s| s.len() % 10).collect()
        }
    }

    #[test]
    fn backend_panic_fails_only_its_batch() {
        let server = Server::spawn(
            Box::new(FussyBackend),
            // batch size 1 isolates the poison request in its own batch
            BatchPolicy::new(1, Duration::from_millis(1)),
        );
        let client = server.client();
        let bad = client.classify(1, vec![-1.0, 0.0]);
        match bad.result {
            Err(ServeError::BackendPanicked(ref msg)) => {
                assert!(msg.contains("negative input"), "got: {msg}");
            }
            other => panic!("expected BackendPanicked, got {other:?}"),
        }
        // the worker survives and keeps serving
        let good = client.classify(2, vec![0.5, 0.5, 0.5]);
        assert_eq!(good.result, Ok(3));
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 1);
        assert_eq!(metrics.errors, 1);
    }

    #[test]
    fn dead_worker_fails_requests_and_shutdown_still_returns() {
        // the factory itself panics → the worker thread dies before
        // serving anything; clients must see Lost, not hang or panic,
        // and shutdown must return metrics that show the loss
        let (dead_tx, dead_rx) = mpsc::channel::<()>();
        let server = Server::spawn_with(
            move || {
                let _hold = dead_tx; // dropped as the panic unwinds
                panic!("factory exploded")
            },
            BatchPolicy::new(1, Duration::from_millis(1)),
        );
        // recv() errs once the worker's unwind has begun; the job-queue
        // receiver drops in that same unwind, so retry a few dispatches
        // until the leader observes the closed queue and counts the
        // loss (exactly once — it exits after the first failed send;
        // later classifies fail client-side, uncounted)
        assert!(dead_rx.recv().is_err());
        let client = server.client();
        for _ in 0..20 {
            let r = client.classify(1, vec![1.0]);
            assert_eq!(r.result, Err(ServeError::Lost));
            thread::sleep(Duration::from_millis(1));
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 0);
        assert_eq!(metrics.errors, 1);
    }

    /// Asserts the uniform-batch contract PJRT relies on.
    struct StrictShapeBackend;

    impl Backend for StrictShapeBackend {
        fn name(&self) -> &str {
            "strict-shape"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            let len0 = seqs.first().map(|s| s.len()).unwrap_or(0);
            assert!(
                seqs.iter().all(|s| s.len() == len0),
                "ragged batch reached a uniform-shape backend"
            );
            seqs.iter().map(|s| s.len() % 10).collect()
        }
    }

    #[test]
    fn bucketed_policy_feeds_uniform_batches_to_strict_backend() {
        // mixed-length load under a bucketed policy: the strict backend
        // would panic on any ragged batch (surfacing as error results),
        // and correct labels prove the ticket routing survives the
        // drain-order shuffling that bucketing introduces
        let server = Server::spawn(
            Box::new(StrictShapeBackend),
            BatchPolicy::new(4, Duration::from_millis(2)).bucketed(),
        );
        let client = server.client();
        let lens = [3usize, 5, 3, 5, 3, 5, 5];
        let rxs: Vec<_> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| client.submit(i as u64, vec![0.0; n]))
            .collect();
        for (rx, &n) in rxs.into_iter().zip(lens.iter()) {
            let r = rx.recv().unwrap();
            assert_eq!(r.result, Ok(n % 10), "wrong or failed response");
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, lens.len() as u64);
        assert_eq!(metrics.errors, 0);
    }

    /// Streaming test backend: per-session running sum. Logits =
    /// `[sum]`, label = round(sum) mod 10 — a trivial stateful model
    /// whose streamed result trivially equals its one-shot result.
    struct SumStream {
        sums: Vec<f32>,
        free: Vec<usize>,
        leased: Vec<bool>,
        explode_on_step: bool,
    }

    impl SumStream {
        fn new(capacity: usize) -> SumStream {
            SumStream {
                sums: vec![0.0; capacity],
                free: (0..capacity).rev().collect(),
                leased: vec![false; capacity],
                explode_on_step: false,
            }
        }

        /// A variant whose first tick panics — kills its worker thread
        /// (streaming workers deliberately have no panic containment).
        fn exploding(capacity: usize) -> SumStream {
            SumStream { explode_on_step: true, ..SumStream::new(capacity) }
        }
    }

    impl Backend for SumStream {
        fn name(&self) -> &str {
            "sum-stream"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            seqs.iter()
                .map(|s| (s.iter().sum::<f32>().round() as usize) % 10)
                .collect()
        }

        fn streaming(&mut self) -> Option<&mut dyn SessionBackend> {
            Some(self)
        }
    }

    impl SessionBackend for SumStream {
        fn session_capacity(&self) -> usize {
            self.sums.len()
        }

        fn frame_width(&self) -> usize {
            1
        }

        fn open_session(&mut self) -> Option<usize> {
            let slot = self.free.pop()?;
            self.leased[slot] = true;
            self.sums[slot] = 0.0;
            Some(slot)
        }

        fn step_sessions(&mut self, slots: &[usize], frames: &[f32]) {
            assert!(!self.explode_on_step, "backend exploded mid-tick");
            for (&slot, &x) in slots.iter().zip(frames.iter()) {
                assert!(self.leased[slot], "step on an unleased slot");
                self.sums[slot] += x;
            }
        }

        fn session_logits(&self, slot: usize) -> Vec<f32> {
            vec![self.sums[slot]]
        }

        fn close_session(&mut self, slot: usize) -> usize {
            self.leased[slot] = false;
            self.free.push(slot);
            (self.sums[slot].round() as usize) % 10
        }
    }

    #[test]
    fn stream_sessions_end_to_end() {
        let server = StreamServer::spawn(|| Box::new(SumStream::new(4)) as _, 1, 4);
        let client = server.client();
        let a = client.open().unwrap();
        let b = client.open().unwrap();
        assert_ne!(a.id, b.id);
        // interleaved incremental pushes, one or many frames at a time
        assert_eq!(a.push_frames(vec![1.0]).unwrap(), 1);
        assert_eq!(b.push_frames(vec![2.0, 2.0]).unwrap(), 2);
        // mid-sequence poll reflects exactly the frames pushed so far
        assert_eq!(a.logits().unwrap(), vec![1.0]);
        assert_eq!(b.logits().unwrap(), vec![4.0]);
        a.push_frames(vec![2.0]).unwrap();
        b.push_frames(vec![3.0]).unwrap();
        assert_eq!(a.close().unwrap(), 3);
        assert_eq!(b.close().unwrap(), 7);
        let metrics = server.shutdown();
        assert_eq!(metrics.errors, 0);
        assert!(metrics.items >= 4, "push latencies must be recorded");
    }

    #[test]
    fn stream_open_rejected_busy_when_slots_exhausted() {
        // 2 workers × 1 slot = capacity 2; leases are resident, so the
        // third open is rejected, not queued — and closing one admits
        // the next
        let server = StreamServer::spawn(|| Box::new(SumStream::new(1)) as _, 2, 1);
        let client = server.client();
        let a = client.open().unwrap();
        let b = client.open().unwrap();
        assert_eq!(client.open().err(), Some(ServeError::Busy));
        a.push_frames(vec![4.0]).unwrap();
        assert_eq!(a.close().unwrap(), 4);
        let c = client.open().expect("freed slot must admit a new session");
        c.push_frames(vec![5.0]).unwrap();
        assert_eq!(c.close().unwrap(), 5);
        b.close().unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.errors, 1);
        assert_eq!(metrics.errors_busy, 1, "the rejection must be counted");
    }

    #[test]
    fn stream_shutdown_with_live_sessions_fails_later_ops() {
        let server = StreamServer::spawn(|| Box::new(SumStream::new(2)) as _, 1, 2);
        let client = server.client();
        let live = client.open().unwrap();
        live.push_frames(vec![1.0]).unwrap();
        server.shutdown(); // session still open: dropped with the server
        assert_eq!(live.push_frames(vec![1.0]).err(), Some(ServeError::Lost));
        assert_eq!(live.close().err(), Some(ServeError::Lost));
        assert!(client.open().is_err());
    }

    #[test]
    fn stream_against_non_streaming_backend_fails_cleanly() {
        // SumBackend has no streaming interface: the worker must fail
        // ops per-session instead of panicking — and every failed open
        // must roll the leader's admission back, so opens keep failing
        // with Lost instead of exhausting phantom capacity into Busy
        let server = StreamServer::spawn(|| Box::new(SumBackend) as _, 1, 2);
        let client = server.client();
        for _ in 0..5 {
            // 5 > capacity 2: a leaked live count would turn these Busy
            assert_eq!(client.open().err(), Some(ServeError::Lost));
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.errors_lost, 5);
        assert_eq!(metrics.errors_busy, 0);
    }

    #[test]
    fn dead_stream_worker_excluded_from_placement() {
        // one of two workers gets a backend that panics on its first
        // tick (killing the worker thread); the leader must mark it
        // dead on the first failed send and keep placing new sessions
        // on the survivor instead of black-holing every open
        let built = Arc::new(Mutex::new(0usize));
        let built2 = Arc::clone(&built);
        let server = StreamServer::spawn(
            move || {
                let mut n = built2.lock().unwrap();
                *n += 1;
                if *n == 1 {
                    Box::new(SumStream::exploding(2)) as Box<dyn Backend>
                } else {
                    Box::new(SumStream::new(2)) as Box<dyn Backend>
                }
            },
            2,
            2,
        );
        let client = server.client();
        // fill both workers, then push everywhere: the exploding
        // worker's sessions fail, the survivor's serve normally
        let sessions: Vec<_> = (0..4).map(|_| client.open().unwrap()).collect();
        let mut survived = 0;
        for s in sessions {
            let pushed = s.push_frames(vec![2.0]);
            match pushed {
                Ok(_) => {
                    survived += 1;
                    assert_eq!(s.close().unwrap(), 2);
                }
                Err(ServeError::Lost) => {
                    // its worker is gone; close fails too, freeing the
                    // leader-side accounting
                    assert_eq!(s.close().err(), Some(ServeError::Lost));
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(survived, 2, "the healthy worker's sessions must serve");
        // new sessions must land on the survivor. The leader re-places
        // an open whose chosen worker turns out dead, so this succeeds
        // directly — the retry loop only guards the narrow race where
        // the dying worker's channel still accepts the send mid-unwind.
        let mut reopened = None;
        for _ in 0..4 {
            if let Ok(s) = client.open() {
                reopened = Some(s);
                break;
            }
        }
        let s = reopened.expect("opens must route to the surviving worker");
        s.push_frames(vec![3.0]).unwrap();
        assert_eq!(s.close().unwrap(), 3);
        server.shutdown();
    }

    #[test]
    fn work_spreads_across_worker_threads() {
        use std::collections::HashSet;

        /// Slow backend that records which thread served each batch.
        struct MarkingBackend(Arc<Mutex<HashSet<thread::ThreadId>>>);

        impl Backend for MarkingBackend {
            fn name(&self) -> &str {
                "marking"
            }

            fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
                self.0.lock().unwrap().insert(thread::current().id());
                thread::sleep(Duration::from_millis(10));
                vec![0; seqs.len()]
            }
        }

        let seen: Arc<Mutex<HashSet<thread::ThreadId>>> =
            Arc::new(Mutex::new(HashSet::new()));
        let seen2 = Arc::clone(&seen);
        let server = Server::spawn_sharded(
            move || Box::new(MarkingBackend(Arc::clone(&seen2))) as Box<dyn Backend>,
            BatchPolicy::new(1, Duration::from_millis(1)),
            4,
        );
        let client = server.client();
        let rxs: Vec<_> = (0..12).map(|i| client.submit(i, vec![0.0])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        server.shutdown();
        let n_threads = seen.lock().unwrap().len();
        assert!(
            n_threads >= 2,
            "12 slow batches over 4 workers used only {n_threads} thread(s)"
        );
    }
}
