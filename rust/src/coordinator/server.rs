//! Thread-based serving loop (tokio is not in the offline crate set; the
//! workload — long sequences through the simulators — is CPU-bound, so
//! an async reactor would buy nothing here anyway).
//!
//! Architecture: clients submit requests over an mpsc channel to a
//! *leader* thread that runs the dynamic batcher. The leader stamps
//! every submission with a monotonic **ticket** and keeps the response
//! channel keyed by it, so drained requests pair back to their waiters
//! in O(1) — client-chosen ids are echoed, never used for routing
//! (duplicates are harmless). Ready batches are pushed onto a shared
//! work queue feeding N *worker* threads, each of which owns one
//! backend instance — constructed *on* the worker thread via the
//! factory it was spawned with, because the PJRT backend wraps
//! non-`Send` XLA handles.
//!
//! Failure containment: a backend panic fails only the requests of the
//! batch it was classifying — the panic is caught, every request of the
//! batch receives [`ServeError::BackendPanicked`], and the worker keeps
//! serving. A worker that dies outright (e.g. its factory panicked)
//! only loses its own metrics; [`Server::shutdown`] joins what survives
//! and returns the merged [`LatencyRecorder`] instead of propagating.
//!
//! Backends are pluggable ([`Backend`]): golden model, mixed-signal
//! engine, or the PJRT executable.

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, Batcher, Request};
use crate::coordinator::metrics::LatencyRecorder;

/// A sequence classifier backend. Not required to be `Send`: the PJRT
/// executable wraps non-Send XLA handles, so backends are *constructed
/// on their worker thread* via the factory passed to
/// [`Server::spawn_with`] / [`Server::spawn_sharded`].
pub trait Backend {
    fn name(&self) -> &str;
    /// Classify a batch of sequences. The default serving contract is
    /// **ragged** — sequences may differ in length: the golden backend
    /// processes them per-sequence and the mixed-signal backend groups
    /// them by length for its lockstep batch path. Backends compiled
    /// for one batch shape (PJRT) must be served with
    /// [`BatchPolicy::bucketed`], which guarantees uniform-length
    /// batches at the leader; the mixed-signal backend is fastest under
    /// the same policy (one lockstep group per batch).
    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize>;
}

/// Why a request failed instead of producing a label.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The backend panicked while classifying this request's batch; the
    /// payload message is preserved for diagnosis.
    BackendPanicked(String),
    /// The server (leader or the serving worker) went away before a
    /// response could be produced.
    Lost,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BackendPanicked(msg) => {
                write!(f, "backend panicked: {msg}")
            }
            ServeError::Lost => write!(f, "server dropped the response"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Response to one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub result: Result<usize, ServeError>,
    pub latency: Duration,
}

impl Response {
    /// The served label, for drivers that expect success; panics with
    /// the serving error otherwise.
    pub fn label(&self) -> usize {
        match &self.result {
            Ok(l) => *l,
            Err(e) => panic!("request {} failed: {e}", self.id),
        }
    }
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// One unit of worker work: a drained batch with its response channels.
type Job = Vec<(Request, mpsc::Sender<Response>)>;

/// A per-worker backend constructor, invoked on the worker's own thread.
type BoxedFactory = Box<dyn FnOnce() -> Box<dyn Backend> + Send>;

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking classify: submit and wait. Never panics — if the server
    /// (or the worker holding this request) dies, the response carries
    /// [`ServeError::Lost`].
    pub fn classify(&self, id: u64, sequence: Vec<f32>) -> Response {
        let (rtx, rrx) = mpsc::channel();
        let req = Request::new(id, sequence);
        let enqueued = req.enqueued;
        if self.tx.send(Msg::Submit(req, rtx)).is_err() {
            return Response {
                id,
                result: Err(ServeError::Lost),
                latency: enqueued.elapsed(),
            };
        }
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Response {
                id,
                result: Err(ServeError::Lost),
                latency: enqueued.elapsed(),
            },
        }
    }

    /// Fire-and-forget submit returning the response receiver. If the
    /// server is gone the receiver's `recv()` errors immediately.
    pub fn submit(&self, id: u64, sequence: Vec<f32>) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(Request::new(id, sequence), rtx));
        rrx
    }
}

/// A running server; `shutdown()` drains the queue and returns the
/// merged metrics of all workers.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    /// The leader returns its own recorder: requests it had to drop
    /// (every worker dead) are counted there as errors, so losses are
    /// visible in the merged metrics, not just client-side.
    leader: thread::JoinHandle<LatencyRecorder>,
    workers: Vec<thread::JoinHandle<LatencyRecorder>>,
}

impl Server {
    /// Spawn a single-worker server with a `Send` backend.
    pub fn spawn(backend: Box<dyn Backend + Send>, policy: BatchPolicy) -> Server {
        Server::spawn_with(move || backend as Box<dyn Backend>, policy)
    }

    /// Spawn a single-worker server, constructing the backend *on* the
    /// worker thread (required for PJRT, whose handles are not `Send`).
    pub fn spawn_with<F>(factory: F, policy: BatchPolicy) -> Server
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        Server::spawn_parts(vec![Box::new(factory)], policy)
    }

    /// Spawn a sharded server: `workers` threads (clamped to ≥ 1), each
    /// constructing its own backend instance by calling `factory` on its
    /// own thread, all fed from one work-distribution queue. The backend
    /// instances themselves never cross threads, preserving the
    /// non-`Send` PJRT constraint; only the factory must be `Send + Sync`.
    pub fn spawn_sharded<F>(factory: F, policy: BatchPolicy, workers: usize) -> Server
    where
        F: Fn() -> Box<dyn Backend> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let factories: Vec<BoxedFactory> = (0..workers.max(1))
            .map(|_| {
                let f = Arc::clone(&factory);
                Box::new(move || (*f)()) as BoxedFactory
            })
            .collect();
        Server::spawn_parts(factories, policy)
    }

    fn spawn_parts(factories: Vec<BoxedFactory>, policy: BatchPolicy) -> Server {
        assert!(!factories.is_empty(), "server needs at least one worker");
        let (tx, rx) = mpsc::channel::<Msg>();
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let workers: Vec<thread::JoinHandle<LatencyRecorder>> = factories
            .into_iter()
            .enumerate()
            .map(|(w, factory)| {
                let job_rx = Arc::clone(&job_rx);
                thread::Builder::new()
                    .name(format!("minimalist-worker-{w}"))
                    .spawn(move || worker_loop(factory, job_rx))
                    .expect("spawning worker thread")
            })
            .collect();
        let leader = thread::Builder::new()
            .name("minimalist-leader".to_string())
            .spawn(move || leader_loop(rx, job_tx, policy))
            .expect("spawning leader thread");
        Server { tx, leader, workers }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Number of worker threads serving this instance.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting requests, drain the queue, return the merged
    /// metrics of every worker that survived. Thread panics are
    /// reported, not propagated — a dead worker costs its metrics, not
    /// the shutdown.
    pub fn shutdown(self) -> LatencyRecorder {
        let _ = self.tx.send(Msg::Shutdown);
        let leader_metrics = match self.leader.join() {
            Ok(m) => Some(m),
            Err(_) => {
                eprintln!(
                    "minimalist-server: leader thread panicked; \
                     in-flight requests were dropped"
                );
                None
            }
        };
        let mut merged: Option<LatencyRecorder> = None;
        for w in self.workers {
            match w.join() {
                Ok(m) => match merged.as_mut() {
                    Some(acc) => acc.merge(&m),
                    None => merged = Some(m),
                },
                Err(_) => eprintln!(
                    "minimalist-server: a worker thread panicked; \
                     its metrics are lost"
                ),
            }
        }
        let mut merged = merged.unwrap_or_default();
        if let Some(lm) = leader_metrics {
            merged.merge(&lm);
        }
        merged
    }
}

/// Stamp a submission with the next routing ticket and queue it.
fn enqueue(
    batcher: &mut Batcher,
    waiters: &mut HashMap<u64, mpsc::Sender<Response>>,
    next_ticket: &mut u64,
    mut req: Request,
    rtx: mpsc::Sender<Response>,
) {
    req.ticket = *next_ticket;
    *next_ticket += 1;
    waiters.insert(req.ticket, rtx);
    batcher.push(req);
}

/// The leader: accepts submissions, runs the batching policy, pairs
/// each drained request with its response channel by ticket, and pushes
/// the batch onto the work queue. Exits (dropping the queue sender,
/// which stops the workers) once shut down and fully drained. Returns a
/// recorder holding only the error count of requests it had to drop.
fn leader_loop(
    rx: mpsc::Receiver<Msg>,
    job_tx: mpsc::Sender<Job>,
    policy: BatchPolicy,
) -> LatencyRecorder {
    let mut lost = LatencyRecorder::new();
    let mut batcher = Batcher::new(policy);
    let mut waiters: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
    let mut next_ticket: u64 = 1; // 0 marks "not yet assigned"
    let mut open = true;
    while open || !batcher.is_empty() {
        // Block until the next message or the oldest request's deadline
        // (so partial batches still fire), then drain whatever else
        // already arrived.
        let timeout = batcher
            .deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(policy.max_wait)
            .max(Duration::from_micros(100));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(req, rtx)) => {
                enqueue(&mut batcher, &mut waiters, &mut next_ticket, req, rtx);
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Submit(req, rtx) => {
                            enqueue(
                                &mut batcher,
                                &mut waiters,
                                &mut next_ticket,
                                req,
                                rtx,
                            );
                        }
                        Msg::Shutdown => open = false,
                    }
                }
            }
            Ok(Msg::Shutdown) => open = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
        // Dispatch every ready batch — with several queued batches this
        // is what spreads work across the idle workers.
        loop {
            let now = Instant::now();
            if !(batcher.ready(now) || (!open && !batcher.is_empty())) {
                break;
            }
            let batch = batcher.drain();
            if batch.is_empty() {
                break; // defensive: never dispatch (or spin on) empty jobs
            }
            let job: Job = batch
                .into_iter()
                .map(|req| {
                    let rtx = waiters
                        .remove(&req.ticket)
                        .expect("waiter registered at submit");
                    (req, rtx)
                })
                .collect();
            if let Err(mpsc::SendError(job)) = job_tx.send(job) {
                // every worker died: this job's requests plus everything
                // still queued are lost — account them so the merged
                // metrics show the failure instead of "err=0"
                lost.record_errors((job.len() + waiters.len()) as u64);
                return lost;
            }
        }
    }
    lost
}

/// Render a caught panic payload for the error response.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// One worker: construct the backend on this thread, then pull batches
/// off the shared queue until the leader hangs up. A backend panic
/// fails that batch's requests and the worker keeps serving (every
/// backend re-derives its per-sequence state from scratch on classify,
/// so a caught panic cannot corrupt later results).
fn worker_loop(
    factory: BoxedFactory,
    job_rx: Arc<Mutex<mpsc::Receiver<Job>>>,
) -> LatencyRecorder {
    let mut backend = factory();
    let mut metrics = LatencyRecorder::new();
    loop {
        // Hold the lock only while receiving — classification runs
        // unlocked so the other workers can keep pulling jobs.
        let job = {
            let rx = job_rx.lock().expect("job queue poisoned");
            rx.recv()
        };
        let Ok(mut job) = job else { break };
        // take, don't clone: the job is owned and the payloads are not
        // needed again after classification
        let seqs: Vec<Vec<f32>> = job
            .iter_mut()
            .map(|(r, _)| std::mem::take(&mut r.sequence))
            .collect();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || backend.classify_batch(&seqs),
        ));
        match outcome {
            Ok(labels) => {
                for ((req, rtx), label) in job.into_iter().zip(labels) {
                    let latency = req.enqueued.elapsed();
                    metrics.record(latency);
                    let _ = rtx.send(Response {
                        id: req.id,
                        result: Ok(label),
                        latency,
                    });
                }
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                metrics.record_errors(job.len() as u64);
                for (req, rtx) in job {
                    let _ = rtx.send(Response {
                        id: req.id,
                        result: Err(ServeError::BackendPanicked(msg.clone())),
                        latency: req.enqueued.elapsed(),
                    });
                }
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test backend: label = round(sum of the sequence) mod 10.
    struct SumBackend;

    impl Backend for SumBackend {
        fn name(&self) -> &str {
            "sum"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            seqs.iter()
                .map(|s| (s.iter().sum::<f32>().round() as usize) % 10)
                .collect()
        }
    }

    #[test]
    fn serves_blocking_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(4, Duration::from_millis(1)),
        );
        let client = server.client();
        let r = client.classify(1, vec![1.0, 2.0]);
        assert_eq!(r.label(), 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 1);
        assert_eq!(metrics.errors, 0);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(8, Duration::from_millis(2)),
        );
        let client = server.client();
        let receivers: Vec<_> = (0..20)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.label(), i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 20);
        assert!(metrics.throughput() > 0.0);
    }

    #[test]
    fn shutdown_drains_pending() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(1000, Duration::from_secs(60)),
        );
        let client = server.client();
        let rxs: Vec<_> = (0..5).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown(); // must flush despite huge deadline
        assert_eq!(metrics.items, 5);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn sharded_serves_all_and_merges_metrics() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::new(4, Duration::from_millis(1)),
            4,
        );
        assert_eq!(server.n_workers(), 4);
        let client = server.client();
        let rxs: Vec<_> = (0..40)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().label(), i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 40);
    }

    #[test]
    fn sharded_shutdown_drains_pending() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::new(1000, Duration::from_secs(60)),
            3,
        );
        let client = server.client();
        let rxs: Vec<_> = (0..7).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 7);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::default(),
            0,
        );
        assert_eq!(server.n_workers(), 1);
        let r = server.client().classify(9, vec![4.0]);
        assert_eq!(r.label(), 4);
        server.shutdown();
    }

    #[test]
    fn duplicate_request_ids_route_to_their_own_waiters() {
        // Regression: routing used to pair responses with waiters by the
        // client-chosen id — two in-flight requests with the same id
        // could swap answers. Tickets make the id purely cosmetic.
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy::new(8, Duration::from_millis(5)),
        );
        let client = server.client();
        // same id, different payloads, in one batch window
        let rx_a = client.submit(7, vec![1.0]);
        let rx_b = client.submit(7, vec![2.0]);
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.id, 7);
        assert_eq!(b.id, 7);
        assert_eq!(a.label(), 1, "first waiter must get its own answer");
        assert_eq!(b.label(), 2, "second waiter must get its own answer");
        server.shutdown();
    }

    /// Panics on any sequence whose first element is negative.
    struct FussyBackend;

    impl Backend for FussyBackend {
        fn name(&self) -> &str {
            "fussy"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            assert!(
                seqs.iter().all(|s| s.first().map(|&x| x >= 0.0).unwrap_or(true)),
                "negative input"
            );
            seqs.iter().map(|s| s.len() % 10).collect()
        }
    }

    #[test]
    fn backend_panic_fails_only_its_batch() {
        let server = Server::spawn(
            Box::new(FussyBackend),
            // batch size 1 isolates the poison request in its own batch
            BatchPolicy::new(1, Duration::from_millis(1)),
        );
        let client = server.client();
        let bad = client.classify(1, vec![-1.0, 0.0]);
        match bad.result {
            Err(ServeError::BackendPanicked(ref msg)) => {
                assert!(msg.contains("negative input"), "got: {msg}");
            }
            other => panic!("expected BackendPanicked, got {other:?}"),
        }
        // the worker survives and keeps serving
        let good = client.classify(2, vec![0.5, 0.5, 0.5]);
        assert_eq!(good.result, Ok(3));
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 1);
        assert_eq!(metrics.errors, 1);
    }

    #[test]
    fn dead_worker_fails_requests_and_shutdown_still_returns() {
        // the factory itself panics → the worker thread dies before
        // serving anything; clients must see Lost, not hang or panic,
        // and shutdown must return metrics that show the loss
        let (dead_tx, dead_rx) = mpsc::channel::<()>();
        let server = Server::spawn_with(
            move || {
                let _hold = dead_tx; // dropped as the panic unwinds
                panic!("factory exploded")
            },
            BatchPolicy::new(1, Duration::from_millis(1)),
        );
        // recv() errs once the worker's unwind has begun; the job-queue
        // receiver drops in that same unwind, so retry a few dispatches
        // until the leader observes the closed queue and counts the
        // loss (exactly once — it exits after the first failed send;
        // later classifies fail client-side, uncounted)
        assert!(dead_rx.recv().is_err());
        let client = server.client();
        for _ in 0..20 {
            let r = client.classify(1, vec![1.0]);
            assert_eq!(r.result, Err(ServeError::Lost));
            thread::sleep(Duration::from_millis(1));
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 0);
        assert_eq!(metrics.errors, 1);
    }

    /// Asserts the uniform-batch contract PJRT relies on.
    struct StrictShapeBackend;

    impl Backend for StrictShapeBackend {
        fn name(&self) -> &str {
            "strict-shape"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            let len0 = seqs.first().map(|s| s.len()).unwrap_or(0);
            assert!(
                seqs.iter().all(|s| s.len() == len0),
                "ragged batch reached a uniform-shape backend"
            );
            seqs.iter().map(|s| s.len() % 10).collect()
        }
    }

    #[test]
    fn bucketed_policy_feeds_uniform_batches_to_strict_backend() {
        // mixed-length load under a bucketed policy: the strict backend
        // would panic on any ragged batch (surfacing as error results),
        // and correct labels prove the ticket routing survives the
        // drain-order shuffling that bucketing introduces
        let server = Server::spawn(
            Box::new(StrictShapeBackend),
            BatchPolicy::new(4, Duration::from_millis(2)).bucketed(),
        );
        let client = server.client();
        let lens = [3usize, 5, 3, 5, 3, 5, 5];
        let rxs: Vec<_> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| client.submit(i as u64, vec![0.0; n]))
            .collect();
        for (rx, &n) in rxs.into_iter().zip(lens.iter()) {
            let r = rx.recv().unwrap();
            assert_eq!(r.result, Ok(n % 10), "wrong or failed response");
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, lens.len() as u64);
        assert_eq!(metrics.errors, 0);
    }

    #[test]
    fn work_spreads_across_worker_threads() {
        use std::collections::HashSet;

        /// Slow backend that records which thread served each batch.
        struct MarkingBackend(Arc<Mutex<HashSet<thread::ThreadId>>>);

        impl Backend for MarkingBackend {
            fn name(&self) -> &str {
                "marking"
            }

            fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
                self.0.lock().unwrap().insert(thread::current().id());
                thread::sleep(Duration::from_millis(10));
                vec![0; seqs.len()]
            }
        }

        let seen: Arc<Mutex<HashSet<thread::ThreadId>>> =
            Arc::new(Mutex::new(HashSet::new()));
        let seen2 = Arc::clone(&seen);
        let server = Server::spawn_sharded(
            move || Box::new(MarkingBackend(Arc::clone(&seen2))) as Box<dyn Backend>,
            BatchPolicy::new(1, Duration::from_millis(1)),
            4,
        );
        let client = server.client();
        let rxs: Vec<_> = (0..12).map(|i| client.submit(i, vec![0.0])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        server.shutdown();
        let n_threads = seen.lock().unwrap().len();
        assert!(
            n_threads >= 2,
            "12 slow batches over 4 workers used only {n_threads} thread(s)"
        );
    }
}
