//! Thread-based serving loop (tokio is not in the offline crate set; the
//! workload — long sequences through the simulators — is CPU-bound, so
//! an async reactor would buy nothing here anyway).
//!
//! Architecture: clients submit requests over an mpsc channel to a
//! *leader* thread that runs the dynamic batcher. Ready batches are
//! pushed onto a shared work queue feeding N *worker* threads, each of
//! which owns one backend instance — constructed *on* the worker thread
//! via the factory it was spawned with, because the PJRT backend wraps
//! non-`Send` XLA handles. Every worker records latencies into its own
//! [`LatencyRecorder`]; [`Server::shutdown`] joins all threads and
//! merges the per-worker recorders into the aggregate it returns.
//!
//! Backends are pluggable ([`Backend`]): golden model, mixed-signal
//! engine, or the PJRT executable.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, Batcher, Request};
use crate::coordinator::metrics::LatencyRecorder;

/// A sequence classifier backend. Not required to be `Send`: the PJRT
/// executable wraps non-Send XLA handles, so backends are *constructed
/// on their worker thread* via the factory passed to
/// [`Server::spawn_with`] / [`Server::spawn_sharded`].
pub trait Backend {
    fn name(&self) -> &str;
    /// Classify a batch of sequences (all the same length).
    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize>;
}

/// Response to one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub label: usize,
    pub latency: Duration,
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// One unit of worker work: a drained batch with its response channels.
type Job = Vec<(Request, mpsc::Sender<Response>)>;

/// A per-worker backend constructor, invoked on the worker's own thread.
type BoxedFactory = Box<dyn FnOnce() -> Box<dyn Backend> + Send>;

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking classify: submit and wait.
    pub fn classify(&self, id: u64, sequence: Vec<f32>) -> Response {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(
                Request { id, sequence, enqueued: Instant::now() },
                rtx,
            ))
            .expect("server gone");
        rrx.recv().expect("server dropped response")
    }

    /// Fire-and-forget submit returning the response receiver.
    pub fn submit(&self, id: u64, sequence: Vec<f32>) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(
                Request { id, sequence, enqueued: Instant::now() },
                rtx,
            ))
            .expect("server gone");
        rrx
    }
}

/// A running server; `shutdown()` drains the queue and returns the
/// merged metrics of all workers.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    leader: thread::JoinHandle<()>,
    workers: Vec<thread::JoinHandle<LatencyRecorder>>,
}

impl Server {
    /// Spawn a single-worker server with a `Send` backend.
    pub fn spawn(backend: Box<dyn Backend + Send>, policy: BatchPolicy) -> Server {
        Server::spawn_with(move || backend as Box<dyn Backend>, policy)
    }

    /// Spawn a single-worker server, constructing the backend *on* the
    /// worker thread (required for PJRT, whose handles are not `Send`).
    pub fn spawn_with<F>(factory: F, policy: BatchPolicy) -> Server
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        Server::spawn_parts(vec![Box::new(factory)], policy)
    }

    /// Spawn a sharded server: `workers` threads (clamped to ≥ 1), each
    /// constructing its own backend instance by calling `factory` on its
    /// own thread, all fed from one work-distribution queue. The backend
    /// instances themselves never cross threads, preserving the
    /// non-`Send` PJRT constraint; only the factory must be `Send + Sync`.
    pub fn spawn_sharded<F>(factory: F, policy: BatchPolicy, workers: usize) -> Server
    where
        F: Fn() -> Box<dyn Backend> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let factories: Vec<BoxedFactory> = (0..workers.max(1))
            .map(|_| {
                let f = Arc::clone(&factory);
                Box::new(move || (*f)()) as BoxedFactory
            })
            .collect();
        Server::spawn_parts(factories, policy)
    }

    fn spawn_parts(factories: Vec<BoxedFactory>, policy: BatchPolicy) -> Server {
        assert!(!factories.is_empty(), "server needs at least one worker");
        let (tx, rx) = mpsc::channel::<Msg>();
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let workers: Vec<thread::JoinHandle<LatencyRecorder>> = factories
            .into_iter()
            .enumerate()
            .map(|(w, factory)| {
                let job_rx = Arc::clone(&job_rx);
                thread::Builder::new()
                    .name(format!("minimalist-worker-{w}"))
                    .spawn(move || worker_loop(factory, job_rx))
                    .expect("spawning worker thread")
            })
            .collect();
        let leader = thread::Builder::new()
            .name("minimalist-leader".to_string())
            .spawn(move || leader_loop(rx, job_tx, policy))
            .expect("spawning leader thread");
        Server { tx, leader, workers }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Number of worker threads serving this instance.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting requests, drain the queue, return merged metrics.
    pub fn shutdown(self) -> LatencyRecorder {
        let _ = self.tx.send(Msg::Shutdown);
        self.leader.join().expect("leader thread panicked");
        let mut merged: Option<LatencyRecorder> = None;
        for w in self.workers {
            let m = w.join().expect("worker thread panicked");
            match merged.as_mut() {
                Some(acc) => acc.merge(&m),
                None => merged = Some(m),
            }
        }
        merged.expect("server had no workers")
    }
}

/// The leader: accepts submissions, runs the batching policy, pairs
/// each drained request with its response channel, and pushes the batch
/// onto the work queue. Exits (dropping the queue sender, which stops
/// the workers) once shut down and fully drained.
fn leader_loop(rx: mpsc::Receiver<Msg>, job_tx: mpsc::Sender<Job>, policy: BatchPolicy) {
    let mut batcher = Batcher::new(policy);
    let mut waiters: Vec<(u64, mpsc::Sender<Response>)> = Vec::new();
    let mut open = true;
    while open || !batcher.is_empty() {
        // Block until the next message or the oldest request's deadline
        // (so partial batches still fire), then drain whatever else
        // already arrived.
        let timeout = batcher
            .deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(policy.max_wait)
            .max(Duration::from_micros(100));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(req, rtx)) => {
                waiters.push((req.id, rtx));
                batcher.push(req);
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Submit(req, rtx) => {
                            waiters.push((req.id, rtx));
                            batcher.push(req);
                        }
                        Msg::Shutdown => open = false,
                    }
                }
            }
            Ok(Msg::Shutdown) => open = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
        // Dispatch every ready batch — with several queued batches this
        // is what spreads work across the idle workers.
        loop {
            let now = Instant::now();
            if !(batcher.ready(now) || (!open && !batcher.is_empty())) {
                break;
            }
            let batch = batcher.drain();
            if batch.is_empty() {
                break; // defensive: never dispatch (or spin on) empty jobs
            }
            let job: Job = batch
                .into_iter()
                .map(|req| {
                    let pos = waiters
                        .iter()
                        .position(|(id, _)| *id == req.id)
                        .expect("response channel lost");
                    let (_, rtx) = waiters.swap_remove(pos);
                    (req, rtx)
                })
                .collect();
            if job_tx.send(job).is_err() {
                return; // every worker died; nothing left to serve
            }
        }
    }
}

/// One worker: construct the backend on this thread, then pull batches
/// off the shared queue until the leader hangs up.
fn worker_loop(
    factory: BoxedFactory,
    job_rx: Arc<Mutex<mpsc::Receiver<Job>>>,
) -> LatencyRecorder {
    let mut backend = factory();
    let mut metrics = LatencyRecorder::new();
    loop {
        // Hold the lock only while receiving — classification runs
        // unlocked so the other workers can keep pulling jobs.
        let job = {
            let rx = job_rx.lock().expect("job queue poisoned");
            rx.recv()
        };
        let Ok(mut job) = job else { break };
        // take, don't clone: the job is owned and the payloads are not
        // needed again after classification
        let seqs: Vec<Vec<f32>> = job
            .iter_mut()
            .map(|(r, _)| std::mem::take(&mut r.sequence))
            .collect();
        let labels = backend.classify_batch(&seqs);
        for ((req, rtx), label) in job.into_iter().zip(labels) {
            let latency = req.enqueued.elapsed();
            metrics.record(latency);
            let _ = rtx.send(Response { id: req.id, label, latency });
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test backend: label = round(sum of the sequence) mod 10.
    struct SumBackend;

    impl Backend for SumBackend {
        fn name(&self) -> &str {
            "sum"
        }

        fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
            seqs.iter()
                .map(|s| (s.iter().sum::<f32>().round() as usize) % 10)
                .collect()
        }
    }

    #[test]
    fn serves_blocking_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let client = server.client();
        let r = client.classify(1, vec![1.0, 2.0]);
        assert_eq!(r.label, 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        );
        let client = server.client();
        let receivers: Vec<_> = (0..20)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.label, i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 20);
        assert!(metrics.throughput() > 0.0);
    }

    #[test]
    fn shutdown_drains_pending() {
        let server = Server::spawn(
            Box::new(SumBackend),
            BatchPolicy { max_batch: 1000, max_wait: Duration::from_secs(60) },
        );
        let client = server.client();
        let rxs: Vec<_> = (0..5).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown(); // must flush despite huge deadline
        assert_eq!(metrics.items, 5);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn sharded_serves_all_and_merges_metrics() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            4,
        );
        assert_eq!(server.n_workers(), 4);
        let client = server.client();
        let rxs: Vec<_> = (0..40)
            .map(|i| client.submit(i, vec![i as f32]))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().label, i % 10);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 40);
    }

    #[test]
    fn sharded_shutdown_drains_pending() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy { max_batch: 1000, max_wait: Duration::from_secs(60) },
            3,
        );
        let client = server.client();
        let rxs: Vec<_> = (0..7).map(|i| client.submit(i, vec![i as f32])).collect();
        let metrics = server.shutdown();
        assert_eq!(metrics.items, 7);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let server = Server::spawn_sharded(
            || Box::new(SumBackend) as Box<dyn Backend>,
            BatchPolicy::default(),
            0,
        );
        assert_eq!(server.n_workers(), 1);
        let r = server.client().classify(9, vec![4.0]);
        assert_eq!(r.label, 4);
        server.shutdown();
    }

    #[test]
    fn work_spreads_across_worker_threads() {
        use std::collections::HashSet;

        /// Slow backend that records which thread served each batch.
        struct MarkingBackend(Arc<Mutex<HashSet<thread::ThreadId>>>);

        impl Backend for MarkingBackend {
            fn name(&self) -> &str {
                "marking"
            }

            fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
                self.0.lock().unwrap().insert(thread::current().id());
                thread::sleep(Duration::from_millis(10));
                vec![0; seqs.len()]
            }
        }

        let seen: Arc<Mutex<HashSet<thread::ThreadId>>> =
            Arc::new(Mutex::new(HashSet::new()));
        let seen2 = Arc::clone(&seen);
        let server = Server::spawn_sharded(
            move || Box::new(MarkingBackend(Arc::clone(&seen2))) as Box<dyn Backend>,
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            4,
        );
        let client = server.client();
        let rxs: Vec<_> = (0..12).map(|i| client.submit(i, vec![0.0])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        server.shutdown();
        let n_threads = seen.lock().unwrap().len();
        assert!(
            n_threads >= 2,
            "12 slow batches over 4 workers used only {n_threads} thread(s)"
        );
    }
}
