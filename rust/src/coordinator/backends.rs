//! Classification backends for the serving loop.
//!
//! Three interchangeable implementations of [`super::server::Backend`]:
//! * [`GoldenBackend`] — the rust software model (logical units, exact)
//! * [`MixedSignalBackend`] — the switched-capacitor engine (physics)
//! * [`PjrtBackend`] — the AOT-compiled JAX model through the XLA CPU
//!   client (the paper's "software model", executed hermetically)
//!
//! The golden and mixed-signal backends also implement the streaming
//! interface ([`crate::coordinator::SessionBackend`]) when constructed
//! with provisioned session slots (`with_sessions` /
//! `streaming_factory`): the golden backend keeps one resident
//! [`GoldenNetwork`] per slot, the mixed-signal backend leases slots of
//! its engine's analog state pool — both produce streamed logits
//! bit-identical to their one-shot classification of the same frames.

use anyhow::Result;

use crate::config::{CircuitConfig, CoreGeometry, MappingConfig};
use crate::coordinator::engine::MixedSignalEngine;
use crate::coordinator::server::{Backend, SessionBackend};
use crate::mapping::Plan;
use crate::nn::mingru::{argmax, GoldenNetwork};
use crate::nn::weights::NetworkWeights;
use crate::runtime::Executable;

/// Serving backend over the bit-exact golden float model.
pub struct GoldenBackend {
    net: GoldenNetwork,
    /// Streaming sessions: one resident network per slot (empty unless
    /// constructed via [`GoldenBackend::with_sessions`]).
    session_nets: Vec<GoldenNetwork>,
    free: Vec<usize>,
    leased: Vec<bool>,
}

impl GoldenBackend {
    /// A one-shot (batch) backend over `net`, with no streaming slots.
    pub fn new(net: GoldenNetwork) -> GoldenBackend {
        GoldenBackend {
            net,
            session_nets: Vec::new(),
            free: Vec::new(),
            leased: Vec::new(),
        }
    }

    /// A golden backend with `sessions` resident streaming slots — the
    /// trivial stateful counterpart of the mixed-signal session pool,
    /// so streaming parity can be pinned against the exact software
    /// model (tests/stream_parity.rs).
    pub fn with_sessions(net: GoldenNetwork, sessions: usize) -> GoldenBackend {
        let c = sessions.max(1);
        let session_nets = (0..c)
            .map(|_| GoldenNetwork::new(net.weights.clone()))
            .collect();
        GoldenBackend {
            net,
            session_nets,
            free: (0..c).rev().collect(),
            leased: vec![false; c],
        }
    }

    /// Worker factory for [`crate::coordinator::Server::spawn_sharded`]:
    /// every call builds an independent golden backend from the shared
    /// checkpoint, on whichever thread invokes it.
    pub fn factory(
        weights: NetworkWeights,
    ) -> impl Fn() -> Box<dyn Backend> + Send + Sync + 'static {
        move || {
            Box::new(GoldenBackend::new(GoldenNetwork::new(weights.clone())))
                as Box<dyn Backend>
        }
    }

    /// Worker factory for [`crate::coordinator::StreamServer::spawn`]:
    /// each worker holds `sessions` resident golden session slots.
    pub fn streaming_factory(
        weights: NetworkWeights,
        sessions: usize,
    ) -> impl Fn() -> Box<dyn Backend> + Send + Sync + 'static {
        move || {
            Box::new(GoldenBackend::with_sessions(
                GoldenNetwork::new(weights.clone()),
                sessions,
            )) as Box<dyn Backend>
        }
    }
}

impl Backend for GoldenBackend {
    fn name(&self) -> &str {
        "golden"
    }

    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
        seqs.iter().map(|s| self.net.classify(s)).collect()
    }

    fn streaming(&mut self) -> Option<&mut dyn SessionBackend> {
        if self.session_nets.is_empty() {
            None
        } else {
            Some(self)
        }
    }
}

impl SessionBackend for GoldenBackend {
    fn session_capacity(&self) -> usize {
        self.session_nets.len()
    }

    fn frame_width(&self) -> usize {
        self.net.weights.dims[0]
    }

    fn open_session(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.leased[slot] = true;
        self.session_nets[slot].reset();
        Some(slot)
    }

    fn step_sessions(&mut self, slots: &[usize], frames: &[f32]) {
        let w = self.frame_width();
        for (k, &slot) in slots.iter().enumerate() {
            debug_assert!(self.leased[slot], "step on an unleased slot");
            self.session_nets[slot].step(&frames[k * w..(k + 1) * w], None);
        }
    }

    fn session_logits(&self, slot: usize) -> Vec<f32> {
        self.session_nets[slot].logits()
    }

    fn close_session(&mut self, slot: usize) -> usize {
        assert!(self.leased[slot], "close of an unleased slot {slot}");
        self.leased[slot] = false;
        self.free.push(slot);
        argmax(&self.session_nets[slot].logits())
    }
}

/// Serving backend over the switched-capacitor engine.
pub struct MixedSignalBackend {
    engine: MixedSignalEngine,
}

impl MixedSignalBackend {
    /// Wrap `engine` as a serving backend.
    pub fn new(engine: MixedSignalEngine) -> MixedSignalBackend {
        MixedSignalBackend { engine }
    }

    /// A mixed-signal backend with `sessions` resident streaming slots:
    /// each live session leases one engine slot, whose analog state
    /// (capacitor voltages, swap configuration, RNG stream position)
    /// persists across requests until close. The backend then serves
    /// the streaming path only — `classify_batch` would dissolve the
    /// slot pool, so it refuses to run while sessions are live (the
    /// engine asserts).
    pub fn with_sessions(mut engine: MixedSignalEngine, sessions: usize) -> MixedSignalBackend {
        engine.provision_sessions(sessions);
        MixedSignalBackend { engine }
    }

    /// The wrapped engine (read access for stats and diagnostics).
    pub fn engine(&self) -> &MixedSignalEngine {
        &self.engine
    }

    /// Worker factory for [`crate::coordinator::Server::spawn_sharded`]:
    /// each worker maps the network onto its own bank of simulated
    /// cores. The layer→core mapping is planned and validated once, up
    /// front — the probe engine becomes the template the workers
    /// replicate — so a bad geometry fails here instead of panicking
    /// inside a worker, and the returned [`Plan`] lets callers inspect
    /// or print the placement the workers will execute.
    pub fn factory(
        weights: NetworkWeights,
        circuit: CircuitConfig,
        geometry: CoreGeometry,
    ) -> Result<(Plan, impl Fn() -> Box<dyn Backend> + Send + Sync + 'static)> {
        let plan = Plan::build(&weights.dims, &MappingConfig::with_geometry(geometry))?;
        Self::factory_from_plan(weights, circuit, plan, 1)
    }

    /// Like [`MixedSignalBackend::factory`], but for an explicit plan —
    /// callers with non-default planner knobs (core budgets, replication
    /// caps) serve exactly the placement they planned. `engine_threads`
    /// sets each worker engine's intra-plan traversal lanes
    /// ([`MixedSignalEngine::set_engine_threads`], ADR-007): results are
    /// bit-identical at every value, so it is purely a throughput knob.
    pub fn factory_from_plan(
        weights: NetworkWeights,
        circuit: CircuitConfig,
        plan: Plan,
        engine_threads: usize,
    ) -> Result<(Plan, impl Fn() -> Box<dyn Backend> + Send + Sync + 'static)> {
        let mut template = MixedSignalEngine::from_plan(weights, circuit, plan)?;
        template.set_engine_threads(engine_threads);
        let plan = template.plan.clone();
        Ok((plan, move || {
            let engine = template
                .replicate()
                .expect("mapping validated at factory construction");
            Box::new(MixedSignalBackend::new(engine)) as Box<dyn Backend>
        }))
    }

    /// Worker factory for [`crate::coordinator::StreamServer::spawn`]:
    /// each worker's engine provisions `sessions` resident slots, so
    /// the worker holds that many live sequences' analog state at once
    /// and advances them in lockstep. Validates the plan up front like
    /// [`MixedSignalBackend::factory_from_plan`].
    pub fn streaming_factory_from_plan(
        weights: NetworkWeights,
        circuit: CircuitConfig,
        plan: Plan,
        sessions: usize,
        engine_threads: usize,
    ) -> Result<(Plan, impl Fn() -> Box<dyn Backend> + Send + Sync + 'static)> {
        let mut template = MixedSignalEngine::from_plan(weights, circuit, plan)?;
        template.set_engine_threads(engine_threads);
        let plan = template.plan.clone();
        Ok((plan, move || {
            let engine = template
                .replicate()
                .expect("mapping validated at factory construction");
            Box::new(MixedSignalBackend::with_sessions(engine, sessions))
                as Box<dyn Backend>
        }))
    }
}

impl Backend for MixedSignalBackend {
    fn name(&self) -> &str {
        "mixed-signal"
    }

    /// Route the batch through the engine's lockstep batch path: the
    /// cores hold one analog state slot per sequence and every time
    /// step advances the whole batch through a single plan traversal.
    ///
    /// The engine requires uniform-shape batches, so a ragged batch
    /// (possible under the default, non-bucketed policy) is grouped by
    /// sequence length first and the labels scattered back into request
    /// order; a bucketed policy ([`crate::coordinator::BatchPolicy::bucketed`],
    /// the recommended serving configuration for this backend) always
    /// arrives as a single group. Results are bit-identical to
    /// per-sequence `classify` either way (tests/batch_parity.rs).
    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
        let mut labels = vec![0usize; seqs.len()];
        // stable sort: requests keep their arrival order within a group
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by_key(|&i| seqs[i].len());
        let mut group: Vec<&[f32]> = Vec::new();
        let mut start = 0;
        while start < order.len() {
            let len0 = seqs[order[start]].len();
            let end = start
                + order[start..]
                    .iter()
                    .take_while(|&&i| seqs[i].len() == len0)
                    .count();
            group.clear();
            group.extend(order[start..end].iter().map(|&i| seqs[i].as_slice()));
            let group_labels = self.engine.classify_batch(&group);
            for (&i, l) in order[start..end].iter().zip(group_labels) {
                labels[i] = l;
            }
            start = end;
        }
        labels
    }

    fn streaming(&mut self) -> Option<&mut dyn SessionBackend> {
        if self.engine.session_capacity() > 0 {
            Some(self)
        } else {
            None
        }
    }

    /// The engine's cumulative delta-sparsity counters (ADR-005) — all
    /// zeros unless the circuit was configured with `delta > 0`.
    fn delta_stats(&self) -> Option<crate::satsim::DeltaCounters> {
        Some(self.engine.delta_stats())
    }

    /// The engine's live §4.2 energy meter, merged across its cores —
    /// every cap event, switch toggle, and conversion this backend has
    /// simulated since construction.
    fn energy_stats(&self) -> Option<crate::energy::EnergyMeter> {
        Some(self.engine.energy())
    }
}

/// The streaming interface over the engine's slot pool: each live
/// session's analog state is resident in one engine slot, and every
/// tick advances the listed sessions through a single lockstep plan
/// traversal (`MixedSignalEngine::step_slots`). Streamed logits are
/// bit-identical to a one-shot classification of the same frames — the
/// slot-RNG seeding convention again (docs/adr/001, pinned by
/// tests/stream_parity.rs).
impl SessionBackend for MixedSignalBackend {
    fn session_capacity(&self) -> usize {
        self.engine.session_capacity()
    }

    fn frame_width(&self) -> usize {
        self.engine.weights.dims[0]
    }

    fn open_session(&mut self) -> Option<usize> {
        self.engine.lease_slot()
    }

    fn step_sessions(&mut self, slots: &[usize], frames: &[f32]) {
        self.engine.step_slots(slots, frames);
    }

    fn session_logits(&self, slot: usize) -> Vec<f32> {
        self.engine.logits_slot(slot)
    }

    fn close_session(&mut self, slot: usize) -> usize {
        let label = argmax(&self.engine.logits_slot(slot));
        self.engine.release_slot(slot);
        label
    }
}

/// PJRT backend: runs the AOT `sequence.hlo.txt` artifact, which maps
/// [T, B, 1] input sequences to [B, 10] logits. The artifact is compiled
/// for a fixed batch B; smaller batches are padded.
///
/// This backend requires uniform-length batches (it asserts on a
/// mismatch): serve it with [`crate::coordinator::BatchPolicy::bucketed`]
/// so the leader never hands it a ragged batch. Should a mismatch slip
/// through anyway, the serving loop contains the panic — that batch's
/// requests fail with `ServeError::BackendPanicked`, the worker lives.
pub struct PjrtBackend {
    exe: Executable,
    /// Sequence length the executable was compiled for.
    pub seq_len: usize,
    /// Batch size the executable was compiled for.
    pub batch: usize,
    /// Input width per frame.
    pub d_in: usize,
    /// Output class count.
    pub n_classes: usize,
}

impl PjrtBackend {
    /// Wrap a compiled executable with its fixed I/O shape.
    pub fn new(
        exe: Executable,
        seq_len: usize,
        batch: usize,
        d_in: usize,
        n_classes: usize,
    ) -> PjrtBackend {
        PjrtBackend { exe, seq_len, batch, d_in, n_classes }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn classify_batch(&mut self, seqs: &[Vec<f32>]) -> Vec<usize> {
        let mut labels = Vec::with_capacity(seqs.len());
        for chunk in seqs.chunks(self.batch) {
            // pack [T, B, d_in] with zero padding for short batches
            let mut buf = vec![0.0f32; self.seq_len * self.batch * self.d_in];
            for (b, seq) in chunk.iter().enumerate() {
                assert_eq!(seq.len(), self.seq_len * self.d_in,
                           "sequence length mismatch for AOT artifact");
                for t in 0..self.seq_len {
                    for d in 0..self.d_in {
                        buf[(t * self.batch + b) * self.d_in + d] =
                            seq[t * self.d_in + d];
                    }
                }
            }
            let out = self
                .exe
                .run_f32(&[(
                    &buf,
                    &[self.seq_len, self.batch, self.d_in],
                )])
                .expect("PJRT execution failed");
            let logits = &out[0]; // [B, n_classes]
            for b in 0..chunk.len() {
                labels.push(argmax(
                    &logits[b * self.n_classes..(b + 1) * self.n_classes],
                ));
            }
        }
        labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CircuitConfig, CoreGeometry};
    use crate::nn::weights::synthetic_network;

    #[test]
    fn golden_backend_serves() {
        let net = GoldenNetwork::new(synthetic_network(&[1, 8, 10], 3));
        let mut b = GoldenBackend::new(net);
        let seqs = vec![vec![0.5f32; 16], vec![0.9f32; 16]];
        let labels = b.classify_batch(&seqs);
        assert_eq!(labels.len(), 2);
        assert!(labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn mixed_signal_backend_serves() {
        let engine = MixedSignalEngine::new(
            synthetic_network(&[1, 8, 10], 3),
            CircuitConfig::ideal(),
            CoreGeometry { rows: 8, cols: 16 },
        )
        .unwrap();
        let mut b = MixedSignalBackend::new(engine);
        let labels = b.classify_batch(&[vec![0.5f32; 16]]);
        assert_eq!(labels.len(), 1);
        // delta machinery is off at the default threshold: the backend
        // reports counters (it has an engine), but they stay zero
        let d = b.delta_stats().unwrap();
        assert_eq!(d.components_fired + d.components_skipped, 0);
        // the live energy meter saw every step of the classification
        let m = b.energy_stats().unwrap();
        assert_eq!(m.steps, 16);
        assert!(m.cap_events > 0 && m.total_j() > 0.0);
    }

    #[test]
    fn factories_build_independent_consistent_backends() {
        let nw = synthetic_network(&[1, 8, 10], 3);
        let gf = GoldenBackend::factory(nw.clone());
        let seqs = vec![vec![0.5f32; 16]];
        let (mut a, mut b) = (gf(), gf());
        assert_eq!(a.classify_batch(&seqs), b.classify_batch(&seqs));

        let (plan, mf) = MixedSignalBackend::factory(
            nw.clone(),
            CircuitConfig::ideal(),
            CoreGeometry { rows: 8, cols: 16 },
        )
        .unwrap();
        assert_eq!(plan.n_cores, 2);
        let (mut c, mut d) = (mf(), mf());
        assert_eq!(c.classify_batch(&seqs), d.classify_batch(&seqs));
    }

    #[test]
    fn mixed_signal_backend_scatters_ragged_batches_by_length() {
        // ragged batch (default, non-bucketed policy): the backend must
        // group by length for the lockstep engine and return the labels
        // in request order — equal to per-sequence classification
        let nw = synthetic_network(&[1, 8, 10], 3);
        let engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::default(),
            CoreGeometry { rows: 8, cols: 16 },
        )
        .unwrap();
        let mut reference = MixedSignalBackend::new(engine.replicate().unwrap());
        let mut b = MixedSignalBackend::new(engine);
        let seqs: Vec<Vec<f32>> = [16usize, 8, 16, 4, 8]
            .iter()
            .enumerate()
            .map(|(i, &n)| (0..n).map(|t| ((t + i) % 3) as f32 / 2.0).collect())
            .collect();
        let want: Vec<usize> = seqs
            .iter()
            .map(|s| reference.classify_batch(&[s.clone()])[0])
            .collect();
        assert_eq!(b.classify_batch(&seqs), want);
    }

    #[test]
    fn mixed_signal_factory_plans_row_split_geometries() {
        // 100 inputs on 64-row cores: the factory returns a plan with
        // two row tiles and workers that serve it on the physics path
        // (the former rejects-bad-geometry case, inverted).
        let nw = synthetic_network(&[100, 8], 1);
        let (plan, mf) = MixedSignalBackend::factory(
            nw,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 64, cols: 64 },
        )
        .unwrap();
        assert_eq!(plan.layers[0].row_tiles, 2);
        assert_eq!(plan.n_cores, 2);
        // two independently replicated workers must serve identical
        // labels for the row-split placement
        let (mut a, mut b) = (mf(), mf());
        let seqs = vec![vec![0.4f32; 100 * 4], vec![0.9f32; 100 * 4]];
        let la = a.classify_batch(&seqs);
        assert_eq!(la.len(), 2);
        assert_eq!(la, b.classify_batch(&seqs));
    }

    #[test]
    fn plain_backends_expose_no_streaming_interface() {
        let nw = synthetic_network(&[1, 8, 10], 3);
        let mut g = GoldenBackend::new(GoldenNetwork::new(nw.clone()));
        assert!(g.streaming().is_none());
        let engine = MixedSignalEngine::new(
            nw,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 8, cols: 16 },
        )
        .unwrap();
        let mut m = MixedSignalBackend::new(engine);
        assert!(m.streaming().is_none());
    }

    #[test]
    fn golden_streaming_matches_one_shot_classification() {
        let nw = synthetic_network(&[1, 8, 10], 3);
        let mut reference = GoldenNetwork::new(nw.clone());
        let mut b = GoldenBackend::with_sessions(GoldenNetwork::new(nw), 2);
        let sb = b.streaming().expect("provisioned sessions");
        assert_eq!(sb.session_capacity(), 2);
        assert_eq!(sb.frame_width(), 1);
        let s0 = sb.open_session().unwrap();
        let s1 = sb.open_session().unwrap();
        assert!(sb.open_session().is_none(), "pool of 2 must exhaust");
        let seq_a: Vec<f32> = (0..16).map(|t| (t % 3) as f32 / 2.0).collect();
        let seq_b: Vec<f32> = (0..16).map(|t| (t % 5) as f32 / 4.0).collect();
        for t in 0..16 {
            // one lockstep tick advancing both interleaved sessions
            sb.step_sessions(&[s0, s1], &[seq_a[t], seq_b[t]]);
        }
        reference.classify(&seq_a);
        assert_eq!(sb.session_logits(s0), reference.logits());
        let want_a = argmax(&reference.logits());
        reference.classify(&seq_b);
        assert_eq!(sb.session_logits(s1), reference.logits());
        assert_eq!(sb.close_session(s0), want_a);
        // the freed slot admits (and resets for) a new session
        let s2 = sb.open_session().unwrap();
        assert_eq!(s2, s0);
        sb.step_sessions(&[s2], &[0.5]);
        reference.classify(&[0.5]);
        assert_eq!(sb.session_logits(s2), reference.logits());
    }

    #[test]
    fn mixed_signal_streaming_factory_provisions_slots() {
        let nw = synthetic_network(&[1, 8, 10], 3);
        let plan = Plan::build(
            &nw.dims,
            &MappingConfig::with_geometry(CoreGeometry { rows: 8, cols: 16 }),
        )
        .unwrap();
        let (_plan, mf) = MixedSignalBackend::streaming_factory_from_plan(
            nw,
            CircuitConfig::default(),
            plan,
            3,
            2,
        )
        .unwrap();
        let mut b = mf();
        let sb = b.streaming().expect("factory must provision sessions");
        assert_eq!(sb.session_capacity(), 3);
        let s = sb.open_session().unwrap();
        sb.step_sessions(&[s], &[0.7]);
        let logits = sb.session_logits(s);
        assert!(logits.iter().all(|l| l.is_finite()));
        assert!(sb.close_session(s) < 10);
    }

    #[test]
    fn mixed_signal_factory_rejects_degenerate_geometry_up_front() {
        // a zero-row geometry cannot hold anything — the factory must
        // fail at construction, not panic later inside a worker thread
        let nw = synthetic_network(&[4, 8], 1);
        assert!(MixedSignalBackend::factory(
            nw,
            CircuitConfig::ideal(),
            CoreGeometry { rows: 0, cols: 64 },
        )
        .is_err());
    }
}
