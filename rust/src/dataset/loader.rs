//! Loader for the MTF test split exported by the python generator
//! (`python -m compile.data --export`), used wherever bit-exact parity
//! with the python-side evaluation matters (Fig 4 traces, Fig 5 replay).

use anyhow::{bail, Result};

use crate::io::tensorfile::TensorFile;

/// Sequence-encoded test split: x is [n, T] (input dim 1), y is [n].
#[derive(Debug, Clone)]
pub struct TestSplit {
    pub seq_len: usize,
    pub x: Vec<Vec<f32>>,
    pub y: Vec<usize>,
}

/// Load a sequence test split from a tensorfile on disk.
pub fn load_test_split(path: &str) -> Result<TestSplit> {
    let tf = TensorFile::load(path)?;
    let xt = tf.req("x")?;
    let yt = tf.req("y")?;
    if xt.shape.len() != 2 {
        bail!("expected x of shape [n, T], got {:?}", xt.shape);
    }
    let (n, t) = (xt.shape[0], xt.shape[1]);
    let flat = xt.as_f32();
    let x: Vec<Vec<f32>> = (0..n)
        .map(|i| flat[i * t..(i + 1) * t].to_vec())
        .collect();
    let y: Vec<usize> = yt.as_i32()?.iter().map(|&v| v as usize).collect();
    if y.len() != n {
        bail!("label count {} != sample count {}", y.len(), n);
    }
    Ok(TestSplit { seq_len: t, x, y })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tensorfile::{Tensor, TensorFile};

    #[test]
    fn roundtrip_via_bytes() {
        let mut tf = TensorFile::new();
        tf.insert("x", Tensor::f32(vec![2, 4], vec![0.0, 0.5, 1.0, 0.25,
                                                    1.0, 0.0, 0.0, 0.75]));
        tf.insert("y", Tensor::i32(vec![2], vec![3, 7]));
        let dir = std::env::temp_dir().join("mtf_loader_test.mtf");
        tf.save(&dir).unwrap();
        let split = load_test_split(dir.to_str().unwrap()).unwrap();
        assert_eq!(split.seq_len, 4);
        assert_eq!(split.y, vec![3, 7]);
        assert_eq!(split.x[1][3], 0.75);
    }
}
