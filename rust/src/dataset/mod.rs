//! synthMNIST in rust: the synthetic sequential-digit workload
//! (algorithmic mirror of `python/compile/data.py`).
//!
//! Two sources of data on the rust side:
//! * [`glyphs`] — the native generator (used by the serving driver and
//!   benches for unlimited load without touching python); statistically
//!   identical to the python generator but *not* bit-identical (different
//!   RNG), so…
//! * [`loader`] — …parity tests and the Fig 4/Fig 5 replays read the MTF
//!   test split exported by `python -m compile.data --export`, which is
//!   bit-exact.

pub mod glyphs;
pub mod loader;

pub use glyphs::{make_glyph, make_split, Sample};
pub use loader::load_test_split;
