//! Stroke-skeleton digit rendering (rust twin of `data.py`).
//!
//! Digits 0–9 are polylines in the unit square, rendered with a smooth
//! distance-falloff brush after a random affine jitter, plus pixel noise.
//! Sequences are the row-major pixel scan (T = size², input dim 1).

use crate::util::rng::Rng;

/// One rendered sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub label: usize,
    /// Row-major pixels in [0,1], length size².
    pub pixels: Vec<f32>,
}

/// Polyline skeletons (identical coordinates to data.py).
fn strokes(digit: usize) -> &'static [&'static [(f32, f32)]] {
    const D0: &[&[(f32, f32)]] = &[&[(0.50, 0.08), (0.78, 0.25), (0.78, 0.75),
        (0.50, 0.92), (0.22, 0.75), (0.22, 0.25), (0.50, 0.08)]];
    const D1: &[&[(f32, f32)]] = &[&[(0.35, 0.25), (0.55, 0.08), (0.55, 0.92)],
        &[(0.30, 0.92), (0.75, 0.92)]];
    const D2: &[&[(f32, f32)]] = &[&[(0.25, 0.25), (0.40, 0.10), (0.65, 0.10),
        (0.78, 0.28), (0.70, 0.50), (0.25, 0.92), (0.78, 0.92)]];
    const D3: &[&[(f32, f32)]] = &[&[(0.25, 0.15), (0.60, 0.10), (0.75, 0.27),
        (0.55, 0.47), (0.75, 0.68), (0.60, 0.90), (0.25, 0.85)]];
    const D4: &[&[(f32, f32)]] = &[&[(0.65, 0.92), (0.65, 0.08), (0.22, 0.62),
        (0.80, 0.62)]];
    const D5: &[&[(f32, f32)]] = &[&[(0.75, 0.10), (0.30, 0.10), (0.28, 0.45),
        (0.60, 0.42), (0.78, 0.62), (0.70, 0.88), (0.25, 0.90)]];
    const D6: &[&[(f32, f32)]] = &[&[(0.70, 0.10), (0.35, 0.35), (0.25, 0.65),
        (0.40, 0.90), (0.70, 0.85), (0.75, 0.60), (0.45, 0.52), (0.27, 0.62)]];
    const D7: &[&[(f32, f32)]] = &[&[(0.22, 0.10), (0.78, 0.10), (0.45, 0.92)],
        &[(0.35, 0.52), (0.68, 0.52)]];
    const D8: &[&[(f32, f32)]] = &[&[(0.50, 0.48), (0.70, 0.32), (0.62, 0.10),
        (0.38, 0.10), (0.30, 0.32), (0.50, 0.48), (0.72, 0.68), (0.60, 0.92),
        (0.40, 0.92), (0.28, 0.68), (0.50, 0.48)]];
    const D9: &[&[(f32, f32)]] = &[&[(0.73, 0.38), (0.55, 0.48), (0.30, 0.40),
        (0.25, 0.15), (0.55, 0.08), (0.73, 0.20), (0.73, 0.38), (0.65, 0.92)]];
    match digit {
        0 => D0, 1 => D1, 2 => D2, 3 => D3, 4 => D4,
        5 => D5, 6 => D6, 7 => D7, 8 => D8, 9 => D9,
        _ => panic!("digit out of range: {digit}"),
    }
}

/// Segments (x1,y1,x2,y2) of a digit after affine jitter.
fn jittered_segments(digit: usize, rng: &mut Rng) -> Vec<[f32; 4]> {
    let th = rng.uniform_in(-0.25, 0.25) as f32;
    let sx = rng.uniform_in(0.82, 1.12) as f32;
    let sy = rng.uniform_in(0.82, 1.12) as f32;
    let sh = rng.uniform_in(-0.15, 0.15) as f32;
    let tx = rng.uniform_in(-0.06, 0.06) as f32;
    let ty = rng.uniform_in(-0.06, 0.06) as f32;
    let (c, s) = (th.cos(), th.sin());
    let m = [[c * sx, (-s + sh) * sy], [s * sx, c * sy]];
    let tf = |x: f32, y: f32| -> (f32, f32) {
        let (px, py) = (x - 0.5, y - 0.5);
        (
            m[0][0] * px + m[0][1] * py + 0.5 + tx,
            m[1][0] * px + m[1][1] * py + 0.5 + ty,
        )
    };
    let mut segs = Vec::new();
    for line in strokes(digit) {
        for w in line.windows(2) {
            let (x1, y1) = tf(w[0].0, w[0].1);
            let (x2, y2) = tf(w[1].0, w[1].1);
            segs.push([x1, y1, x2, y2]);
        }
    }
    segs
}

/// Render one glyph: distance-field brush over the segments + noise.
pub fn make_glyph(digit: usize, size: usize, rng: &mut Rng, noise: f64) -> Vec<f32> {
    let segs = jittered_segments(digit, rng);
    let thickness = rng.uniform_in(0.045, 0.075) as f32;
    let mut img = vec![0.0f32; size * size];
    for (row, chunk) in img.chunks_mut(size).enumerate() {
        let py = (row as f32 + 0.5) / size as f32;
        for (col, px_out) in chunk.iter_mut().enumerate() {
            let px = (col as f32 + 0.5) / size as f32;
            let mut dmin = f32::MAX;
            for s in &segs {
                let (ax, ay, bx, by) = (s[0], s[1], s[2], s[3]);
                let (abx, aby) = (bx - ax, by - ay);
                let denom = (abx * abx + aby * aby).max(1e-12);
                let t = (((px - ax) * abx + (py - ay) * aby) / denom)
                    .clamp(0.0, 1.0);
                let (qx, qy) = (ax + t * abx, ay + t * aby);
                let d = ((px - qx).powi(2) + (py - qy).powi(2)).sqrt();
                dmin = dmin.min(d);
            }
            let v = (1.5 - dmin / thickness).clamp(0.0, 1.0);
            let n = rng.normal_scaled(0.0, noise) as f32;
            *px_out = (v + n).clamp(0.0, 1.0);
        }
    }
    img
}

/// Generate a class-balanced split of `n` samples.
pub fn make_split(n: usize, size: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Rng::new(seed ^ 0xD1617);
    let mut labels: Vec<usize> = (0..n).map(|i| i % 10).collect();
    rng.shuffle(&mut labels);
    labels
        .into_iter()
        .enumerate()
        .map(|(i, label)| {
            let mut g_rng = Rng::new(seed
                .wrapping_mul(0x9E37_79B9)
                .wrapping_add(i as u64 * 31 + label as u64));
            Sample { label, pixels: make_glyph(label, size, &mut g_rng, 0.05) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyphs_are_in_range_and_nonempty() {
        let mut rng = Rng::new(1);
        for d in 0..10 {
            let img = make_glyph(d, 16, &mut rng, 0.05);
            assert_eq!(img.len(), 256);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let ink: f32 = img.iter().sum();
            assert!(ink > 5.0, "digit {d} rendered empty (ink={ink})");
        }
    }

    #[test]
    fn split_is_balanced_and_deterministic() {
        let a = make_split(100, 8, 7);
        let b = make_split(100, 8, 7);
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
        let mut counts = [0usize; 10];
        for s in &a {
            counts[s.label] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn different_seeds_different_images() {
        let a = make_split(10, 8, 1);
        let b = make_split(10, 8, 2);
        assert!(a.iter().zip(b.iter()).any(|(x, y)| x.pixels != y.pixels));
    }

    #[test]
    fn glyph_classes_are_visually_distinct() {
        // crude separability check: mean inter-class L2 distance of the
        // *clean* class templates must dominate intra-class jitter.
        let clean = |d: usize, idx: u64| {
            let mut rng = Rng::new(1000 + idx);
            make_glyph(d, 16, &mut rng, 0.0)
        };
        let l2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        let mut inter = 0.0;
        let mut n_inter = 0;
        let mut intra = 0.0;
        let mut n_intra = 0;
        for d1 in 0..10 {
            intra += l2(&clean(d1, 0), &clean(d1, 1));
            n_intra += 1;
            for d2 in (d1 + 1)..10 {
                inter += l2(&clean(d1, 0), &clean(d2, 0));
                n_inter += 1;
            }
        }
        let inter = inter / n_inter as f32;
        let intra = intra / n_intra as f32;
        // Pixel-L2 underestimates separability (affine jitter moves mass
        // without changing identity); require inter > intra as a sanity
        // floor — learnability is established by the training runs.
        assert!(
            inter > intra,
            "classes not separable: inter {inter} vs intra {intra}"
        );
    }
}
