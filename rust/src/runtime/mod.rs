//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! once by `python/compile/aot.py`) and execute them from the request
//! path. Python is never involved at runtime — the HLO text is parsed,
//! compiled and run by the XLA CPU client through the `xla` crate.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that the image's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Availability: the `xla` bindings only exist in environments that
//! vendor them, so the real implementation is gated behind the `pjrt`
//! cargo feature. Without it this module compiles to a stub with the
//! same API — including [`Executable`] staying `!Send`, so code written
//! against the stub keeps the thread-affinity discipline the real PJRT
//! handles demand — whose constructors return a descriptive error.

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    /// A compiled XLA executable plus its I/O description.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Model name the executable was loaded under.
        pub name: String,
    }

    /// The PJRT client and the loaded model executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// A runtime on the host CPU platform.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(Runtime { client })
        }

        /// Name of the PJRT platform in use.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact.
        pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
            let path = path.as_ref();
            if !path.exists() {
                bail!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
            Ok(Executable {
                exe,
                name: path.file_name().unwrap().to_string_lossy().into_owned(),
            })
        }
    }

    impl Executable {
        /// Execute with f32 buffers. Each input is (data, shape); the result
        /// is the flattened f32 tuple elements (aot.py lowers with
        /// return_tuple=True).
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e:?}"))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
            let tuple = result
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
            let mut out = Vec::with_capacity(tuple.len());
            for t in tuple {
                out.push(
                    t.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?,
                );
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::marker::PhantomData;
    use std::path::Path;

    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (the xla bindings must be vendored; see rust/Cargo.toml)";

    /// Stub for the compiled XLA executable. Deliberately `!Send` (via
    /// the raw-pointer marker) to mirror the real PJRT handles, which
    /// are bound to the thread that created them — backends must be
    /// constructed on their worker thread either way.
    pub struct Executable {
        /// Model name the executable was loaded under.
        pub name: String,
        _not_send: PhantomData<*const ()>,
    }

    /// Stub for the PJRT client.
    pub struct Runtime {
        _not_send: PhantomData<*const ()>,
    }

    impl Runtime {
        /// The stub runtime (PJRT feature disabled).
        pub fn cpu() -> Result<Runtime> {
            bail!(UNAVAILABLE)
        }

        /// Name of the (stub) platform.
        pub fn platform(&self) -> String {
            "stub (no pjrt feature)".to_string()
        }

        /// Unavailable in the stub build — always errors.
        pub fn load_hlo_text(&self, _path: impl AsRef<Path>) -> Result<Executable> {
            bail!(UNAVAILABLE)
        }
    }

    impl Executable {
        /// Unavailable in the stub build — always errors.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            bail!(UNAVAILABLE)
        }
    }
}

pub use imp::{Executable, Runtime};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::cpu().unwrap();
        match rt.load_hlo_text("/nonexistent/model.hlo.txt") {
            Ok(_) => panic!("expected an error for a missing artifact"),
            Err(e) => assert!(e.to_string().contains("make artifacts")),
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
