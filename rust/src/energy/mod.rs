//! Energy accounting for the mixed-signal cores (paper §4.2).
//!
//! The paper bounds the energy per time step by the repeated charging and
//! discharging of the sampling capacitors plus the toggling of the
//! switches. The meter tracks both:
//!
//! * **capacitor events** — charging a cap C from V_a to V_b through a
//!   switch dissipates ½·C·ΔV² in the switch resistance regardless of R
//!   (the classic adiabatic-limit argument), logged per event;
//! * **switch gate events** — each transmission-gate toggle burns
//!   C_gate·V_DD² in the gate driver;
//! * comparator decisions and SAR conversions (counted; their analog
//!   energy is far below the array's, as the paper notes for its ADC).
//!
//! The meter distinguishes *simulated* energy (actual ΔV of each event)
//! from the *worst-case bound* (every event at full swing), reproducing
//! both the paper's bound-style estimate and an activity-dependent
//! refinement the paper leaves to future work.

use crate::config::CircuitConfig;

#[derive(Debug, Clone, Default, PartialEq)]
/// Switching-activity counter priced into joules.
pub struct EnergyMeter {
    /// Dissipated energy from capacitor (dis)charging events (J).
    pub cap_energy_j: f64,
    /// Energy burned driving switch gates (J).
    pub gate_energy_j: f64,
    /// Event counts.
    pub cap_events: u64,
    /// Capacitor/segment switch toggles.
    pub switch_toggles: u64,
    /// Clocked comparator decisions.
    pub comparator_decisions: u64,
    /// Full SAR conversions.
    pub adc_conversions: u64,
    /// Time steps accounted (for per-step reporting).
    pub steps: u64,
}

impl EnergyMeter {
    /// A zeroed meter.
    pub fn new() -> EnergyMeter {
        EnergyMeter::default()
    }

    /// Log charging a capacitor `c` (F) from `v_from` to `v_to`.
    #[inline]
    pub fn cap_charge(&mut self, c: f64, v_from: f64, v_to: f64) {
        let dv = v_to - v_from;
        self.cap_energy_j += 0.5 * c * dv * dv;
        self.cap_events += 1;
    }

    /// Log `n` switch toggles.
    #[inline]
    pub fn toggles(&mut self, cfg: &CircuitConfig, n: u64) {
        self.toggles_cached(n, cfg.c_gate * cfg.v_dd * cfg.v_dd);
    }

    /// Hot-path variant with the per-toggle energy pre-multiplied.
    #[inline]
    pub fn toggles_cached(&mut self, n: u64, e_per_toggle: f64) {
        self.switch_toggles += n;
        self.gate_energy_j += n as f64 * e_per_toggle;
    }

    #[inline]
    /// Count one comparator decision.
    pub fn comparator(&mut self) {
        self.comparator_decisions += 1;
    }

    #[inline]
    /// Count one full ADC conversion.
    pub fn adc_conversion(&mut self) {
        self.adc_conversions += 1;
    }

    /// Mark one network step complete.
    pub fn step_done(&mut self) {
        self.steps += 1;
    }

    /// Total energy so far, in joules.
    pub fn total_j(&self) -> f64 {
        self.cap_energy_j + self.gate_energy_j
    }

    /// Mean energy per completed step, in joules.
    pub fn per_step_j(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.total_j() / self.steps as f64
        }
    }

    /// Fold another meter's counts into this one.
    pub fn merge(&mut self, other: &EnergyMeter) {
        self.cap_energy_j += other.cap_energy_j;
        self.gate_energy_j += other.gate_energy_j;
        self.cap_events += other.cap_events;
        self.switch_toggles += other.switch_toggles;
        self.comparator_decisions += other.comparator_decisions;
        self.adc_conversions += other.adc_conversions;
        // steps intentionally not summed: meters merged across cores
        // describe the same time steps.
        self.steps = self.steps.max(other.steps);
    }

    /// Fold a meter that covers **different** time steps into this one
    /// — per-worker meters at serving shutdown, where each worker's
    /// engine stepped through its own requests. Identical to
    /// [`EnergyMeter::merge`] except `steps` sums, so
    /// [`EnergyMeter::per_step_j`] stays an average over every step any
    /// worker ran rather than over the busiest worker's.
    pub fn merge_disjoint(&mut self, other: &EnergyMeter) {
        let steps = self.steps + other.steps;
        self.merge(other);
        self.steps = steps;
    }
}

/// Analytic worst-case bound for one core time step (the paper's §4.2
/// estimate): every sampling capacitor sees a full-swing recharge and
/// every switch toggles (the z ≡ 1 scenario).
///
/// Per synapse and step: the h̃ cap and the z cap resample (the h cap
/// holds), the swap then fully exchanges the banks; switches: 4 rail
/// switches + 2 share switches + swap switches per synapse pair.
pub fn worst_case_step_bound(
    cfg: &CircuitConfig,
    rows: usize,
    cols: usize,
) -> f64 {
    let n = (rows * cols) as f64;
    // Conservative supply-referred swing (the paper's "bounded by"
    // phrasing): every recharge at full V_DD. Simulated (activity-
    // dependent) energy uses the actual rail-to-rail ΔV per event and
    // lands well below this bound.
    let dv = cfg.v_dd;
    // 3 caps per synapse can each see one full recharge per step
    // (h̃ sample, z sample, and the swapped state cap settling).
    let cap_e = 3.0 * n * 0.5 * cfg.c_unit * dv * dv;
    // Switch toggles per synapse: 4 rail-select + 1 share (h̃) + 1 share
    // (z) + 2 swap = 8; plus per-column: ADC sharing segment switches.
    let toggles = 8.0 * n + 6.0 * cols as f64;
    let gate_e = toggles * cfg.c_gate * cfg.v_dd * cfg.v_dd;
    cap_e + gate_e
}

/// The paper's reference configuration: 4 cores of 64×64 (§4.2).
pub fn paper_network_bound(cfg: &CircuitConfig) -> f64 {
    4.0 * worst_case_step_bound(cfg, 64, 64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cap_event_energy() {
        let mut m = EnergyMeter::new();
        m.cap_charge(1e-15, 0.0, 1.0);
        assert!((m.cap_energy_j - 0.5e-15).abs() < 1e-30);
        assert_eq!(m.cap_events, 1);
    }

    #[test]
    fn per_step_average() {
        let cfg = CircuitConfig::default();
        let mut m = EnergyMeter::new();
        m.cap_charge(1e-15, 0.0, 1.0);
        m.toggles(&cfg, 10);
        m.step_done();
        m.cap_charge(1e-15, 0.0, 1.0);
        m.step_done();
        assert_eq!(m.steps, 2);
        assert!(m.per_step_j() > 0.0);
        assert!((m.per_step_j() - m.total_j() / 2.0).abs() < 1e-30);
    }

    #[test]
    fn bound_scale_matches_paper_order_of_magnitude() {
        // With the default electrical parameters the 4-core worst case
        // must land at the paper's quoted scale (169 pJ per time step).
        let cfg = CircuitConfig::default();
        let bound = paper_network_bound(&cfg);
        let pj = bound * 1e12;
        assert!(pj > 20.0 && pj < 800.0, "bound = {pj} pJ");
    }

    #[test]
    fn merge_disjoint_sums_steps() {
        let mut a = EnergyMeter::new();
        let mut b = EnergyMeter::new();
        a.cap_charge(1e-15, 0.0, 0.5);
        a.step_done();
        a.step_done();
        b.cap_charge(1e-15, 0.0, 0.5);
        b.step_done();
        // same-step merge keeps the lockstep count …
        let mut lock = a.clone();
        lock.merge(&b);
        assert_eq!(lock.steps, 2);
        // … disjoint merge sums it (per-worker meters at shutdown)
        a.merge_disjoint(&b);
        assert_eq!(a.steps, 3);
        assert_eq!(a.cap_events, 2);
        assert!((a.per_step_j() - a.total_j() / 3.0).abs() < 1e-30);
    }

    #[test]
    fn merge_accumulates() {
        let cfg = CircuitConfig::default();
        let mut a = EnergyMeter::new();
        let mut b = EnergyMeter::new();
        a.cap_charge(1e-15, 0.0, 0.5);
        b.toggles(&cfg, 3);
        b.comparator();
        a.merge(&b);
        assert_eq!(a.switch_toggles, 3);
        assert_eq!(a.comparator_decisions, 1);
        assert!(a.total_j() > 0.0);
    }
}
