//! Self-contained utility substrate: the offline vendored crate set has
//! no serde/clap/criterion/rand/proptest, so the library carries its own
//! minimal, tested replacements.

pub mod bench;
pub mod check;
pub mod cli;
pub mod http;
pub mod json;
pub mod pool;
pub mod rng;
