//! Minimal JSON parser + writer (serde/serde_json are not in the offline
//! vendored crate set). Covers the full JSON grammar; used for configs,
//! run metadata, and interop with the python training outputs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — handy for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
/// Parse failure with byte position.
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------

    /// Object field `key`, if this is an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element `i`, if this is an array.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as i64, if integral.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    /// Required numeric field `key`, erroring if absent.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    /// Required string field `key`, erroring if absent.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    // -- construction ------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Insert or replace field `key` (self must be an object).
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // -- parsing -----------------------------------------------------------

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 4; // consume first escape's hex
                                if self.b[self.pos + 1..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.pos + 1..self.pos + 5],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    self.pos += 4; // consume low-half hex
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                self.pos += 4;
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"k":null},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
