//! Deterministic PRNG for the simulator (no `rand` crate in the offline
//! vendored set, so this is a self-contained xoshiro256++ with a SplitMix64
//! seeder — the de-facto standard pairing).
//!
//! All stochastic circuit effects (capacitor mismatch, kT/C noise,
//! comparator offset/noise) draw from an explicitly seeded `Rng`, so every
//! mixed-signal simulation is reproducible from its config seed.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
///
/// Also the public seed-splitting primitive for Monte-Carlo device
/// sweeps (`crate::montecarlo::instance_seed`): successive calls on a
/// master-seed state yield well-mixed, decorrelated per-instance seeds
/// (ADR-008).
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (e.g. one per synapse column).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    /// The next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // take the top 53 bits for a dyadic uniform
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method.
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fast standard normal via Acklam's inverse-CDF rational
    /// approximation (|relative error| < 1.15e-9 — far below any physical
    /// noise source simulated here). ~3× faster than Box–Muller in the
    /// satsim hot path because the central region needs no
    /// transcendentals beyond one division.
    #[inline]
    pub fn normal_fast(&mut self) -> f64 {
        let p = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        // clamp away from 0/1 (probability 2^-53 — unreachable in practice)
        icdf_normal(p.clamp(1e-300, 1.0 - 1e-16))
    }
}

/// Acklam's inverse normal CDF.
#[inline]
fn icdf_normal(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02,
        -2.759285104469687e+02, 1.383577518672690e+02,
        -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02,
        -1.556989798598866e+02, 6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01,
        -2.400758277161838e+00, -2.549732539343734e+00,
        4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01,
        2.445134137142996e+00, 3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

impl Rng {
    /// Bernoulli with probability p.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn normal_fast_moments_and_symmetry() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal_fast();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn icdf_matches_known_quantiles() {
        // Φ⁻¹(0.975) = 1.959964, Φ⁻¹(0.5) = 0, Φ⁻¹(0.001) = −3.090232
        assert!((icdf_normal(0.975) - 1.959964).abs() < 1e-5);
        assert!(icdf_normal(0.5).abs() < 1e-9);
        assert!((icdf_normal(0.001) + 3.090232).abs() < 1e-4);
        assert!((icdf_normal(0.999) - 3.090232).abs() < 1e-4);
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
