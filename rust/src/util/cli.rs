//! Tiny command-line parser (clap is not in the offline vendored crate
//! set). Supports `--key value`, `--key=value`, `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
/// Parsed `--key[=value]` command-line arguments.
pub struct Args {
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(body) = item.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether boolean flag `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// Parse `--name` as usize, or `default`.
    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Parse `--name` as u64, or `default`.
    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Parse `--name` as f64, or `default`.
    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // NB `--flag value`-ambiguity: a bare `--name` followed by a
        // non-option token is parsed as an option with that value, so
        // flags either come last or use the `--key=value` form.
        let a = parse("serve pos2 --port 8080 --config=x.json --verbose");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.opt("port"), Some("8080"));
        assert_eq!(a.opt("config"), Some("x.json"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "pos2"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--n 42 --x 1.5");
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse("--n abc").get_usize("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quiet");
        assert!(a.flag("quiet"));
        assert_eq!(a.opt("quiet"), None);
    }
}
