//! In-repo micro-benchmark harness (criterion is not in the offline
//! vendored crate set). Provides warmup, adaptive iteration counts,
//! robust statistics (median / MAD), and the table printer the
//! `rust/benches/*` targets use to regenerate the paper's tables.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
/// Robust timing summary of one benchmark.
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Median per-iteration time (ns).
    pub median_ns: f64,
    /// Median absolute deviation (ns).
    pub mad_ns: f64,
    /// Fastest iteration (ns).
    pub min_ns: f64,
    /// Mean per-iteration time (ns).
    pub mean_ns: f64,
}

impl BenchResult {
    /// Median per-iteration time as a `Duration`.
    pub fn per_iter(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }

    /// Items per second at the median time.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

/// Human-readable time with sensible units.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: warm up for ~10% of the budget, then sample until
/// the time budget is used. Each *sample* measures a batch of iterations
/// sized so one batch is ≥ ~1 ms (amortizes timer overhead).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + batch size calibration
    let warmup_end = Instant::now() + budget.mul_f64(0.1).max(Duration::from_millis(10));
    let mut one = Duration::ZERO;
    let mut count = 0u64;
    while Instant::now() < warmup_end || count == 0 {
        let t = Instant::now();
        f();
        one += t.elapsed();
        count += 1;
    }
    let per_call = (one.as_nanos() as f64 / count as f64).max(1.0);
    let batch = ((1e6 / per_call).ceil() as u64).clamp(1, 1_000_000);

    let mut samples: Vec<f64> = Vec::new();
    let mut iters = 0u64;
    let end = Instant::now() + budget.mul_f64(0.9);
    while Instant::now() < end || samples.len() < 5 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(dt);
        iters += batch;
        if samples.len() >= 2000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mad_ns: mad,
        min_ns: samples[0],
        mean_ns: mean,
    }
}

/// Convenience: run with the default 2 s budget and print one line.
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_secs(2), f);
    println!(
        "  {:<44} {:>12} ± {:<10} (n={})",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.mad_ns),
        r.iters
    );
    r
}

/// Simple fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  | {} |", cols.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("  |-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench("noop-ish", Duration::from_millis(50), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters > 100);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // just exercise the path
    }
}
