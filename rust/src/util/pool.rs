//! A small reusable scoped fork-join thread pool — std-only, in keeping
//! with the anyhow-only crate policy (design record: ADR-007).
//!
//! Built for exactly one call shape: the mixed-signal engine's threaded
//! plan traversal, where a handful of *independent* tasks (disjoint
//! cores of one layer) fan out per time step, and the caller must block
//! until every task has finished before it touches the results. That
//! blocking join is also what makes the lifetime story sound: the job
//! closure may borrow caller-local state non-`'static`, because
//! [`ScopedPool::run`] never returns while a worker can still observe
//! the borrow.
//!
//! Steady-state discipline: the pool allocates only at construction
//! (worker threads, shared control block). [`ScopedPool::run`] itself
//! performs no heap allocation — a mutex handshake, an atomic work
//! cursor, and a raw borrow of the caller's closure — so it is safe to
//! call from inside the engine's zero-alloc step path
//! (tests/hot_path_alloc.rs runs it under the counting allocator).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Lifetime-erased pointer to the caller's job closure. Only ever
/// dereferenced between the epoch publication and the `active == 0`
/// join in [`ScopedPool::run`], while the borrow it was cast from is
/// pinned by the blocked caller.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls are safe), and the
// pointer is only dereferenced while `ScopedPool::run` keeps the
// original borrow alive on the calling thread (it blocks until every
// worker has finished with the job).
unsafe impl Send for JobPtr {}

/// Mutex-guarded control state of the pool.
struct Ctrl {
    /// Monotone job counter; workers wait for it to advance.
    epoch: u64,
    /// Workers still running the current job.
    active: usize,
    /// The current job, present while `active > 0`.
    job: Option<JobPtr>,
    /// A worker's job closure panicked (re-raised by `run`).
    panicked: bool,
    /// Workers exit at the next wakeup.
    shutdown: bool,
}

struct Shared {
    m: Mutex<Ctrl>,
    /// Wakes workers on a new epoch (or shutdown).
    work_cv: Condvar,
    /// Wakes the caller when the last worker finishes.
    done_cv: Condvar,
    /// Next task index to claim; tasks are distributed dynamically so an
    /// imbalanced split (e.g. a wide owner tile) self-levels.
    cursor: AtomicUsize,
    /// Task count of the current job.
    limit: AtomicUsize,
}

/// A persistent fork-join pool of `threads − 1` workers; the calling
/// thread participates as the remaining lane, so `threads == 1` is the
/// serial case with no pool traffic at all.
pub struct ScopedPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ScopedPool {
    /// Build a pool that executes jobs on `threads` lanes total
    /// (clamped to ≥ 1): the caller plus `threads − 1` spawned workers.
    pub fn new(threads: usize) -> ScopedPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            m: Mutex::new(Ctrl {
                epoch: 0,
                active: 0,
                job: None,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            cursor: AtomicUsize::new(0),
            limit: AtomicUsize::new(0),
        });
        let workers = (1..threads)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("satsim-pool-{lane}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("spawning a pool worker thread")
            })
            .collect();
        ScopedPool { shared, workers }
    }

    /// Total lanes (caller included).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `job(i)` for every task index `i in 0..n`, distributing tasks
    /// across all lanes via an atomic cursor, and return only when every
    /// task has completed. The closure may borrow caller-local state:
    /// the blocking join keeps those borrows alive for as long as any
    /// worker can observe them. Tasks must be independent — `job` runs
    /// concurrently with itself on distinct indices.
    ///
    /// Allocation-free on the non-panic path; a panic inside `job` (on
    /// any lane) is re-raised here after all lanes have stopped touching
    /// the borrow.
    pub fn run(&self, n: usize, job: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.workers.is_empty() || n == 1 {
            // serial fast path: no handshake, no atomics
            let r = catch_unwind(AssertUnwindSafe(|| {
                for i in 0..n {
                    job(i);
                }
            }));
            if let Err(p) = r {
                resume_unwind(p);
            }
            return;
        }
        self.shared.cursor.store(0, Ordering::Relaxed);
        self.shared.limit.store(n, Ordering::Relaxed);
        {
            // lint: allow(panic, mutex poisoning is fatal by design — a panicked lane already aborted the step)
            let mut c = self.shared.m.lock().expect("pool mutex poisoned");
            c.job = Some(JobPtr(job));
            c.epoch += 1;
            c.active = self.workers.len();
            drop(c);
            self.shared.work_cv.notify_all();
        }
        // the caller is a full lane: drain tasks until the cursor runs dry
        let main_result =
            catch_unwind(AssertUnwindSafe(|| Self::drain(&self.shared, job)));
        // join: block until every worker has finished with the job — the
        // step that makes the lifetime erasure in JobPtr sound
        // lint: allow(panic, mutex poisoning is fatal by design — a panicked lane already aborted the step)
        let mut c = self.shared.m.lock().expect("pool mutex poisoned");
        while c.active > 0 {
            // lint: allow(panic, mutex poisoning is fatal by design — a panicked lane already aborted the step)
            c = self.shared.done_cv.wait(c).expect("pool mutex poisoned");
        }
        c.job = None;
        let worker_panicked = std::mem::take(&mut c.panicked);
        drop(c);
        if let Err(p) = main_result {
            resume_unwind(p);
        }
        assert!(!worker_panicked, "scoped pool worker panicked during a job");
    }

    /// Claim-and-run loop shared by the caller lane and the workers.
    fn drain(shared: &Shared, job: &(dyn Fn(usize) + Sync)) {
        let limit = shared.limit.load(Ordering::Relaxed);
        loop {
            let i = shared.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= limit {
                return;
            }
            job(i);
        }
    }

    fn worker_loop(shared: &Shared) {
        let mut seen = 0u64;
        loop {
            let job = {
                // lint: allow(panic, worker dies with the pool if the mutex is poisoned)
                let mut c = shared.m.lock().expect("pool mutex poisoned");
                while c.epoch == seen && !c.shutdown {
                    // lint: allow(panic, worker dies with the pool if the mutex is poisoned)
                    c = shared.work_cv.wait(c).expect("pool mutex poisoned");
                }
                if c.shutdown {
                    return;
                }
                seen = c.epoch;
                match c.job {
                    Some(j) => j,
                    // epoch advanced with no job only at shutdown; treat
                    // a spurious state as an empty job
                    None => continue,
                }
            };
            // SAFETY: `run` blocks until `active == 0`, so the borrow
            // behind this pointer is alive for the whole drain below.
            let f = unsafe { &*job.0 };
            let r = catch_unwind(AssertUnwindSafe(|| Self::drain(shared, f)));
            // lint: allow(panic, worker dies with the pool if the mutex is poisoned)
            let mut c = shared.m.lock().expect("pool mutex poisoned");
            if r.is_err() {
                c.panicked = true;
            }
            c.active -= 1;
            if c.active == 0 {
                shared.done_cv.notify_all();
            }
        }
    }
}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        {
            // a poisoned mutex at teardown means a worker already died
            // panicking; detach instead of double-panicking
            let Ok(mut c) = self.shared.m.lock() else { return };
            c.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = ScopedPool::new(threads);
            assert_eq!(pool.threads(), threads);
            for n in [0usize, 1, 3, 64, 257] {
                let hits: Vec<AtomicU64> =
                    (0..n).map(|_| AtomicU64::new(0)).collect();
                pool.run(n, &|i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        1,
                        "task {i} at {threads} threads, n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn borrows_caller_state_mutably_through_disjoint_tasks() {
        // the scoped contract: tasks write disjoint slices of a local
        // buffer borrowed across the pool boundary
        let pool = ScopedPool::new(3);
        let mut out = vec![0u64; 100];
        {
            let chunks: Vec<&mut [u64]> = out.chunks_mut(10).collect();
            let cells: Vec<Mutex<&mut [u64]>> =
                chunks.into_iter().map(Mutex::new).collect();
            pool.run(cells.len(), &|k| {
                let mut chunk = cells[k].lock().unwrap();
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (k * 10 + j) as u64;
                }
            });
        }
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        let pool = ScopedPool::new(4);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(8, &|i| {
                total.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * (1 + 8) * 8 / 2);
    }

    #[test]
    fn worker_panic_is_reraised_on_the_caller() {
        let pool = ScopedPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i % 2 == 1 {
                    panic!("boom {i}");
                }
            });
        }));
        assert!(r.is_err(), "a panicking task must fail the run");
        // the pool survives and serves the next job
        let total = AtomicU64::new(0);
        pool.run(4, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ScopedPool::new(1);
        let mut sum = 0u64;
        {
            let cell = Mutex::new(&mut sum);
            pool.run(10, &|i| {
                **cell.lock().unwrap() += i as u64;
            });
        }
        assert_eq!(sum, 45);
    }
}
