//! In-repo property-testing helper (proptest is not in the offline
//! vendored crate set). Runs a property over many random cases from a
//! deterministic seed and, on failure, retries with a simple bisection
//! shrink over the case index space to report the smallest failing seed.
//!
//! Usage:
//! ```ignore
//! check::property("charge is conserved", 500, |rng| {
//!     let v: Vec<f64> = (0..rng.below(20) + 1).map(|_| rng.uniform()).collect();
//!     let shared = share(&v);
//!     prop_assert!((shared * v.len() as f64 - v.iter().sum::<f64>()).abs() < 1e-9);
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of one property evaluation (`Err` carries the failure).
pub type PropResult = Result<(), String>;

/// Assert inside a property; produces a message the runner reports.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!(
                "assertion failed: {} ({}:{})",
                stringify!($cond),
                file!(),
                line!()
            ));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert two floats are close (absolute tolerance).
#[macro_export]
macro_rules! prop_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {} vs {} = {} (|Δ| = {} > {}) at {}:{}",
                stringify!($a),
                a,
                stringify!($b),
                b,
                (a - b).abs(),
                $tol,
                file!(),
                line!()
            ));
        }
    }};
}

/// Run `prop` over `cases` random cases. Panics with the failing case's
/// seed and message so the case can be replayed exactly.
pub fn property<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed (for debugging).
pub fn replay<F>(seed: u64, mut prop: F) -> PropResult
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        property("always true", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_panics_with_seed() {
        property("fails on big", 100, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.9, "x = {x} too big");
            Ok(())
        });
    }

    #[test]
    fn macros_compile_and_work() {
        fn inner(rng: &mut Rng) -> PropResult {
            let x = rng.uniform();
            prop_assert!(x >= 0.0);
            prop_close!(x, x, 1e-12);
            Ok(())
        }
        property("macros", 10, inner);
    }
}
