//! A strictly-bounded HTTP/1.1 subset (hyper/axum are not in the
//! offline vendored crate set — see docs/adr/004): request-line +
//! headers + `Content-Length` bodies, keep-alive, nothing else. The
//! full wire contract lives in docs/http-api.md; this module is the
//! byte-level half (parse a request, write a response), shared by the
//! serving front end ([`crate::coordinator::http`]), the load
//! generator, and the conformance tests.
//!
//! Every input dimension is capped ([`Limits`]) **before** the bytes
//! are buffered, so a hostile peer cannot make the server allocate
//! unboundedly: the request head (request line + headers) is capped at
//! [`Limits::max_head_bytes`] total, header count at
//! [`Limits::max_headers`], and the declared body at
//! [`Limits::max_body_bytes`]. Anything outside the subset is refused
//! with the specific status the spec assigns (`411` for a missing
//! Content-Length on POST, `501` for Transfer-Encoding, `505` for
//! unknown versions, `431` for an oversized head, `413` for an
//! oversized body, `400` for everything malformed) — carried on
//! [`ReadError::Bad`] so the connection loop can answer and close
//! without interpreting the failure itself.

use std::io::{BufRead, Read, Write};

use crate::util::json::Json;

/// Hard caps on what [`read_request`] will buffer. Defaults are
/// generous for the JSON payloads of docs/http-api.md and tiny by
/// attack standards.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Request line + all header lines together (bytes).
    pub max_head_bytes: usize,
    /// Number of header lines.
    pub max_headers: usize,
    /// Declared `Content-Length` (bytes).
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_head_bytes: 8 * 1024,
            max_headers: 64,
            max_body_bytes: 1024 * 1024,
        }
    }
}

/// One parsed request. Header names are lower-cased at parse time;
/// values keep their case with surrounding whitespace trimmed.
#[derive(Debug)]
pub struct HttpRequest {
    /// Uppercase request method.
    pub method: String,
    /// Request target as sent (path + optional `?query`).
    pub target: String,
    /// `HTTP/1.1` or `HTTP/1.0` — anything else is refused with 505.
    pub version: String,
    /// Header name/value pairs, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw request body.
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header with this (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Keep-alive per the HTTP/1.x defaults: 1.1 stays open unless the
    /// client says `Connection: close`; 1.0 closes unless it says
    /// `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        if self.version == "HTTP/1.0" {
            conn.eq_ignore_ascii_case("keep-alive")
        } else {
            !conn.eq_ignore_ascii_case("close")
        }
    }

    /// Path split on `/` with the query string and empty segments
    /// dropped — what the router matches on.
    pub fn path_segments(&self) -> Vec<&str> {
        self.target
            .split('?')
            .next()
            .unwrap_or("")
            .split('/')
            .filter(|s| !s.is_empty())
            .collect()
    }
}

/// Why [`read_request`] returned no request.
#[derive(Debug)]
pub enum ReadError {
    /// Protocol violation: answer with `status` and close.
    Bad { status: u16, msg: String },
    /// Clean close before the first byte of a request — the keep-alive
    /// end of a connection, not an error.
    Eof,
    /// The read timed out with no request bytes consumed: an idle
    /// keep-alive connection. The caller decides whether to keep
    /// waiting (poll its drain flag and loop) or give up.
    Idle,
    /// Transport failure (including a timeout mid-request) — nothing
    /// sensible can be answered; just close.
    Io(std::io::Error),
}

fn bad(status: u16, msg: impl Into<String>) -> ReadError {
    ReadError::Bad { status, msg: msg.into() }
}

fn is_timeout(e: &std::io::Error) -> bool {
    // unix sockets report SO_RCVTIMEO expiry as WouldBlock, windows as
    // TimedOut — treat both as the timeout they are
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one `\n`-terminated line into `buf` (CR/LF stripped), buffering
/// at most `cap` bytes. `consumed_any` distinguishes an idle timeout
/// (no request started) from a stall mid-request.
fn read_line_bounded(
    r: &mut impl BufRead,
    buf: &mut Vec<u8>,
    cap: usize,
    consumed_any: &mut bool,
) -> Result<(), ReadError> {
    loop {
        let available = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) && !*consumed_any && buf.is_empty() => {
                return Err(ReadError::Idle)
            }
            Err(e) => return Err(ReadError::Io(e)),
        };
        if available.is_empty() {
            // EOF: clean only between requests
            if buf.is_empty() && !*consumed_any {
                return Err(ReadError::Eof);
            }
            return Err(bad(400, "connection closed mid-request"));
        }
        *consumed_any = true;
        match available.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if buf.len() + i > cap {
                    return Err(bad(431, "request head too large"));
                }
                buf.extend_from_slice(&available[..i]);
                r.consume(i + 1);
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                return Ok(());
            }
            None => {
                let n = available.len();
                if buf.len() + n > cap {
                    return Err(bad(431, "request head too large"));
                }
                buf.extend_from_slice(available);
                r.consume(n);
            }
        }
    }
}

/// Parse one request off the stream, enforcing `limits` as the bytes
/// arrive. Returns [`ReadError::Eof`] on a clean keep-alive close and
/// [`ReadError::Idle`] on a first-byte read timeout; every protocol
/// violation carries the status to answer with ([`ReadError::Bad`]).
pub fn read_request(
    r: &mut impl BufRead,
    limits: &Limits,
) -> Result<HttpRequest, ReadError> {
    let mut consumed = false;
    let mut head_budget = limits.max_head_bytes;
    let mut line = Vec::new();
    read_line_bounded(r, &mut line, head_budget, &mut consumed)?;
    head_budget = head_budget.saturating_sub(line.len() + 2);
    let text = std::str::from_utf8(&line)
        .map_err(|_| bad(400, "request line is not valid UTF-8"))?;
    let mut parts = text.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None)
                if !m.is_empty() && !t.is_empty() && !v.is_empty() =>
            {
                (m, t, v)
            }
            _ => return Err(bad(400, "malformed request line")),
        };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(bad(400, "malformed method"));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(bad(505, format!("unsupported version '{version}'")));
    }
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let mut hline = Vec::new();
        read_line_bounded(r, &mut hline, head_budget, &mut consumed)?;
        if hline.is_empty() {
            break;
        }
        if headers.len() == limits.max_headers {
            return Err(bad(431, "too many headers"));
        }
        head_budget = head_budget.saturating_sub(hline.len() + 2);
        let htext = std::str::from_utf8(&hline)
            .map_err(|_| bad(400, "header is not valid UTF-8"))?;
        let Some((name, value)) = htext.split_once(':') else {
            return Err(bad(400, "malformed header line"));
        };
        headers
            .push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = HttpRequest {
        method: method.to_string(),
        target: target.to_string(),
        version: version.to_string(),
        headers,
        body: Vec::new(),
    };
    if req.header("transfer-encoding").is_some() {
        return Err(bad(501, "transfer-encoding is not supported"));
    }
    let body = match req.header("content-length") {
        Some(cl) => {
            let n: usize = cl
                .parse()
                .map_err(|_| bad(400, format!("bad Content-Length '{cl}'")))?;
            if n > limits.max_body_bytes {
                return Err(bad(
                    413,
                    format!(
                        "body of {n} bytes exceeds the {} byte limit",
                        limits.max_body_bytes
                    ),
                ));
            }
            let mut body = vec![0u8; n];
            r.read_exact(&mut body).map_err(|e| {
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    bad(400, "connection closed mid-body")
                } else {
                    ReadError::Io(e)
                }
            })?;
            body
        }
        None => {
            // methods that carry request bodies must declare them —
            // there is no chunked fallback in this subset
            if req.method == "POST" || req.method == "PUT" {
                return Err(bad(411, "Content-Length required"));
            }
            Vec::new()
        }
    };
    Ok(HttpRequest { body, ..req })
}

/// Reason phrase for every status the spec (docs/http-api.md) emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Write one response: status line, `Content-Type`/`Content-Length`/
/// `Connection` (the three headers the subset defines), and the body.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    close: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\n\
         content-length: {}\r\nconnection: {}\r\n\r\n",
        status_reason(status),
        body.len(),
        if close { "close" } else { "keep-alive" }
    )?;
    w.write_all(body)?;
    w.flush()
}

// ---------------------------------------------------------------------------
// Client half — for the load generator, benches, and tests
// ---------------------------------------------------------------------------

/// A parsed response on the client side.
#[derive(Debug)]
pub struct HttpResponse {
    /// Response status code.
    pub status: u16,
    /// Header name/value pairs.
    pub headers: Vec<(String, String)>,
    /// Raw response body.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// First header value matching `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Body text (lossy — diagnostics only).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    /// Body parsed as JSON.
    pub fn json(&self) -> anyhow::Result<Json> {
        let text = std::str::from_utf8(&self.body)?;
        Json::parse(text).map_err(|e| anyhow::anyhow!("response body: {e}"))
    }
}

/// Parse one response off a stream: status line, headers, then exactly
/// `Content-Length` body bytes (0 when absent — the server half always
/// declares it).
pub fn read_response(r: &mut impl BufRead) -> anyhow::Result<HttpResponse> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    if line.is_empty() {
        anyhow::bail!("connection closed before the status line");
    }
    let status: u16 = line
        .trim_end()
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line '{line}'"))?;
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h)?;
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            headers
                .push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse())
        .transpose()
        .map_err(|_| anyhow::anyhow!("bad Content-Length in response"))?
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(HttpResponse { status, headers, body })
}

/// One keep-alive client connection speaking the same subset: JSON in,
/// JSON out, requests strictly in series (the closed-loop shape the
/// load generator wants).
pub struct HttpClient {
    reader: std::io::BufReader<std::net::TcpStream>,
    writer: std::net::TcpStream,
}

impl HttpClient {
    /// Open a client connection to `addr`.
    pub fn connect(addr: &str) -> std::io::Result<HttpClient> {
        let stream = std::net::TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(HttpClient {
            reader: std::io::BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// One blocking request/response roundtrip. POST/PUT always declare
    /// a `Content-Length` (0 when `body` is `None`) — the server's 411
    /// rule; other methods only when a body is given.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> anyhow::Result<HttpResponse> {
        let payload = body.map(|j| j.to_string()).unwrap_or_default();
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: minimalist\r\n");
        if body.is_some() || method == "POST" || method == "PUT" {
            head.push_str(&format!(
                "content-type: application/json\r\ncontent-length: {}\r\n",
                payload.len()
            ));
        }
        head.push_str("\r\n");
        self.writer.write_all(head.as_bytes())?;
        self.writer.write_all(payload.as_bytes())?;
        self.writer.flush()?;
        read_response(&mut self.reader)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<HttpRequest, ReadError> {
        read_request(&mut Cursor::new(raw.as_bytes()), &Limits::default())
    }

    fn bad_status(r: Result<HttpRequest, ReadError>) -> u16 {
        match r {
            Err(ReadError::Bad { status, .. }) => status,
            other => panic!("expected Bad, got {other:?}"),
        }
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let req = parse(
            "POST /v1/classify?x=1 HTTP/1.1\r\nHost: h\r\n\
             Content-Length: 4\r\n\r\nabcd",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path_segments(), vec!["v1", "classify"]);
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.header("host"), Some("h"));
        assert!(req.keep_alive());
    }

    #[test]
    fn keep_alive_defaults_per_version() {
        let close11 = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!close11.unwrap().keep_alive());
        assert!(!parse("GET / HTTP/1.0\r\n\r\n").unwrap().keep_alive());
        let ka10 = parse("GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n");
        assert!(ka10.unwrap().keep_alive());
    }

    #[test]
    fn pipelined_requests_parse_in_sequence() {
        let raw = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut c = Cursor::new(raw.as_bytes());
        let l = Limits::default();
        assert_eq!(read_request(&mut c, &l).unwrap().target, "/a");
        assert_eq!(read_request(&mut c, &l).unwrap().target, "/b");
        assert!(matches!(read_request(&mut c, &l), Err(ReadError::Eof)));
    }

    #[test]
    fn refusals_carry_the_documented_status() {
        assert_eq!(bad_status(parse("WHAT?\r\n\r\n")), 400);
        assert_eq!(bad_status(parse("get / HTTP/1.1\r\n\r\n")), 400);
        assert_eq!(bad_status(parse("GET / HTTP/2.0\r\n\r\n")), 505);
        assert_eq!(bad_status(parse("POST /x HTTP/1.1\r\n\r\n")), 411);
        let chunked = "POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(bad_status(parse(chunked)), 501);
        let nocl = "POST /x HTTP/1.1\r\nContent-Length: zero\r\n\r\n";
        assert_eq!(bad_status(parse(nocl)), 400);
        let big = "POST /x HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n";
        assert_eq!(bad_status(parse(big)), 413);
        let huge_header =
            format!("GET / HTTP/1.1\r\nx-pad: {}\r\n\r\n", "a".repeat(9000));
        assert_eq!(bad_status(parse(&huge_header)), 431);
        assert_eq!(bad_status(parse("GET / HTTP/1.1\r\nnocolon\r\n\r\n")), 400);
        // truncations: mid-head and mid-body
        assert_eq!(bad_status(parse("GET / HTTP/1.1\r\nHost: h")), 400);
        let cut = "POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        assert_eq!(bad_status(parse(cut)), 400);
    }

    #[test]
    fn header_count_limit_enforced() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..65 {
            raw.push_str(&format!("x-{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        assert_eq!(bad_status(parse(&raw)), 431);
    }

    #[test]
    fn response_roundtrips_through_the_client_parser() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "application/json", b"{\"a\":1}", false)
            .unwrap();
        let resp = read_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("connection"), Some("keep-alive"));
        assert_eq!(resp.json().unwrap().req_f64("a").unwrap(), 1.0);
        let mut wire = Vec::new();
        write_response(&mut wire, 429, "application/json", b"{}", true)
            .unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("connection: close"));
    }
}
